//! Buffered-async vs synchronous aggregation (DESIGN.md §Async):
//! wall-clock engine overhead per committed model version, plus the
//! acceptance gate — on the heavy-tailed `edge-mix` preset the
//! buffered engine must reach the sync run's target training loss in
//! **≤ 0.8× the simulated seconds** (≥ 1.25× better simulated
//! time-to-target-loss) **at comparable uploaded bits**. The sync
//! barrier pays the slowest of K uploads every round; the buffered
//! engine commits after the m fastest arrivals across overlapping
//! cohorts, so the straggler tail stops pacing learning. Both gates
//! run in the `AQUILA_BENCH_FAST=1` CI smoke, so a regression that
//! slows the event engine's simulated clock fails CI outright. A
//! polynomial-staleness configuration is reported alongside (target
//! reached, clock ≤ sync) without the tight bits gate — staleness
//! down-weighting trades some upload efficiency for robustness.

use aquila::algorithms::qsgd::QsgdAlgo;
use aquila::benchkit::{black_box, Bench};
use aquila::coordinator::{AggregationMode, RunConfig, Session, StalenessPolicy};
use aquila::problems::quadratic::QuadraticProblem;
use aquila::transport::scenario::NetworkSpec;
use std::sync::Arc;

/// Model dimension of the quadratic problem.
const DIM: usize = 48;
/// Device count (full participation: the sync cohort is all of them).
const DEVICES: usize = 10;
/// Buffered commit size: arrivals folded per model version.
const M: usize = 5;

fn cfg(aggregation: AggregationMode, rounds: usize) -> RunConfig {
    RunConfig {
        alpha: 0.2,
        beta: 0.25,
        rounds,
        eval_every: 0,
        seed: 11,
        threads: 0,
        network: NetworkSpec::parse("edge-mix:jitter=0.3").unwrap(),
        aggregation,
        ..RunConfig::default()
    }
}

fn buffered(staleness: StalenessPolicy) -> AggregationMode {
    AggregationMode::Buffered {
        m: M,
        staleness,
        max_inflight: 3 * DEVICES,
    }
}

fn session(aggregation: AggregationMode, rounds: usize) -> Session {
    let problem = Arc::new(QuadraticProblem::new(DIM, DEVICES, 0.5, 2.0, 0.5, 0xA5));
    Session::builder(problem, Arc::new(QsgdAlgo::new(6)))
        .config(cfg(aggregation, rounds))
        .build()
}

/// Simulated seconds and uploaded bits at the first record reaching
/// `target` training loss.
fn hit(trace: &aquila::metrics::RunTrace, target: f64) -> Option<(f64, u64)> {
    trace
        .rounds
        .iter()
        .find(|r| r.train_loss <= target)
        .map(|r| (r.sim_time, r.cum_bits))
}

fn main() {
    let mut bench = Bench::from_env_args();
    let fast = std::env::var("AQUILA_BENCH_FAST").is_ok();

    // ---- Wall-clock: event-loop overhead per commit ----------------
    // Horizons far beyond the time budget so the final-round eval
    // never lands in a timed sample.
    let mut s_sync = session(AggregationMode::Sync, 1_000_000);
    let mut k = 0usize;
    bench.bench_throughput(
        &format!("sync round edge-mix K={DEVICES}"),
        (DEVICES * DIM) as u64,
        || {
            black_box(s_sync.run_round(k));
            k += 1;
        },
    );
    let mut s_buf = session(buffered(StalenessPolicy::Constant(1.0)), 1_000_000);
    let mut k = 0usize;
    bench.bench_throughput(
        &format!("buffered commit edge-mix m={M} inflight={}", 3 * DEVICES),
        (M * DIM) as u64,
        || {
            black_box(s_buf.run_round(k));
            k += 1;
        },
    );

    // ---- CI gate: simulated time-to-target-loss --------------------
    let sync_rounds = if fast { 40 } else { 120 };
    let t_sync = session(AggregationMode::Sync, sync_rounds).run();
    // Target: the loss the sync run reaches at 3/4 of its horizon —
    // deep enough to be meaningful, shallow enough that the buffered
    // runs reach it well inside their commit budget.
    let target = t_sync.rounds[sync_rounds * 3 / 4].train_loss;
    let (sync_time, sync_bits) = hit(&t_sync, target).expect("sync run contains its own target");
    // Equal upload budget: m·commits = K·rounds, plus headroom so the
    // gate measures the clock, not the horizon cutoff.
    let commits = 2 * sync_rounds * DEVICES / M;

    let t_buf = session(buffered(StalenessPolicy::Constant(1.0)), commits).run();
    let (buf_time, buf_bits) =
        hit(&t_buf, target).expect("buffered run never reached the sync target loss");
    let time_ratio = buf_time / sync_time;
    let bits_ratio = buf_bits as f64 / sync_bits as f64;
    println!(
        "time-to-loss {target:.6}: sync {sync_time:.3}s / buffered {buf_time:.3}s \
         = {time_ratio:.3}x (gate: <= 0.8x), uploaded bits {bits_ratio:.3}x (gate: <= 1.25x)"
    );
    assert!(
        time_ratio <= 0.8,
        "buffered aggregation reached the target loss in {time_ratio:.2}x the sync \
         simulated time (gate: <= 0.8x) — the event engine lost its straggler advantage"
    );
    assert!(
        bits_ratio <= 1.25,
        "buffered aggregation spent {bits_ratio:.2}x the sync uploaded bits to reach \
         the target (gate: <= 1.25x) — the time win is not at comparable bits"
    );

    // ---- Reported (not bits-gated): polynomial staleness -----------
    let t_poly = session(buffered(StalenessPolicy::Poly(0.5)), commits).run();
    let (poly_time, poly_bits) =
        hit(&t_poly, target).expect("poly-staleness run never reached the sync target loss");
    println!(
        "poly:0.5 staleness: {:.3}x sync time, {:.3}x sync bits",
        poly_time / sync_time,
        poly_bits as f64 / sync_bits as f64
    );
    assert!(
        poly_time <= sync_time,
        "poly-staleness buffered run was slower than the sync barrier on simulated time"
    );
    bench.finish();
}
