//! Chaos-decorator overhead: wrapping a connection in a disabled
//! [`ChaosConnection`] must be free.
//!
//! The serve path always constructs through the decorator-capable
//! code, so the pass-through cost of a disabled [`ChaosSpec`] is paid
//! by every production run. `ChaosSpec::roll` returns before building
//! any RNG when a probability is zero, so the disabled decorator adds
//! a handful of branches per op — this bench measures a send→recv
//! round-trip over a bare loopback pair vs the same pair behind a
//! disabled decorator and **asserts the overhead stays under 5%**. An
//! enabled mix (detectable corruption) is reported for scale but not
//! gated: injecting faults is allowed to cost whatever it costs.

use aquila::benchkit::{black_box, Bench};
use aquila::protocol::transport::LoopbackConnection;
use aquila::protocol::{ChaosConnection, ChaosSpec, Connection, Message, ProtocolError};
use std::sync::Arc;
use std::time::Duration;

/// Send→recv round-trips per timed sample, amortizing timer noise.
const BATCH: usize = 512;

fn main() {
    let mut bench = Bench::from_env_args();
    let timeout = Duration::from_secs(1);

    let (mut tx, mut rx) = LoopbackConnection::pair();
    let bare = bench
        .bench_throughput(&format!("loopback_bare batch={BATCH}"), BATCH as u64, || {
            for _ in 0..BATCH {
                tx.send(black_box(&Message::Heartbeat)).expect("send");
                black_box(rx.recv(timeout).expect("recv"));
            }
        })
        .mean;

    let (a, mut rx) = LoopbackConnection::pair();
    let mut tx = ChaosConnection::new(Box::new(a), Arc::new(ChaosSpec::default()), 1);
    let disabled = bench
        .bench_throughput(
            &format!("loopback_chaos_disabled batch={BATCH}"),
            BATCH as u64,
            || {
                for _ in 0..BATCH {
                    tx.send(black_box(&Message::Heartbeat)).expect("send");
                    black_box(rx.recv(timeout).expect("recv"));
                }
            },
        )
        .mean;

    // Enabled chaos, for scale: corruption is detectable (the peer sees
    // `UnknownKind`) and leaves the loopback pair usable, so the same
    // loop runs with faults actually firing.
    let spec = ChaosSpec {
        corrupt_p: 0.2,
        seed: 7,
        ..ChaosSpec::default()
    };
    let (a, mut rx) = LoopbackConnection::pair();
    let mut tx = ChaosConnection::new(Box::new(a), Arc::new(spec), 2);
    let mut corrupted = 0u64;
    bench.bench_throughput(
        &format!("loopback_chaos_corrupt20 batch={BATCH}"),
        BATCH as u64,
        || {
            for _ in 0..BATCH {
                tx.send(black_box(&Message::Heartbeat)).expect("send");
                match rx.recv(timeout) {
                    Ok(m) => {
                        black_box(m);
                    }
                    Err(ProtocolError::UnknownKind(_)) => corrupted += 1,
                    Err(e) => panic!("unexpected recv error: {e}"),
                }
            }
        },
    );

    let ratio = disabled.as_secs_f64() / bare.as_secs_f64().max(1e-12);
    println!(
        "disabled-chaos decorator: {ratio:.3}x bare loopback \
         ({corrupted} frames corrupted in the enabled case)"
    );
    assert!(
        ratio < 1.05,
        "disabled chaos decorator must cost < 5% over bare loopback, measured {ratio:.3}x"
    );
    bench.finish();
}
