//! Population-virtualization bench (DESIGN.md §Population): per-round
//! wall-clock and resident slot counts as the population N grows at a
//! fixed cohort K — the lazy, spec-backed device store's contract is
//! that both stay flat in N (memory O(cache + K + d), round time
//! O(K·d), never O(N)).
//!
//! Each case drives a virtualized AQUILA run over the streamed
//! quadratic with random-K selection and a bounded slot cache, timing
//! steady-state rounds (round 0's bootstrap stays outside the timed
//! region). Under `AQUILA_BENCH_FAST=1` (CI smoke) the sweep runs
//! N ∈ {10k, 1M} and the bench *asserts* the contract (min timings):
//! peak resident slots never exceed cache + K, and the N=1M round is
//! within 1.25× of the N=10k round — so an accidental O(N) scan on the
//! round path fails CI instead of silently decaying. The full sweep
//! adds N=10M.

use aquila::algorithms::aquila::Aquila;
use aquila::benchkit::{black_box, Bench};
use aquila::coordinator::{RunConfig, Session, SlotPolicy};
use aquila::problems::quadratic::StreamedQuadratic;
use aquila::problems::GradientSource;
use aquila::selection::SelectionSpec;
use std::sync::Arc;
use std::time::Duration;

/// Cohort size per round (the paper-scale K for million-device runs).
const K: usize = 1000;
/// Live-slot cache capacity — a couple of cohorts.
const CACHE: usize = 2048;
/// Model dimension of the streamed quadratic.
const DIM: usize = 256;

fn pop_label(n: usize) -> String {
    if n >= 1_000_000 {
        format!("N={}M", n / 1_000_000)
    } else {
        format!("N={}k", n / 1_000)
    }
}

/// Bench steady-state rounds at population `n`; returns the min round
/// time and the session's peak resident slot count.
fn bench_population(bench: &mut Bench, n: usize) -> (Duration, usize) {
    let label = pop_label(n);
    let problem: Arc<dyn GradientSource> =
        Arc::new(StreamedQuadratic::new(DIM, n, 0.5, 2.0, 0.5, 0xA11A));
    let cfg = RunConfig {
        alpha: 0.2,
        beta: 0.25,
        // Far beyond what the time budget reaches, so the final-round
        // evaluation never lands inside a timed sample.
        rounds: 1_000_000,
        eval_every: 0,
        seed: 7,
        threads: 0,
        slots: SlotPolicy::Lazy { cache: CACHE },
        ..RunConfig::default()
    };
    let mut session = Session::builder(problem, Arc::new(Aquila::new(0.25)))
        .config(cfg)
        .selection_spec(SelectionSpec::RandomK(K))
        .build();
    // Bootstrap round (first cohort materialization) outside the timed
    // region — steady state is what must be flat in N.
    session.run_round(0);
    let mut k = 1usize;
    let min = bench
        .bench_throughput(
            &format!("virtualized round {label} K={K} cache={CACHE}"),
            (K * DIM) as u64,
            || {
                black_box(session.run_round(k));
                k += 1;
            },
        )
        .min;
    let resident = session.resident_slots();
    let peak = session.peak_resident_slots();
    println!("  {label}: {} rounds, resident slots {resident}, peak {peak}", k);
    // Memory gate: residency is bounded by the cache plus one
    // in-flight cohort, at every population size.
    assert!(
        peak <= CACHE + K,
        "{label}: peak resident slots {peak} exceed cache {CACHE} + cohort {K}"
    );
    assert!(
        resident <= CACHE,
        "{label}: {resident} live slots exceed the cache {CACHE} between rounds"
    );
    (min, peak)
}

fn main() {
    let mut bench = Bench::from_env_args();
    let fast = std::env::var("AQUILA_BENCH_FAST").is_ok();
    let pops: &[usize] = if fast {
        &[10_000, 1_000_000]
    } else {
        &[10_000, 1_000_000, 10_000_000]
    };
    let mut timings = Vec::new();
    for &n in pops {
        let (min, _) = bench_population(&mut bench, n);
        timings.push((n, min));
    }

    // ---- CI gate: round time flat in N -----------------------------
    let t_at = |n: usize| {
        timings
            .iter()
            .find(|&&(pop, _)| pop == n)
            .map(|&(_, t)| t)
            .expect("population was benched")
    };
    let t_small = t_at(10_000);
    let t_large = t_at(1_000_000);
    let ratio = t_large.as_secs_f64() / t_small.as_secs_f64();
    println!("round-time ratio N=1M / N=10k: {ratio:.3}x (gate: <= 1.25x)");
    assert!(
        ratio <= 1.25,
        "virtualized round time grew {ratio:.2}x from N=10k to N=1M — an O(N) scan \
         leaked onto the round path ({t_small:?} -> {t_large:?})"
    );
    bench.finish();
}
