//! Protocol bench (ISSUE 6 satellite): what a served round adds on top
//! of the in-process transport phase.
//!
//! * `transmit_direct` — the baseline: staged wire bytes straight into
//!   `Channel::transmit`, exactly what `RoundEngine::finish_round`
//!   does in-process.
//! * `loopback_round` — the same round's uploads as framed
//!   `RoundResult` messages through a loopback connection pair,
//!   decoded (including wire-payload validation) and then fed to the
//!   same channel transmit — the coordinator-service data path minus
//!   threads.
//!
//! The closing ratio is the per-round protocol overhead; it should be
//! small relative to the transmit itself (framing is one header per
//! message and payload bytes are never re-encoded).

use aquila::benchkit::{black_box, Bench};
use aquila::protocol::messages::RoundResult;
use aquila::protocol::transport::LoopbackConnection;
use aquila::protocol::{Connection, Message};
use aquila::quant::midtread::quantize;
use aquila::transport::wire::{self, Payload, UploadRef};
use aquila::transport::Channel;
use aquila::util::rng::Xoshiro256pp;
use std::time::Duration;

fn main() {
    let mut bench = Bench::from_env_args();
    let d = 65_536usize;
    let m = 32usize;
    let mut rng = Xoshiro256pp::seed_from_u64(17);

    // One 4-bit innovation payload per device, pre-encoded to wire
    // bytes (what the device phase stages on either side).
    let payloads: Vec<Vec<u8>> = (0..m)
        .map(|_| {
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            wire::encode(&Payload::MidtreadDelta(quantize(&v, 4)))
        })
        .collect();
    let participants: Vec<usize> = (0..m).collect();
    let model_bits = d as u64 * 32;

    let mut ch = Channel::reliable();
    let mut round = 0usize;
    let direct_mean = bench
        .bench_throughput(&format!("transmit_direct d=64k M={m} b=4"), (d * m) as u64, || {
            let ups: Vec<UploadRef<'_>> = payloads
                .iter()
                .enumerate()
                .map(|(dev, bytes)| UploadRef { device: dev, bytes })
                .collect();
            let (del, stats) = ch.transmit(round, &participants, model_bits, ups);
            assert_eq!(del.len(), m, "reliable channel delivers everything");
            black_box(stats);
            round += 1;
        })
        .mean;

    let msgs: Vec<Message> = payloads
        .iter()
        .enumerate()
        .map(|(dev, bytes)| {
            Message::RoundResult(RoundResult {
                round: 0,
                device: dev as u32,
                loss: 0.5,
                level: Some(4),
                uploads: 1,
                skips: 0,
                payload: Some(bytes.clone()),
            })
        })
        .collect();
    let (mut tx, mut rx) = LoopbackConnection::pair();
    let mut ch2 = Channel::reliable();
    let mut round = 0usize;
    let served_mean = bench
        .bench_throughput(
            &format!("loopback_round frame+decode+transmit d=64k M={m}"),
            (d * m) as u64,
            || {
                for msg in &msgs {
                    tx.send(msg).expect("loopback send");
                }
                let mut arrived: Vec<(usize, Vec<u8>)> = Vec::with_capacity(m);
                for _ in 0..m {
                    match rx.recv(Duration::from_secs(1)).expect("loopback recv") {
                        Message::RoundResult(r) => {
                            arrived.push((r.device as usize, r.payload.expect("payload")));
                        }
                        other => panic!("unexpected message: {other:?}"),
                    }
                }
                let ups: Vec<UploadRef<'_>> = arrived
                    .iter()
                    .map(|(dev, bytes)| UploadRef { device: *dev, bytes })
                    .collect();
                let (del, stats) = ch2.transmit(round, &participants, model_bits, ups);
                assert_eq!(del.len(), m, "every framed upload arrives");
                black_box(stats);
                round += 1;
            },
        )
        .mean;

    println!(
        "protocol overhead (framing + loopback + decode) vs direct transmit: {:.2}x",
        served_mean.as_secs_f64() / direct_mean.as_secs_f64().max(1e-12),
    );
    bench.finish();
}
