//! Sectioned vs global quantization: kernel speed, measured round
//! quantization error at equal bits, and wire header overhead, on
//! dataset-shaped gradients (device 0's full-batch gradient of each
//! synth problem). Run with `--json ../BENCH_quant.json` to record the
//! trajectory; EXPERIMENTS.md §Sectioned quantization documents the
//! columns.
//!
//! Like the aggregation bench, this doubles as a smoke check: it
//! *asserts* that tensor-mode scales strictly reduce the measured
//! error on the synth-cf10 MLP (the motivating case — bias vs weight
//! gradient scales), never increase it meaningfully anywhere else, and
//! that tensor-mode header overhead at d = 1M stays under 0.1% — so a
//! sectioning regression fails CI instead of silently skewing numbers.

use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::hetero::CapacityMask;
use aquila::problems::ParamLayout;
use aquila::quant::midtread::{
    dequantize, quantize_sections, quantize_sections_buf, quantize_sections_packed_buf,
};
use aquila::quant::packing::packed_len;
use aquila::quant::{SectionSpec, Sections};
use aquila::transport::wire::{encode, Payload};

const BITS: u8 = 4;

fn sq_err(v: &[f32], dq: &[f32]) -> f64 {
    v.iter()
        .zip(dq)
        .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
        .sum()
}

/// Quantize `grad` under `sections`, returning (wire bytes, ‖v − Δq‖₂²).
fn measure(grad: &[f32], sections: &Sections) -> (usize, f64) {
    let q = quantize_sections(grad, BITS, sections);
    let err = sq_err(grad, &dequantize(&q));
    let bytes = encode(&Payload::MidtreadFull(q)).len();
    (bytes, err)
}

fn main() {
    let mut bench = Bench::from_env_args();
    let modes = [
        SectionSpec::Global,
        SectionSpec::Tensor,
        SectionSpec::Fixed(1024),
    ];

    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.05, 1);
        let problem = spec.build_problem();
        let d = problem.dim();
        let layout = problem.layout();
        let mask = CapacityMask::full(d);
        let theta = problem.init_theta(spec.seed);
        let mut grad = vec![0.0f32; d];
        let mut ws = problem.make_scratch();
        problem.local_grad(0, &theta, &mut grad, &mut ws);

        let (global_bytes, global_err) = measure(&grad, &Sections::global(d));
        for mode in modes {
            let sections = mode.resolve(&layout, &mask);
            let (bytes, err) = measure(&grad, &sections);
            let overhead = 100.0 * (bytes as f64 - global_bytes as f64) / global_bytes as f64;
            println!(
                "{:<6} d={d:<7} {:<12} sq_error {err:>13.6e}  overhead {overhead:>8.4}%",
                ds.name(),
                mode.to_string()
            );
            // Smoke assertions (see module docs).
            assert!(
                err <= global_err * 1.02 + 1e-12,
                "{} {mode}: sectioned error {err} exceeds global {global_err}",
                ds.name()
            );
            if ds == DatasetKind::Cf10 && mode == SectionSpec::Tensor {
                assert!(
                    err < global_err,
                    "tensor scales must reduce cf10 MLP error: {err} vs {global_err}"
                );
            }
            // The measurements ride in the case name so the JSON
            // artifact records them alongside the timing.
            let label = format!(
                "quantize {} b={BITS} {mode} err={err:.4e} overhead={overhead:.4}%",
                ds.name()
            );
            let mut psi = Vec::new();
            bench.bench_throughput(&label, d as u64, || {
                let q =
                    quantize_sections_buf(black_box(&grad), BITS, &sections, std::mem::take(&mut psi));
                psi = black_box(q).psi;
            });
            // Fused quantize→pack counterpart: same scales, straight
            // to the packed little-endian wire body (no psi vector).
            let packed_label = format!("quantize_packed {} b={BITS} {mode}", ds.name());
            let mut body = Vec::new();
            bench.bench_gbps(
                &packed_label,
                d as u64,
                4 * d as u64 + packed_len(d, BITS) as u64,
                || {
                    let q = quantize_sections_packed_buf(
                        black_box(&grad),
                        BITS,
                        &sections,
                        std::mem::take(&mut body),
                    );
                    body = black_box(q).body;
                },
            );
        }
    }

    // Header-overhead contract at production scale: a d ≈ 1M model with
    // 8 tensors must pay ≤ 0.1% extra wire bytes in tensor mode.
    let layout = ParamLayout::contiguous(&[
        ("w1", vec![512, 1024]),
        ("b1", vec![512]),
        ("w2", vec![512, 512]),
        ("b2", vec![512]),
        ("w3", vec![256, 512]),
        ("b3", vec![256]),
        ("w4", vec![256, 420]),
        ("b4", vec![256]),
    ]);
    let d = layout.dim();
    let mask = CapacityMask::full(d);
    let grad: Vec<f32> = (0..d)
        .map(|i| ((i % 977) as f32 - 488.0) / 488.0)
        .collect();
    let (global_bytes, _) = measure(&grad, &Sections::global(d));
    for mode in [SectionSpec::Tensor, SectionSpec::Fixed(1024)] {
        let sections = mode.resolve(&layout, &mask);
        let (bytes, _) = measure(&grad, &sections);
        let overhead = 100.0 * (bytes as f64 - global_bytes as f64) / global_bytes as f64;
        println!("d={d} {mode}: {bytes} wire bytes, overhead {overhead:.4}% over {global_bytes}");
        if mode == SectionSpec::Tensor {
            assert!(
                overhead <= 0.1,
                "tensor-mode header overhead {overhead}% exceeds 0.1% at d={d}"
            );
        }
        let label = format!("encode d=1M b={BITS} {mode} overhead={overhead:.4}%");
        let q = quantize_sections(&grad, BITS, &sections);
        let p = Payload::MidtreadFull(q);
        let mut buf = Vec::new();
        bench.bench_throughput(&label, d as u64, || {
            aquila::transport::wire::encode_into(black_box(&p), &mut buf);
            black_box(&buf);
        });
    }
    bench.finish();
}
