//! Transport-layer benchmarks: scenario simulation cost on the round
//! path.
//!
//! The zero-copy fold (§Perf in DESIGN.md) made the server side cheap;
//! this bench shows the `transport::scenario` subsystem (per-device
//! links, deadline window, jitter, round-keyed fault stream) adds
//! negligible overhead on top of it: channel transmit is measured over
//! the ideal network vs a hostile cellular scenario, and the combined
//! transmit→fold path is measured against the fold alone. Cases:
//!
//! * `transmit_ideal` — byte counting only (the pre-scenario path).
//! * `transmit_cellular` — full simulation: link lookup, jittered
//!   transfer times, deadline window, fault coin per upload.
//! * `fold_only` / `transmit+fold_cellular` — the scenario's marginal
//!   cost relative to the real per-round server work.

use aquila::algorithms::ServerAgg;
use aquila::benchkit::{black_box, Bench};
use aquila::hetero::CapacityMask;
use aquila::quant::midtread::quantize;
use aquila::transport::scenario::NetworkSpec;
use aquila::transport::wire::{upload_refs, EncodedUpload, Payload};
use aquila::transport::{Channel, FaultSpec};
use aquila::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn main() {
    let mut bench = Bench::from_env_args();
    let d = 262_144usize;
    let m = 32usize;
    let mut rng = Xoshiro256pp::seed_from_u64(9);

    // One 4-bit innovation payload per device, pre-encoded to wire
    // bytes (what the device phase stages).
    let staged: Vec<EncodedUpload> = (0..m)
        .map(|dev| {
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            EncodedUpload::encode(dev, &Payload::MidtreadDelta(quantize(&v, 4)))
        })
        .collect();
    let participants: Vec<usize> = (0..m).collect();
    let model_bits = d as u64 * 32;

    // Ideal network: byte counting only.
    let mut ch_ideal = Channel::reliable();
    let mut round = 0usize;
    let ideal_mean = bench
        .bench_throughput(&format!("transmit_ideal d=256k M={m} b=4"), (d * m) as u64, || {
            let ups = upload_refs(black_box(&staged));
            let (del, stats) = ch_ideal.transmit(round, &participants, model_bits, ups);
            assert_eq!(del.len(), m, "ideal reliable channel delivers everything");
            black_box(stats);
            round += 1;
        })
        .mean;

    // Hostile scenario: heterogeneous cellular links, finite deadline,
    // jitter, and a 5% fault stream — the full simulation cost.
    let spec = NetworkSpec::parse("cellular:deadline=2,policy=late,jitter=0.1")
        .expect("bench spec is valid");
    let mut ch_cell = Channel::with_scenario(
        FaultSpec {
            drop_prob: 0.05,
            seed: 3,
        },
        spec.build(m, 7),
    );
    let mut round = 0usize;
    let cell_mean = bench
        .bench_throughput(
            &format!("transmit_cellular+deadline+jitter d=256k M={m}"),
            (d * m) as u64,
            || {
                let ups = upload_refs(black_box(&staged));
                let (del, stats) = ch_cell.transmit(round, &participants, model_bits, ups);
                black_box((del.len(), stats));
                round += 1;
            },
        )
        .mean;

    // The real per-round server work, for scale: zero-copy fold alone,
    // then transmit + fold with the scenario on.
    let full = Arc::new(CapacityMask::full(d));
    let masks: Vec<_> = (0..m).map(|_| full.clone()).collect();
    let scale = 1.0 / m as f32;
    let mut srv = ServerAgg::new(d, masks.clone());
    let uploads = upload_refs(&staged);
    let fold_mean = bench
        .bench_throughput(&format!("fold_only d=256k M={m} b=4"), (d * m) as u64, || {
            srv.accumulate(black_box(&uploads), scale);
            black_box(&srv.direction);
        })
        .mean;
    let mut srv2 = ServerAgg::new(d, masks);
    let mut ch2 = Channel::with_scenario(FaultSpec::none(), spec.build(m, 7));
    let mut round = 0usize;
    let both_mean = bench
        .bench_throughput(
            &format!("transmit+fold_cellular d=256k M={m}"),
            (d * m) as u64,
            || {
                let ups = upload_refs(black_box(&staged));
                let (del, _) = ch2.transmit(round, &participants, model_bits, ups);
                srv2.accumulate(&del, scale);
                black_box(&srv2.direction);
                round += 1;
            },
        )
        .mean;

    println!(
        "scenario transmit vs ideal transmit: {:.2}x; transmit+fold vs fold alone: {:.3}x",
        cell_mean.as_secs_f64() / ideal_mean.as_secs_f64().max(1e-12),
        both_mean.as_secs_f64() / fold_mean.as_secs_f64().max(1e-12),
    );
    bench.finish();
}
