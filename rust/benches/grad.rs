//! Device-phase benchmark: `local_grad` throughput per problem, the
//! batched GEMM compute layer versus the retained naive per-sample
//! reference — the before/after record of the ISSUE-3 refactor
//! (`BENCH_grad.json` in the repo root).
//!
//! Throughput is reported in samples (tokens for the LM) per call. The
//! bench also asserts the batched gradient matches the naive reference
//! elementwise, so a compute-layer regression fails the CI smoke run
//! rather than just skewing numbers.

use aquila::benchkit::{black_box, Bench};
use aquila::data::partition::iid_partition;
use aquila::data::synth::{train_test_split, MixtureSpec};
use aquila::data::text::{markov_corpus, shard_corpus, CorpusSpec};
use aquila::data::ClassificationDataset;
use aquila::problems::cnn::CnnProblem;
use aquila::problems::logistic::LogisticProblem;
use aquila::problems::mlp::MlpProblem;
use aquila::problems::softmax_lm::SoftmaxLmProblem;
use aquila::problems::GradientSource;
use aquila::util::rng::Xoshiro256pp;

fn mixture_shards(
    spec: &MixtureSpec,
    devices: usize,
) -> (Vec<ClassificationDataset>, ClassificationDataset) {
    let (train, test) = train_test_split(spec, 0.15);
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let parts = iid_partition(train.len(), devices, &mut rng);
    (parts.iter().map(|p| train.subset(p)).collect(), test)
}

/// Assert batched and naive gradients agree (1e-4 relative with a
/// gradient-scale floor) — the bench doubles as a correctness smoke.
fn assert_match(batched: &[f32], naive: &[f32], what: &str) {
    let scale = naive.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs())).max(1e-6);
    for (i, (&a, &b)) in batched.iter().zip(naive).enumerate() {
        let (a, b) = (a as f64, b as f64);
        let denom = a.abs().max(b.abs()).max(scale);
        assert!(
            (a - b).abs() <= 1e-4 * denom,
            "{what}[{i}]: batched {a} vs naive {b}"
        );
    }
}

/// Bench one problem's `local_grad` both ways on device 0; returns the
/// measured speedup.
fn bench_problem<P, F>(bench: &mut Bench, problem: &P, naive: F, label: &str, samples: u64) -> f64
where
    P: GradientSource,
    F: Fn(&P, usize, &[f32], &mut [f32]) -> f64,
{
    let d = problem.dim();
    let theta = problem.init_theta(3);
    let mut ws = problem.make_scratch();
    let mut g = vec![0.0f32; d];
    let mut g_ref = vec![0.0f32; d];
    problem.local_grad(0, &theta, &mut g, &mut ws);
    naive(problem, 0, &theta, &mut g_ref);
    assert_match(&g, &g_ref, label);

    let naive_mean = bench
        .bench_throughput(&format!("{label} (naive per-sample)"), samples, || {
            black_box(naive(problem, 0, black_box(&theta), &mut g_ref));
        })
        .mean;
    let batched_mean = bench
        .bench_throughput(&format!("{label} (batched gemm)"), samples, || {
            black_box(problem.local_grad(0, black_box(&theta), &mut g, &mut ws));
        })
        .mean;
    naive_mean.as_secs_f64() / batched_mean.as_secs_f64()
}

fn main() {
    let mut bench = Bench::from_env_args();

    // Logistic regression, CF-100-shaped head on CF-10-sized features.
    let spec = MixtureSpec {
        num_classes: 10,
        dim: 64,
        num_samples: 4096,
        separation: 0.3,
        noise: 1.0,
        seed: 41,
    };
    let (shards, test) = mixture_shards(&spec, 8);
    let n = shards[0].len() as u64;
    let logistic = LogisticProblem::new(shards, test, 1e-4);
    let s_logistic = bench_problem(
        &mut bench,
        &logistic,
        LogisticProblem::local_grad_naive,
        &format!("logistic local_grad shard={n} d={}", logistic.dim()),
        n,
    );

    // MLP (the CF-10 preset model, hidden 32).
    let (shards, test) = mixture_shards(&spec, 8);
    let mlp = MlpProblem::new(shards, test, 32, 1e-4);
    let s_mlp = bench_problem(
        &mut bench,
        &mlp,
        MlpProblem::local_grad_naive,
        &format!("mlp local_grad shard={n} d={}", mlp.dim()),
        n,
    );

    // CNN on 8×8 single-channel images, 8 filters of 3×3.
    let spec_img = MixtureSpec {
        num_classes: 10,
        dim: 64,
        num_samples: 2048,
        separation: 0.3,
        noise: 1.0,
        seed: 43,
    };
    let (shards, test) = mixture_shards(&spec_img, 8);
    let n_img = shards[0].len() as u64;
    let cnn = CnnProblem::new(shards, test, 8, 3, 1e-4);
    let s_cnn = bench_problem(
        &mut bench,
        &cnn,
        CnnProblem::local_grad_naive,
        &format!("cnn local_grad shard={n_img} d={}", cnn.dim()),
        n_img,
    );

    // Bigram softmax LM (count-aggregated vs per-token reference).
    let corpus = markov_corpus(&CorpusSpec {
        vocab: 64,
        length: 160_000,
        peakedness: 2.0,
        seed: 47,
    });
    let test = corpus.slice(0, 20_000);
    let train = corpus.slice(20_000, corpus.len());
    let shards = shard_corpus(&train, 8);
    let tokens = shards[0].len() as u64;
    let lm = SoftmaxLmProblem::new(shards, test, 1e-5);
    let s_lm = bench_problem(
        &mut bench,
        &lm,
        SoftmaxLmProblem::local_grad_naive,
        &format!("softmax_lm local_grad tokens={tokens} d={}", lm.dim()),
        tokens,
    );

    println!(
        "speedups (naive / batched): logistic {s_logistic:.2}x  mlp {s_mlp:.2}x  \
         cnn {s_cnn:.2}x  softmax_lm {s_lm:.2}x"
    );
    bench.finish();
}
