//! Figure 2 series benchmark: generates the per-round series (training
//! loss vs cumulative bits; bits per epoch vs epoch) at reduced scale
//! and times the full multi-algorithm sweep — the cost of regenerating
//! one subplot of Figure 2. `repro fig2` produces the full-scale CSVs.

use aquila::algorithms::table_suite;
use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::repro::run_cell;

fn main() {
    let mut bench = Bench::from_env_args();
    let spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false).scaled(0.1, 25);
    bench.bench("fig2 subplot sweep (7 algos × 25 rounds)", || {
        for algo in table_suite(spec.beta) {
            let trace = run_cell(&spec, algo);
            // The two series of the figure:
            let loss_vs_bits: Vec<(u64, f64)> = trace
                .rounds
                .iter()
                .map(|r| (r.cum_bits, r.train_loss))
                .collect();
            let bits_per_epoch: Vec<(usize, u64)> =
                trace.rounds.iter().map(|r| (r.round, r.bits_up)).collect();
            black_box((loss_vs_bits, bits_per_epoch));
        }
    });
    bench.finish();
}
