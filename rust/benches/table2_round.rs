//! Table II end-to-end round benchmark: wall-clock of one full
//! communication round (M devices × full-batch gradient + client step +
//! transport + fold + update) for each homogeneous dataset, per
//! algorithm. This is the latency counterpart of the bit counts the
//! table reports; `repro table2` regenerates the table itself.

use aquila::algorithms::table_suite;
use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::Coordinator;

fn main() {
    let mut bench = Bench::new();
    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.2, 8);
        let problem = spec.build_problem();
        for algo in table_suite(spec.beta) {
            let mut coord = Coordinator::new(problem.as_ref(), algo.as_ref(), spec.run_config());
            // Bootstrap round outside the timed region.
            coord.run_round(0);
            let mut k = 1usize;
            bench.bench(
                &format!("{} round [{}]", spec.row_label(), algo.name()),
                || {
                    black_box(coord.run_round(k));
                    k += 1;
                },
            );
        }
    }
    bench.finish();
}
