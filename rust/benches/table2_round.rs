//! Table II end-to-end round benchmark: wall-clock of one full
//! communication round (M devices × full-batch gradient + client step +
//! transport + fold + update) for each homogeneous dataset, per
//! algorithm. This is the latency counterpart of the bit counts the
//! table reports; `repro table2` regenerates the table itself.

use aquila::algorithms::table_suite;
use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::Session;
use aquila::problems::GradientSource;
use std::sync::Arc;

fn main() {
    let mut bench = Bench::from_env_args();
    for ds in [DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, false).scaled(0.2, 8);
        let problem: Arc<dyn GradientSource> = spec.build_problem().into();
        for algo in table_suite(spec.beta) {
            let mut session = Session::builder(problem.clone(), algo.clone())
                .config(spec.run_config())
                .build();
            // Bootstrap round outside the timed region.
            session.run_round(0);
            let mut k = 1usize;
            // One round touches every device's full-length gradient.
            let elements = (problem.num_devices() * problem.dim()) as u64;
            bench.bench_throughput(
                &format!("{} round [{}]", spec.row_label(), algo.name()),
                elements,
                || {
                    black_box(session.run_round(k));
                    k += 1;
                },
            );
        }
    }
    bench.finish();
}
