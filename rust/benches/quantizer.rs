//! L3 hot-path micro-benchmarks: the fused AQUILA quantization step at
//! the model dimensions the experiments use. This is the per-device
//! per-round inner loop; EXPERIMENTS.md §Perf records its evolution.

use aquila::benchkit::{black_box, Bench};
use aquila::quant::levels::aquila_level;
use aquila::quant::midtread::{dequantize_into, quantize, quantize_innovation_fused};
use aquila::util::rng::Xoshiro256pp;
use aquila::util::vecmath::innovation_norms;

fn random_vec(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
}

fn main() {
    let mut bench = Bench::from_env_args();
    for &d in &[22_016usize, 1_048_576] {
        let g = random_vec(d, 1);
        let q = random_vec(d, 2);
        let mut dq = vec![0.0f32; d];

        bench.bench_throughput(&format!("innovation_norms d={d}"), d as u64, || {
            black_box(innovation_norms(black_box(&g), black_box(&q)));
        });

        let (l2sq, linf) = innovation_norms(&g, &q);
        let bits = aquila_level(l2sq.sqrt(), linf, d);
        bench.bench_throughput(&format!("fused_quantize d={d} b={bits}"), d as u64, || {
            black_box(quantize_innovation_fused(
                black_box(&g),
                black_box(&q),
                bits,
                linf,
                &mut dq,
            ));
        });

        bench.bench_throughput(&format!("full_device_step d={d}"), d as u64, || {
            let (l2sq, linf) = innovation_norms(black_box(&g), black_box(&q));
            let b = aquila_level(l2sq.sqrt(), linf, d);
            black_box(quantize_innovation_fused(&g, &q, b, linf, &mut dq));
        });

        let qv = quantize(&g, 4);
        bench.bench_throughput(&format!("dequantize d={d} b=4"), d as u64, || {
            dequantize_into(black_box(&qv), &mut dq);
            black_box(&dq);
        });
    }
    bench.finish();
}
