//! Wire-format benchmarks: bit-packing/unpacking of ψ codes and full
//! payload encode/decode — the transport cost of every upload.

use aquila::benchkit::{black_box, Bench};
use aquila::quant::midtread::quantize;
use aquila::quant::packing::{pack, unpack, unpack_range};
use aquila::transport::wire::{decode, encode, Payload};
use aquila::util::rng::Xoshiro256pp;

fn main() {
    let mut bench = Bench::from_env_args();
    let d = 1_048_576usize;
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();

    for bits in [1u8, 4, 8, 13] {
        let q = quantize(&v, bits);
        bench.bench_throughput(&format!("pack d=1M b={bits}"), d as u64, || {
            black_box(pack(black_box(&q.psi), bits));
        });
        let packed = pack(&q.psi, bits);
        bench.bench_throughput(&format!("unpack d=1M b={bits}"), d as u64, || {
            black_box(unpack(black_box(&packed), bits, d));
        });
        // O(1)-addressed sub-range decode: one shard's worth of codes
        // from the middle of the stream (what the parallel fold does).
        let (lo, hi) = (d / 4, d / 4 + d / 8);
        bench.bench_throughput(
            &format!("unpack_range d/8 @d/4 b={bits}"),
            (hi - lo) as u64,
            || {
                black_box(unpack_range(black_box(&packed), bits, lo, hi));
            },
        );
    }

    let q4 = quantize(&v, 4);
    let payload = Payload::MidtreadDelta(q4);
    bench.bench_throughput("wire_encode d=1M b=4", d as u64, || {
        black_box(encode(black_box(&payload)));
    });
    let bytes = encode(&payload);
    bench.bench_throughput("wire_decode d=1M b=4", d as u64, || {
        black_box(decode(black_box(&bytes)).unwrap());
    });
    bench.finish();
}
