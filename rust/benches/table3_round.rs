//! Table III end-to-end round benchmark: as `table2_round` but with the
//! heterogeneous 100%–50% capacity split (gather/scatter masking on the
//! round path). `repro table3` regenerates the table itself.

use aquila::algorithms::table_suite;
use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::Coordinator;
use aquila::hetero::half_half_masks;

fn main() {
    let mut bench = Bench::new();
    for ds in [DatasetKind::Cf10, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, true).scaled(0.2, 8);
        let problem = spec.build_problem();
        let masks = half_half_masks(&problem.layout(), problem.num_devices(), 0.5);
        for algo in table_suite(spec.beta) {
            let mut coord = Coordinator::with_masks(
                problem.as_ref(),
                algo.as_ref(),
                masks.clone(),
                spec.run_config(),
            );
            coord.run_round(0);
            let mut k = 1usize;
            bench.bench(
                &format!("{} hetero round [{}]", spec.row_label(), algo.name()),
                || {
                    black_box(coord.run_round(k));
                    k += 1;
                },
            );
        }
    }
    bench.finish();
}
