//! Table III end-to-end round benchmark: as `table2_round` but with the
//! heterogeneous 100%–50% capacity split (gather/scatter masking on the
//! round path). `repro table3` regenerates the table itself.

use aquila::algorithms::table_suite;
use aquila::benchkit::{black_box, Bench};
use aquila::config::{DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::Session;
use aquila::hetero::half_half_masks;
use aquila::problems::GradientSource;
use std::sync::Arc;

fn main() {
    let mut bench = Bench::from_env_args();
    for ds in [DatasetKind::Cf10, DatasetKind::Wt2] {
        let spec = ExperimentSpec::new(ds, SplitKind::Iid, true).scaled(0.2, 8);
        let problem: Arc<dyn GradientSource> = spec.build_problem().into();
        let masks = half_half_masks(&problem.layout(), problem.num_devices(), 0.5);
        for algo in table_suite(spec.beta) {
            let mut session = Session::builder(problem.clone(), algo.clone())
                .config(spec.run_config())
                .masks(masks.clone())
                .build();
            session.run_round(0);
            let mut k = 1usize;
            // One round touches every device's full-length gradient.
            let elements = (problem.num_devices() * problem.dim()) as u64;
            bench.bench_throughput(
                &format!("{} hetero round [{}]", spec.row_label(), algo.name()),
                elements,
                || {
                    black_box(session.run_round(k));
                    k += 1;
                },
            );
        }
    }
    bench.finish();
}
