//! Server-side benchmarks: the zero-copy shard-parallel fold (packed
//! wire bytes → fused dequantize–scatter into `direction`) versus the
//! pre-PR materializing path (decode → `Vec<u32>` ψ → dense f32 scratch
//! → scatter-add), plus the model update — the L3 aggregation path.
//!
//! The headline case is the ISSUE-2 acceptance scenario: d = 1M,
//! M = 32 devices, 4-bit payloads. The bench asserts that the serial
//! and shard-parallel folds produce bit-identical `direction` vectors
//! and prints the measured speedup.

use aquila::algorithms::ServerAgg;
use aquila::benchkit::{black_box, Bench};
use aquila::hetero::CapacityMask;
use aquila::problems::ParamLayout;
use aquila::quant::midtread::{dequantize_into, quantize};
use aquila::transport::wire::{decode, upload_refs, EncodedUpload, Payload};
use aquila::util::pool::default_threads;
use aquila::util::rng::Xoshiro256pp;
use aquila::util::vecmath::{axpy, diff_norm2_sq};
use std::sync::Arc;

fn main() {
    let mut bench = Bench::from_env_args();
    let d = 1_048_576usize;
    let m = 32usize;
    let threads = default_threads().max(4);
    let mut rng = Xoshiro256pp::seed_from_u64(4);

    // One distinct 4-bit innovation payload per device, pre-encoded to
    // wire bytes (what the channel delivers).
    let full = Arc::new(CapacityMask::full(d));
    let masks: Vec<_> = (0..m).map(|_| full.clone()).collect();
    let staged: Vec<EncodedUpload> = (0..m)
        .map(|dev| {
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            EncodedUpload::encode(dev, &Payload::MidtreadDelta(quantize(&v, 4)))
        })
        .collect();
    let uploads = upload_refs(&staged);
    let scale = 1.0 / m as f32;

    // Pre-PR reference: decode to owned payloads, dequantize into a
    // dense scratch, scatter-add — the materializing pipeline this PR
    // removed from the round path.
    let mut dense = vec![0.0f32; d];
    let mut scratch = vec![0.0f32; d];
    bench.bench_throughput(
        &format!("fold_materializing d=1M M={m} b=4 (pre-PR path)"),
        (d * m) as u64,
        || {
            for up in &staged {
                let p = decode(black_box(&up.bytes)).unwrap();
                match &p {
                    Payload::MidtreadDelta(q) => dequantize_into(q, &mut scratch),
                    _ => unreachable!(),
                }
                full.scatter_add(&scratch, scale, &mut dense);
            }
            black_box(&dense);
        },
    );

    // Zero-copy serial fold (threads = 1).
    let mut srv_serial = ServerAgg::new(d, masks.clone());
    srv_serial.set_threads(1);
    let serial_mean = bench
        .bench_throughput(
            &format!("fold_packed_serial d=1M M={m} b=4"),
            (d * m) as u64,
            || {
                srv_serial.accumulate(black_box(&uploads), scale);
                black_box(&srv_serial.direction);
            },
        )
        .mean;

    // Zero-copy shard-parallel fold.
    let mut srv_par = ServerAgg::new(d, masks.clone());
    srv_par.set_threads(threads);
    let par_mean = bench
        .bench_throughput(
            &format!("fold_packed_parallel d=1M M={m} b=4 t={threads}"),
            (d * m) as u64,
            || {
                srv_par.accumulate(black_box(&uploads), scale);
                black_box(&srv_par.direction);
            },
        )
        .mean;

    // Determinism acceptance check: serial and parallel folds from a
    // clean slate must agree bit-for-bit.
    srv_serial.reset();
    srv_par.reset();
    srv_serial.accumulate(&uploads, scale);
    srv_par.accumulate(&uploads, scale);
    let identical = srv_serial
        .direction
        .iter()
        .zip(&srv_par.direction)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "shard-parallel fold diverged from serial fold");
    println!(
        "fold determinism: serial == parallel (bit-identical); speedup {:.2}x on {threads} threads",
        serial_mean.as_secs_f64() / par_mean.as_secs_f64()
    );

    // Masked (hetero) fold: 50% support through mask indices.
    let layout = ParamLayout::contiguous(&[("w", vec![1024, 1024])]);
    let half = Arc::new(CapacityMask::from_layout(&layout, 0.5));
    let hsupport = half.support();
    let mut srv_h = ServerAgg::new(layout.dim(), vec![half.clone()]);
    srv_h.set_threads(threads);
    let vh: Vec<f32> = (0..hsupport).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    let staged_h = vec![EncodedUpload::encode(
        0,
        &Payload::MidtreadDelta(quantize(&vh, 4)),
    )];
    let uploads_h = upload_refs(&staged_h);
    bench.bench_throughput(
        &format!("fold_masked_payload support={hsupport} t={threads}"),
        hsupport as u64,
        || {
            srv_h.accumulate(black_box(&uploads_h), 0.25);
            black_box(&srv_h.direction);
        },
    );

    // θ update + model-diff (once per round).
    let mut theta: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    let prev = theta.clone();
    let dir: Vec<f32> = (0..d).map(|i| (i % 7) as f32 * 1e-4).collect();
    bench.bench_throughput("theta_update+diff d=1M", d as u64, || {
        axpy(-0.1, black_box(&dir), &mut theta);
        black_box(diff_norm2_sq(&theta, &prev));
    });
    bench.finish();
}
