//! Server-side benchmarks: payload folding (dequantize + scatter-add)
//! and the model update — the L3 aggregation path.

use aquila::algorithms::ServerAgg;
use aquila::benchkit::{black_box, Bench};
use aquila::hetero::CapacityMask;
use aquila::problems::ParamLayout;
use aquila::quant::midtread::quantize;
use aquila::transport::wire::Payload;
use aquila::util::rng::Xoshiro256pp;
use aquila::util::vecmath::{axpy, diff_norm2_sq};
use std::sync::Arc;

fn main() {
    let mut bench = Bench::new();
    let d = 1_048_576usize;
    let m = 16usize;
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();

    let full = Arc::new(CapacityMask::full(d));
    let masks: Vec<_> = (0..m).map(|_| full.clone()).collect();
    let mut srv = ServerAgg::new(d, masks);
    let payload = Payload::MidtreadDelta(quantize(&v, 4));

    bench.bench_throughput("fold_one_payload d=1M b=4", d as u64, || {
        srv.add_scaled_payload(0, black_box(&payload), 1.0 / m as f32);
        black_box(&srv.direction);
    });

    // Masked (hetero) fold: 50% support.
    let layout = ParamLayout::contiguous(&[("w", vec![1024, 1024])]);
    let half = Arc::new(CapacityMask::from_layout(&layout, 0.5));
    let hsupport = half.support();
    let mut srv_h = ServerAgg::new(layout.dim(), vec![half.clone()]);
    let vh: Vec<f32> = v[..hsupport].to_vec();
    let payload_h = Payload::MidtreadDelta(quantize(&vh, 4));
    bench.bench_throughput(
        &format!("fold_masked_payload support={hsupport}"),
        hsupport as u64,
        || {
            srv_h.add_scaled_payload(0, black_box(&payload_h), 0.25);
            black_box(&srv_h.direction);
        },
    );

    // θ update + model-diff (once per round).
    let mut theta = v.clone();
    let prev = v.clone();
    let dir: Vec<f32> = (0..d).map(|i| (i % 7) as f32 * 1e-4).collect();
    bench.bench_throughput("theta_update+diff d=1M", d as u64, || {
        axpy(-0.1, black_box(&dir), &mut theta);
        black_box(diff_norm2_sq(&theta, &prev));
    });
    bench.finish();
}
