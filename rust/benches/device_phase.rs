//! Device-phase fusion bench at wide d (DESIGN.md §Perf "device
//! phase"): isolated quantize→pack and the full stats+quantize+pack
//! device phase, three ways each —
//!
//! * `baseline3` — the pre-fusion three-pass pipeline: legacy fused
//!   quantize (materializes `psi: Vec<u32>`) followed by `pack_into`;
//! * `fused` — the serial fused kernel (`quantize_innovation_packed_buf`,
//!   the one the engine's device phase runs per device);
//! * `fused_par` — the always-blocked thread-parallel kernel
//!   (`quantize_innovation_packed_par`), for single wide vectors.
//!
//! Run with `--json ../BENCH_round.json`-style paths to record the
//! trajectory; EXPERIMENTS.md §Wide-model device phase documents the
//! sweep. Under `AQUILA_BENCH_FAST=1` (CI smoke) only the CI-sized d
//! runs, and the bench *asserts* the fusion speedups hold (min
//! timings): fused_par ≥ 1.5× baseline3 on the full device phase and
//! ≥ 2× on isolated quantize→pack — so a fusion regression fails CI
//! instead of silently decaying. The assertions are skipped with a
//! notice when only one hardware thread is available.

use aquila::benchkit::{black_box, Bench};
use aquila::quant::midtread::{
    quantize_innovation_fused_buf, quantize_innovation_packed_buf, quantize_innovation_packed_par,
};
use aquila::quant::packing::{pack_into, packed_len};
use aquila::util::pool::default_threads;
use aquila::util::rng::Xoshiro256pp;
use std::time::Duration;

const BITS: u8 = 4;

/// `‖g − q_prev‖_∞` — the stats pass every device step pays before
/// quantizing (the range the mid-tread quantizer needs).
fn innovation_linf(g: &[f32], q_prev: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for (&a, &b) in g.iter().zip(q_prev) {
        m = m.max((a - b).abs());
    }
    m
}

struct CaseTimings {
    baseline3: Duration,
    fused: Duration,
    fused_par: Duration,
}

/// Bench one width; returns min timings of the three *device-phase*
/// cases plus the two isolated quantize→pack extremes for the CI gate.
fn bench_width(bench: &mut Bench, d: usize, threads: usize) -> (CaseTimings, Duration, Duration) {
    let mut rng = Xoshiro256pp::seed_from_u64(0xDEC1CE ^ d as u64);
    let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
    let q_prev: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 0.5)).collect();
    let mut dq = vec![0.0f32; d];
    let range = innovation_linf(&g, &q_prev);
    let label_d = if d >= 1_000_000 {
        format!("d={}M", d / 1_000_000)
    } else {
        format!("d={}k", d / 1_000)
    };
    // Traffic per call (bytes): quantize reads g+q_prev (8d), writes dq
    // (4d); the baseline additionally writes+rereads psi (8d); packing
    // writes d·b/8 body bytes.
    let body_bytes = packed_len(d, BITS) as u64;
    let quant_bytes = 12 * d as u64;
    let psi_bytes = 8 * d as u64;
    let stats_bytes = 8 * d as u64;

    // ---- isolated quantize→pack -----------------------------------
    let mut psi: Vec<u32> = Vec::new();
    let mut body: Vec<u8> = Vec::new();
    let iso_base = bench
        .bench_gbps(
            &format!("quantize+pack {label_d} b={BITS} baseline3"),
            d as u64,
            quant_bytes + psi_bytes + body_bytes,
            || {
                let out = quantize_innovation_fused_buf(
                    black_box(&g),
                    &q_prev,
                    BITS,
                    range,
                    &mut dq,
                    std::mem::take(&mut psi),
                );
                body.clear();
                pack_into(&out.quantized.psi, BITS, &mut body);
                black_box(&body);
                psi = out.quantized.psi;
            },
        )
        .min;
    let iso_fused = bench
        .bench_gbps(
            &format!("quantize+pack {label_d} b={BITS} fused"),
            d as u64,
            quant_bytes + body_bytes,
            || {
                let out = quantize_innovation_packed_buf(
                    black_box(&g),
                    &q_prev,
                    BITS,
                    range,
                    &mut dq,
                    std::mem::take(&mut body),
                );
                body = black_box(out).packed.body;
            },
        )
        .min;
    let iso_par = bench
        .bench_gbps(
            &format!("quantize+pack {label_d} b={BITS} fused_par t={threads}"),
            d as u64,
            quant_bytes + body_bytes,
            || {
                let out = quantize_innovation_packed_par(
                    black_box(&g),
                    &q_prev,
                    BITS,
                    range,
                    &mut dq,
                    std::mem::take(&mut body),
                    threads,
                );
                body = black_box(out).packed.body;
            },
        )
        .min;
    println!(
        "  isolated speedup: fused {:.2}x  fused_par {:.2}x",
        iso_base.as_secs_f64() / iso_fused.as_secs_f64(),
        iso_base.as_secs_f64() / iso_par.as_secs_f64()
    );

    // ---- full device phase (stats + quantize + pack) ---------------
    let phase_base = bench
        .bench_gbps(
            &format!("device phase {label_d} b={BITS} baseline3"),
            d as u64,
            stats_bytes + quant_bytes + psi_bytes + body_bytes,
            || {
                let r = innovation_linf(black_box(&g), &q_prev);
                let out = quantize_innovation_fused_buf(
                    &g,
                    &q_prev,
                    BITS,
                    r,
                    &mut dq,
                    std::mem::take(&mut psi),
                );
                body.clear();
                pack_into(&out.quantized.psi, BITS, &mut body);
                black_box(&body);
                psi = out.quantized.psi;
            },
        )
        .min;
    let phase_fused = bench
        .bench_gbps(
            &format!("device phase {label_d} b={BITS} fused"),
            d as u64,
            stats_bytes + quant_bytes + body_bytes,
            || {
                let r = innovation_linf(black_box(&g), &q_prev);
                let out = quantize_innovation_packed_buf(
                    &g,
                    &q_prev,
                    BITS,
                    r,
                    &mut dq,
                    std::mem::take(&mut body),
                );
                body = black_box(out).packed.body;
            },
        )
        .min;
    let phase_par = bench
        .bench_gbps(
            &format!("device phase {label_d} b={BITS} fused_par t={threads}"),
            d as u64,
            stats_bytes + quant_bytes + body_bytes,
            || {
                let r = innovation_linf(black_box(&g), &q_prev);
                let out = quantize_innovation_packed_par(
                    &g,
                    &q_prev,
                    BITS,
                    r,
                    &mut dq,
                    std::mem::take(&mut body),
                    threads,
                );
                body = black_box(out).packed.body;
            },
        )
        .min;
    println!(
        "  device-phase speedup: fused {:.2}x  fused_par {:.2}x",
        phase_base.as_secs_f64() / phase_fused.as_secs_f64(),
        phase_base.as_secs_f64() / phase_par.as_secs_f64()
    );
    (
        CaseTimings {
            baseline3: phase_base,
            fused: phase_fused,
            fused_par: phase_par,
        },
        iso_base,
        iso_par,
    )
}

fn main() {
    let mut bench = Bench::from_env_args();
    let fast = std::env::var("AQUILA_BENCH_FAST").is_ok();
    let threads = default_threads();
    // CI-sized width first (the gated one), then the wide-model sweep.
    let widths: &[usize] = if fast {
        &[1_000_000]
    } else {
        &[1_000_000, 10_000_000, 100_000_000]
    };
    let mut gate: Option<(CaseTimings, Duration, Duration)> = None;
    for &d in widths {
        let r = bench_width(&mut bench, d, threads);
        if gate.is_none() {
            gate = Some(r);
        }
    }

    // ---- CI gate: fusion speedups at the CI-sized width ------------
    let (phase, iso_base, iso_par) = gate.expect("at least one width ran");
    // The serial fused kernel must never lose to the three-pass
    // pipeline it replaced (it strictly removes traffic; 10% slack
    // absorbs timer noise on loaded runners).
    assert!(
        phase.fused.as_secs_f64() <= phase.baseline3.as_secs_f64() * 1.1,
        "serial fused device phase regressed: {:?} vs baseline {:?}",
        phase.fused,
        phase.baseline3
    );
    if threads >= 2 {
        let phase_speedup = phase.baseline3.as_secs_f64() / phase.fused_par.as_secs_f64();
        assert!(
            phase_speedup >= 1.5,
            "fused_par device phase speedup {phase_speedup:.2}x < 1.5x over baseline3 \
             (t={threads})"
        );
        let iso_speedup = iso_base.as_secs_f64() / iso_par.as_secs_f64();
        assert!(
            iso_speedup >= 2.0,
            "fused_par quantize+pack speedup {iso_speedup:.2}x < 2x over baseline3 (t={threads})"
        );
    } else {
        println!("single hardware thread: skipping fused_par speedup gates");
    }
    bench.finish();
}
