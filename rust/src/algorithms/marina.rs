//! MARINA baseline (Gorbunov et al., 2021 [26]): compressed gradient
//! *differences* with periodic full synchronization.
//!
//! Every device transmits every round. With probability `p` the round is
//! a synchronization round (the coordinator flips one shared coin,
//! `RoundCtx::marina_sync`) and devices send their raw gradient; the
//! server resets its estimate to the average. Otherwise devices send the
//! quantized difference `Q(g^k − g^{k−1})` and the server updates
//! `g_est ← g_est + avg(Q(·))`.
//!
//! The original uses RandK; we use the paper's deterministic mid-tread
//! quantizer for comparability (same wire format as the lazy family).

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct Marina {
    /// Fixed level for compressed difference rounds.
    pub bits: u8,
    /// Sync probability `p` (coordinator flips the shared coin).
    pub p_sync: f64,
}

impl Marina {
    /// MARINA at `bits` with synchronization probability `p_sync`.
    pub fn new(bits: u8, p_sync: f64) -> Self {
        assert!((1..=32).contains(&bits));
        assert!((0.0..=1.0).contains(&p_sync));
        Self { bits, p_sync }
    }
}

impl Algorithm for Marina {
    fn name(&self) -> &'static str {
        "MARINA"
    }

    fn incremental(&self) -> bool {
        true
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        let sync = ctx.marina_sync || ctx.round == 0;
        dev.uploads += 1;
        if sync {
            dev.q_prev.copy_from_slice(grad);
            let mut raw = std::mem::take(&mut dev.raw);
            raw.clear();
            raw.extend_from_slice(grad);
            return ClientUpload {
                payload: Some(Payload::RawFull(raw)),
                level: None,
            };
        }
        let stats = super::innovation_stats(grad, &dev.q_prev, &dev.sections);
        let (dq, outcome) = super::quantize_innovation_step(dev, grad, self.bits, &stats);
        // MARINA's reference is the *previous local gradient*, not the
        // quantized estimate.
        dev.q_prev.copy_from_slice(grad);
        dev.prev_err_sq = outcome.err_norm_sq;
        dev.scratch = dq;
        ClientUpload {
            payload: Some(Payload::MidtreadDeltaPacked(outcome.packed)),
            level: Some(self.bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], ctx: &RoundCtx) {
        if ctx.marina_sync || ctx.round == 0 {
            super::fold_average(srv, uploads);
        } else if !uploads.is_empty() {
            // g_est += average of compressed differences.
            srv.accumulate(uploads, 1.0 / uploads.len() as f32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::util::rng::Xoshiro256pp;
    use std::sync::Arc;

    use crate::transport::wire::{upload_refs, EncodedUpload};

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn sync_round_sends_raw() {
        let algo = Marina::new(8, 0.1);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(16)), 1);
        let g = grad(16, 2);
        let mut ctx = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        ctx.marina_sync = true;
        let up = algo.client_step(&mut dev, &g, &ctx);
        assert!(matches!(up.payload, Some(Payload::RawFull(_))));
        assert_eq!(dev.q_prev, g);
    }

    #[test]
    fn diff_round_sends_quantized_delta() {
        let algo = Marina::new(8, 0.1);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(16)), 3);
        let g0 = grad(16, 4);
        let mut c0 = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        c0.marina_sync = true;
        algo.client_step(&mut dev, &g0, &c0);
        let g1 = grad(16, 5);
        let mut c1 = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        c1.marina_sync = false;
        let up = algo.client_step(&mut dev, &g1, &c1);
        assert!(matches!(up.payload, Some(Payload::MidtreadDeltaPacked(_))));
        assert_eq!(up.level, Some(8));
        // Reference tracks the raw gradient.
        assert_eq!(dev.q_prev, g1);
    }

    #[test]
    fn server_estimate_tracks_average_gradient() {
        // With exact (32-bit-ish) quantization, g_est after a diff round
        // ≈ avg of current gradients.
        let algo = Marina::new(16, 0.0);
        let full = Arc::new(CapacityMask::full(8));
        let mut d0 = DeviceState::new(0, full.clone(), 6);
        let mut d1 = DeviceState::new(1, full.clone(), 7);
        let mut srv = ServerAgg::new(8, vec![full.clone(), full]);
        let (a0, a1) = (grad(8, 10), grad(8, 11));
        let mut c0 = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        c0.marina_sync = true;
        let ups0 = vec![
            EncodedUpload::encode(0, &algo.client_step(&mut d0, &a0, &c0).payload.unwrap()),
            EncodedUpload::encode(1, &algo.client_step(&mut d1, &a1, &c0).payload.unwrap()),
        ];
        algo.server_fold(&mut srv, &upload_refs(&ups0), &c0);
        let (b0, b1) = (grad(8, 12), grad(8, 13));
        let mut c1 = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        c1.marina_sync = false;
        let ups1 = vec![
            EncodedUpload::encode(0, &algo.client_step(&mut d0, &b0, &c1).payload.unwrap()),
            EncodedUpload::encode(1, &algo.client_step(&mut d1, &b1, &c1).payload.unwrap()),
        ];
        algo.server_fold(&mut srv, &upload_refs(&ups1), &c1);
        for i in 0..8 {
            let want = 0.5 * (b0[i] + b1[i]);
            assert!(
                (srv.direction[i] - want).abs() < 1e-3,
                "{} vs {}",
                srv.direction[i],
                want
            );
        }
    }

    #[test]
    fn never_skips() {
        let algo = Marina::new(4, 0.5);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(8)), 9);
        for k in 0..10 {
            let mut c = RoundCtx::bare(k, 0.1, 0.0, 1.0);
            c.marina_sync = k % 3 == 0;
            assert!(algo
                .client_step(&mut dev, &grad(8, 20 + k as u64), &c)
                .payload
                .is_some());
        }
        assert_eq!(dev.skips, 0);
    }
}
