//! DAdaQuant baseline (Hönig, Zhao & Mullins, 2022 [8]):
//! doubly-adaptive quantization with **random K-device sampling** — the
//! selection strategy whose lack of theoretical grounding motivates
//! AQUILA's precise criterion (paper Sections I–II).
//!
//! * Time adaptation: the shared level doubles when the running-best
//!   global loss stagnates (`quant::levels::DadaquantSchedule`,
//!   maintained by the coordinator, broadcast via
//!   `RoundCtx::dadaquant_level`).
//! * Client adaptation: device `m` quantizes at
//!   `b_m = max(1, round(b_t · w_m^{1/3}))` where `w_m` is its sample
//!   fraction relative to the average (larger shards ⇒ finer
//!   quantization), following the paper's client-adaptive weighting.
//! * Selection: the coordinator samples `K` devices uniformly per round
//!   (`RoundCtx::selected`); unselected devices neither compute nor
//!   transmit.

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct DAdaQuant {
    /// Relative shard weights `w_m` (sample count / mean sample count);
    /// empty = uniform.
    pub weights: Vec<f64>,
    /// Level cap.
    pub cap: u8,
}

impl DAdaQuant {
    /// DAdaQuant with explicit per-device shard weights.
    pub fn new(weights: Vec<f64>, cap: u8) -> Self {
        Self { weights, cap }
    }

    /// DAdaQuant with uniform shard weights.
    pub fn uniform(cap: u8) -> Self {
        Self {
            weights: Vec::new(),
            cap,
        }
    }

    fn client_level(&self, device: usize, time_level: u8) -> u8 {
        let w = self.weights.get(device).copied().unwrap_or(1.0);
        let b = (time_level as f64 * w.cbrt()).round();
        (b.max(1.0) as u64).min(self.cap as u64) as u8
    }
}

impl Algorithm for DAdaQuant {
    fn name(&self) -> &'static str {
        "DAdaQuant"
    }

    fn incremental(&self) -> bool {
        false
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        // Defensive only: the coordinator engine never invokes the
        // client for unselected devices (participation is accounted
        // engine-side, not in `DeviceState::skips`).
        if !ctx.is_selected(dev.id) {
            return ClientUpload::skip();
        }
        let bits = self.client_level(dev.id, ctx.dadaquant_level);
        let q = super::quantize_full_step(dev, grad, bits);
        dev.uploads += 1;
        ClientUpload {
            payload: Some(Payload::MidtreadFullPacked(q)),
            level: Some(bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        // FedAvg over the sampled cohort.
        super::fold_average(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use std::sync::Arc;

    #[test]
    fn unselected_devices_stay_silent() {
        let algo = DAdaQuant::uniform(16);
        let mut dev = DeviceState::new(3, Arc::new(CapacityMask::full(8)), 1);
        let mut ctx = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        ctx.selected = Some(vec![0, 1]);
        let up = algo.client_step(&mut dev, &[1.0; 8], &ctx);
        assert!(up.payload.is_none());
        ctx.selected = Some(vec![0, 3]);
        let up2 = algo.client_step(&mut dev, &[1.0; 8], &ctx);
        assert!(up2.payload.is_some());
    }

    #[test]
    fn client_level_scales_with_weight() {
        let algo = DAdaQuant::new(vec![1.0, 8.0, 0.125], 32);
        assert_eq!(algo.client_level(0, 4), 4);
        assert_eq!(algo.client_level(1, 4), 8); // 8^(1/3) = 2
        assert_eq!(algo.client_level(2, 4), 2); // 0.125^(1/3) = 0.5
        // max(1, ·) clamp (the operation AQUILA's Theorem-1 remark
        // contrasts against).
        assert_eq!(algo.client_level(2, 1), 1);
    }

    #[test]
    fn uses_broadcast_time_level() {
        let algo = DAdaQuant::uniform(32);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(8)), 2);
        let mut ctx = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        ctx.dadaquant_level = 6;
        let up = algo.client_step(&mut dev, &[0.5; 8], &ctx);
        assert_eq!(up.level, Some(6));
    }
}
