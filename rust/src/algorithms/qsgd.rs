//! QSGD baseline: fixed-level stochastic quantization of the full local
//! gradient, transmitted every round (no device selection).

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct QsgdAlgo {
    /// Magnitude bits per element.
    pub bits: u8,
}

impl QsgdAlgo {
    /// QSGD at `bits` magnitude bits per element.
    pub fn new(bits: u8) -> Self {
        assert!((1..=31).contains(&bits));
        Self { bits }
    }
}

impl Algorithm for QsgdAlgo {
    fn name(&self) -> &'static str {
        "QSGD"
    }

    fn incremental(&self) -> bool {
        false
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], _ctx: &RoundCtx) -> ClientUpload {
        let q = super::quantize_qsgd_step(dev, grad, self.bits);
        dev.uploads += 1;
        ClientUpload {
            payload: Some(Payload::QsgdPacked(q)),
            level: Some(self.bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_average(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::util::rng::Xoshiro256pp;
    use std::sync::Arc;

    #[test]
    fn always_uploads_at_fixed_level() {
        let algo = QsgdAlgo::new(4);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(32)), 1);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for k in 0..10 {
            let grad: Vec<f32> = (0..32).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let up = algo.client_step(&mut dev, &grad, &RoundCtx::bare(k, 0.1, 0.25, 1.0));
            assert!(up.payload.is_some());
            assert_eq!(up.level, Some(4));
        }
        assert_eq!(dev.uploads, 10);
        assert_eq!(dev.skips, 0);
    }

    #[test]
    fn dequantized_payload_approximates_gradient() {
        let algo = QsgdAlgo::new(8);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(256)), 3);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let grad: Vec<f32> = (0..256).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let up = algo.client_step(&mut dev, &grad, &RoundCtx::bare(0, 0.1, 0.25, 0.0));
        let mut srv = ServerAgg::new(256, vec![Arc::new(CapacityMask::full(256))]);
        let staged =
            vec![crate::transport::wire::EncodedUpload::encode(0, &up.payload.unwrap())];
        algo.server_fold(
            &mut srv,
            &crate::transport::wire::upload_refs(&staged),
            &RoundCtx::bare(0, 0.1, 0.25, 0.0),
        );
        let err: f64 = grad
            .iter()
            .zip(&srv.direction)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let norm: f64 = grad.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(err / norm < 0.01, "relative err {}", err / norm);
    }
}
