//! AdaQuantFL baseline (Jhunjhunwala et al., 2021 [7]): all devices
//! transmit a quantized gradient **every** round, at the global level
//!
//! ```text
//! b_k = floor( sqrt( f(θ⁰) / f(θᵏ) ) · b₀ )
//! ```
//!
//! — identical for all devices, growing as the loss decays (the
//! pathology the paper's Section II analyzes: levels can exceed 32 bits
//! near convergence, at which point quantization is pointless; we cap at
//! 32 as the paper assumes for floats).

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::quant::levels::adaquantfl_level;
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct AdaQuantFl {
    /// Initial level `b₀`.
    pub b0: u8,
    /// Level cap (32 = float width).
    pub cap: u8,
}

impl AdaQuantFl {
    /// AdaQuantFL starting at level `b0`, capped at `cap`.
    pub fn new(b0: u8, cap: u8) -> Self {
        assert!(b0 >= 1 && cap >= b0);
        Self { b0, cap }
    }

    fn level(&self, ctx: &RoundCtx) -> u8 {
        if ctx.round == 0 {
            self.b0
        } else {
            adaquantfl_level(ctx.init_loss, ctx.prev_loss, self.b0, self.cap)
        }
    }
}

impl Algorithm for AdaQuantFl {
    fn name(&self) -> &'static str {
        "AdaQuantFL"
    }

    fn incremental(&self) -> bool {
        false
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        let bits = self.level(ctx);
        let q = super::quantize_full_step(dev, grad, bits);
        dev.uploads += 1;
        ClientUpload {
            payload: Some(Payload::MidtreadFullPacked(q)),
            level: Some(bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_average(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use std::sync::Arc;

    #[test]
    fn level_grows_as_loss_decays() {
        let algo = AdaQuantFl::new(2, 32);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(8)), 1);
        let grad = vec![1.0f32; 8];
        let mut ctx = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        ctx.init_loss = 2.0;
        ctx.prev_loss = 2.0;
        let b_early = algo.client_step(&mut dev, &grad, &ctx).level.unwrap();
        ctx.prev_loss = 0.02;
        let b_late = algo.client_step(&mut dev, &grad, &ctx).level.unwrap();
        assert_eq!(b_early, 2);
        assert_eq!(b_late, 20);
        ctx.prev_loss = 1e-9;
        let b_cap = algo.client_step(&mut dev, &grad, &ctx).level.unwrap();
        assert_eq!(b_cap, 32, "cap at float width");
    }

    #[test]
    fn round_zero_uses_b0() {
        let algo = AdaQuantFl::new(3, 32);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(4)), 2);
        let up = algo.client_step(&mut dev, &[1.0; 4], &RoundCtx::bare(0, 0.1, 0.0, 0.0));
        assert_eq!(up.level, Some(3));
        assert!(up.payload.is_some());
    }

    #[test]
    fn never_skips() {
        let algo = AdaQuantFl::new(2, 32);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(4)), 3);
        for k in 0..20 {
            let up = algo.client_step(&mut dev, &[0.5; 4], &RoundCtx::bare(k, 0.1, 0.0, 1e9));
            assert!(up.payload.is_some());
        }
        assert_eq!(dev.skips, 0);
    }
}
