//! AQUILA (this paper, Algorithm 1): adaptive quantization level
//! (eq. 19) + precise device-selection skip rule (eq. 8).
//!
//! Per round, each device:
//!
//! 1. computes the gradient innovation `v = ∇f_m(θᵏ) − q_m^{k−1}` and
//!    its norms `(‖v‖₂, R = ‖v‖_∞)`;
//! 2. selects the optimal level
//!    `b* = ceil(log₂(R√d/‖v‖₂ + 1))` (eq. 19);
//! 3. quantizes: `Δq = Q_{b*}(v)` with error `ε = v − Δq`;
//! 4. **skips** the upload iff
//!    `‖Δq‖² + ‖ε‖² ≤ (β/α²)·‖θᵏ − θ^{k−1}‖²` (eq. 8);
//! 5. on upload, updates its stored `q_m ← q_m + Δq`.
//!
//! The server reuses `q_m^{k−1}` for skipping devices — i.e. the
//! incremental fold `q̄ += Δq/M` (Algorithm 1 lines 14–15).
//!
//! Round `k = 0` bootstraps with `q_m^{−1} = 0` and always uploads
//! (Algorithm 1 lines 2–5).

use super::{Algorithm, ClientUpload, DeviceState, InnovationStats, RoundCtx, ServerAgg};
use crate::quant::levels::aquila_level;
use crate::quant::Sections;
use crate::transport::wire::{Payload, UploadRef};

/// See module docs. `β` is carried in [`RoundCtx`] so sweeps (Figure
/// 4/5 ablation) don't need to rebuild the algorithm.
#[derive(Clone, Debug, Default)]
pub struct Aquila {
    /// Optional fixed level override (`None` = adaptive eq. 19; used by
    /// the ablation benches isolating the level rule from the skip
    /// rule).
    pub fixed_level: Option<u8>,
    /// Constructor-time β recorded for display; the effective β comes
    /// from the round context.
    pub beta: f32,
}

impl Aquila {
    /// AQUILA with tuning factor `β` and the adaptive level rule (eq. 19).
    pub fn new(beta: f32) -> Self {
        Self {
            fixed_level: None,
            beta,
        }
    }

    /// Ablation variant: AQUILA's skip rule with a fixed level.
    pub fn with_fixed_level(beta: f32, level: u8) -> Self {
        Self {
            fixed_level: Some(level),
            beta,
        }
    }
}

/// The eq. 19 level rule evaluated per quantization section: each
/// section's innovation norms yield its own optimal
/// `b*_s = ceil(log₂(R_s·√d_s/‖v_s‖₂ + 1))`; the upload uses
/// `max_s b*_s` so every section meets its Lemma-1 accuracy target
/// (the wire carries one `bits` level and one scale per section). With
/// the default global section this is exactly the original rule.
fn sectioned_aquila_level(stats: &InnovationStats, sections: &Sections) -> u8 {
    if stats.per_section.is_empty() {
        // Default global section: the original closed form, no
        // per-section table was materialized.
        return aquila_level(stats.l2sq.sqrt(), stats.linf, sections.total());
    }
    stats
        .per_section
        .iter()
        .enumerate()
        .map(|(i, &(l2sq, linf))| aquila_level(l2sq.sqrt(), linf, sections.range(i).len()))
        .max()
        .unwrap_or(1)
}

impl Algorithm for Aquila {
    fn name(&self) -> &'static str {
        "AQUILA"
    }

    fn incremental(&self) -> bool {
        true
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        debug_assert_eq!(grad.len(), dev.support());
        // Step 1–2: innovation norms (per quantization section) and the
        // optimal level (eq. 19, evaluated per section).
        let stats = super::innovation_stats(grad, &dev.q_prev, &dev.sections);
        let bits = self
            .fixed_level
            .unwrap_or_else(|| sectioned_aquila_level(&stats, &dev.sections));
        // Step 3: fused quantize→pack (Δq into scratch, packed wire
        // bytes into the recycled per-device body buffer, plus both
        // norms — one scale per section).
        let (dq, outcome) = super::quantize_innovation_step(dev, grad, bits, &stats);
        // Step 4: the skip criterion (eq. 8). Round 0 always uploads.
        let threshold = ctx.beta as f64 / (ctx.alpha as f64 * ctx.alpha as f64)
            * ctx.model_diff_sq;
        let skip =
            ctx.round > 0 && outcome.dq_norm_sq + outcome.err_norm_sq <= threshold;
        if skip {
            dev.skips += 1;
            dev.prev_err_sq = outcome.err_norm_sq;
            dev.scratch = dq;
            dev.body = outcome.packed.body;
            return ClientUpload::skip_at_level(bits);
        }
        // Step 5: upload; device stores its new quantized gradient.
        for (q, &delta) in dev.q_prev.iter_mut().zip(dq.iter()) {
            *q += delta;
        }
        dev.uploads += 1;
        dev.prev_err_sq = outcome.err_norm_sq;
        dev.scratch = dq;
        ClientUpload {
            payload: Some(Payload::MidtreadDeltaPacked(outcome.packed)),
            level: Some(bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_incremental(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::quant::levels::aquila_level_upper_bound;
    use crate::quant::midtread::quantize_innovation_fused;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::vecmath::innovation_norms;
    use std::sync::Arc;

    fn device(d: usize) -> DeviceState {
        DeviceState::new(0, Arc::new(CapacityMask::full(d)), 7)
    }

    fn random_grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn round_zero_always_uploads() {
        let algo = Aquila::new(10.0);
        let mut dev = device(64);
        let grad = random_grad(64, 1);
        // Huge β and zero model diff: the rule would skip, but round 0
        // must bootstrap.
        let ctx = RoundCtx::bare(0, 0.1, 10.0, 0.0);
        let up = algo.client_step(&mut dev, &grad, &ctx);
        assert!(up.payload.is_some());
        assert_eq!(dev.uploads, 1);
    }

    #[test]
    fn beta_zero_never_skips() {
        let algo = Aquila::new(0.0);
        let mut dev = device(32);
        for k in 0..5 {
            let grad = random_grad(32, k + 10);
            let ctx = RoundCtx::bare(k as usize, 0.1, 0.0, 100.0);
            let up = algo.client_step(&mut dev, &grad, &ctx);
            assert!(up.payload.is_some(), "round {k} skipped with β=0");
        }
        assert_eq!(dev.uploads, 5);
        assert_eq!(dev.skips, 0);
    }

    #[test]
    fn large_beta_skips_after_bootstrap() {
        let algo = Aquila::new(1e9);
        let mut dev = device(32);
        let grad = random_grad(32, 3);
        let c0 = RoundCtx::bare(0, 0.1, 1e9, 1.0);
        assert!(algo.client_step(&mut dev, &grad, &c0).payload.is_some());
        let c1 = RoundCtx::bare(1, 0.1, 1e9, 1.0);
        let up = algo.client_step(&mut dev, &grad, &c1);
        assert!(up.payload.is_none());
        assert_eq!(dev.skips, 1);
        // Level still reported on skip (for the level-trace figure).
        assert!(up.level.is_some());
    }

    #[test]
    fn skip_rule_matches_eq8_exactly() {
        // Craft a case near the threshold and verify the inequality
        // decides it.
        let algo = Aquila::new(0.5);
        let alpha = 0.2f32;
        for seed in 0..20u64 {
            let mut dev = device(48);
            let g0 = random_grad(48, seed);
            let ctx0 = RoundCtx::bare(0, alpha, 0.5, 0.0);
            algo.client_step(&mut dev, &g0, &ctx0);
            let g1 = random_grad(48, seed + 100);
            // Recompute the LHS the way the client will see it.
            let (l2sq, linf) = innovation_norms(&g1, &dev.q_prev);
            let bits = aquila_level(l2sq.sqrt(), linf, 48);
            let mut dq = vec![0.0f32; 48];
            let o = quantize_innovation_fused(&g1, &dev.q_prev, bits, linf, &mut dq);
            let lhs = o.dq_norm_sq + o.err_norm_sq;
            let model_diff = 0.9 * lhs * (alpha as f64 * alpha as f64) / 0.5;
            let ctx1 = RoundCtx::bare(1, alpha, 0.5, model_diff);
            let up = algo.client_step(&mut dev, &g1, &ctx1);
            // lhs > (β/α²)·0.9·lhs·α²/β = 0.9 lhs ⇒ upload.
            assert!(up.payload.is_some(), "seed {seed} should upload");

            let mut dev2 = device(48);
            algo.client_step(&mut dev2, &g0, &ctx0);
            let model_diff2 = 1.1 * lhs * (alpha as f64 * alpha as f64) / 0.5;
            let ctx2 = RoundCtx::bare(1, alpha, 0.5, model_diff2);
            let up2 = algo.client_step(&mut dev2, &g1, &ctx2);
            assert!(up2.payload.is_none(), "seed {seed} should skip");
        }
    }

    #[test]
    fn q_prev_tracks_uploads_only() {
        let algo = Aquila::new(1e9);
        let mut dev = device(16);
        let g0 = random_grad(16, 5);
        algo.client_step(&mut dev, &g0, &RoundCtx::bare(0, 0.1, 1e9, 0.0));
        let q_after_upload = dev.q_prev.clone();
        // Skipped round must not mutate q_prev.
        let g1 = random_grad(16, 6);
        let up = algo.client_step(&mut dev, &g1, &RoundCtx::bare(1, 0.1, 1e9, 1.0));
        assert!(up.payload.is_none());
        assert_eq!(dev.q_prev, q_after_upload);
    }

    #[test]
    fn adaptive_level_within_theorem1_bound() {
        let algo = Aquila::new(0.0);
        let d = 4096;
        let mut dev = device(d);
        for k in 0..6u64 {
            let grad = random_grad(d, 40 + k);
            let ctx = RoundCtx::bare(k as usize, 0.1, 0.0, 1.0);
            let up = algo.client_step(&mut dev, &grad, &ctx);
            let b = up.level.unwrap();
            assert!(b >= 1 && b <= aquila_level_upper_bound(d), "b={b}");
        }
    }

    #[test]
    fn sectioned_device_uploads_sectioned_payload() {
        let algo = Aquila::new(0.0);
        let d = 64;
        let mask = Arc::new(CapacityMask::full(d));
        let sections = Arc::new(Sections::from_lens([48usize, 16]));
        let mut dev = DeviceState::with_sections(0, mask, sections.clone(), 7);
        // Hot tail section: its range differs from the head's by 100×.
        let mut grad = random_grad(d, 12);
        for x in grad[48..].iter_mut() {
            *x *= 100.0;
        }
        let up = algo.client_step(&mut dev, &grad, &RoundCtx::bare(0, 0.1, 0.0, 0.0));
        match up.payload.unwrap() {
            Payload::MidtreadDeltaPacked(q) => {
                assert!(q.is_sectioned());
                assert_eq!(q.section_scales.len(), 2);
                assert!(q.section_scales[1].0 > 10.0 * q.section_scales[0].0);
            }
            p => panic!("wrong payload {p:?}"),
        }
        // The level is the max of the per-section eq.-19 levels.
        let zeros = vec![0.0f32; d];
        let stats = super::super::innovation_stats(&grad, &zeros, &sections);
        let expect = super::sectioned_aquila_level(&stats, &sections);
        assert_eq!(up.level, Some(expect));
    }

    #[test]
    fn fixed_level_override() {
        let algo = Aquila::with_fixed_level(0.0, 9);
        let mut dev = device(64);
        let grad = random_grad(64, 8);
        let up = algo.client_step(&mut dev, &grad, &RoundCtx::bare(0, 0.1, 0.0, 0.0));
        assert_eq!(up.level, Some(9));
    }
}
