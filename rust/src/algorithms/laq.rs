//! LAQ baseline (Sun et al., 2020 [5]): lazily-aggregated quantization
//! at a **fixed** level.
//!
//! The device skips its upload at round `k` when the quantized
//! innovation is small relative to a Lyapunov-style memory of recent
//! global model movement plus recent quantization errors:
//!
//! ```text
//! ‖Δq_m^k‖² ≤ (1/(α²M²)) Σ_{d'=1}^{D} ξ_{d'} ‖θ^{k+1−d'} − θ^{k−d'}‖²
//!             + 3 ( ‖ε_m^k‖² + ‖ε_m^{k̂}‖² )
//! ```
//!
//! with `ξ_{d'} = ξ/D` and `k̂` the device's last upload round. This is
//! the criterion AQUILA's eq. 8 replaces: it needs `D` stored model
//! differences and a global-gradient surrogate, and its analysis drags a
//! Lyapunov function through every theorem (paper Section III-A and the
//! LAG-comparison remarks after Corollary 1 / Theorem 3).

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct Laq {
    /// Fixed quantization level.
    pub bits: u8,
    /// Total trigger weight `ξ` (split evenly over the `D` memory
    /// slots).
    pub xi: f64,
    /// Memory depth `D`.
    pub memory: usize,
}

impl Laq {
    /// LAQ at fixed `bits` with skip threshold factor `ξ` over `memory` rounds.
    pub fn new(bits: u8, xi: f64, memory: usize) -> Self {
        assert!((1..=32).contains(&bits));
        assert!(memory >= 1);
        Self { bits, xi, memory }
    }

    /// The LAQ threshold RHS for this round.
    pub(crate) fn threshold(&self, dev: &DeviceState, err_now_sq: f64, ctx: &RoundCtx) -> f64 {
        let d_slots = self.memory.min(ctx.model_diff_history.len());
        let mut acc = 0.0;
        for i in 0..d_slots {
            acc += ctx.model_diff_history[i];
        }
        let alpha2 = ctx.alpha as f64 * ctx.alpha as f64;
        let m = ctx.num_devices.max(1) as f64;
        let lyapunov = self.xi / self.memory as f64 * acc / (alpha2 * m * m);
        lyapunov + 3.0 * (err_now_sq + dev.prev_err_sq)
    }
}

impl Algorithm for Laq {
    fn name(&self) -> &'static str {
        "LAQ"
    }

    fn incremental(&self) -> bool {
        true
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        let stats = super::innovation_stats(grad, &dev.q_prev, &dev.sections);
        let (dq, outcome) = super::quantize_innovation_step(dev, grad, self.bits, &stats);
        let skip = ctx.round > 0
            && outcome.dq_norm_sq <= self.threshold(dev, outcome.err_norm_sq, ctx);
        if skip {
            dev.skips += 1;
            dev.scratch = dq;
            dev.body = outcome.packed.body;
            return ClientUpload::skip_at_level(self.bits);
        }
        for (q, &delta) in dev.q_prev.iter_mut().zip(dq.iter()) {
            *q += delta;
        }
        dev.uploads += 1;
        dev.prev_err_sq = outcome.err_norm_sq;
        dev.scratch = dq;
        ClientUpload {
            payload: Some(Payload::MidtreadDeltaPacked(outcome.packed)),
            level: Some(self.bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_incremental(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::util::rng::Xoshiro256pp;
    use std::sync::Arc;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    fn ctx_with_m(round: usize, m: usize, diff: f64) -> RoundCtx {
        let mut c = RoundCtx::bare(round, 0.1, 0.0, diff);
        c.num_devices = m;
        c
    }

    #[test]
    fn round_zero_uploads() {
        let algo = Laq::new(8, 1e12, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(32)), 1);
        let up = algo.client_step(&mut dev, &grad(32, 1), &ctx_with_m(0, 4, 0.0));
        assert!(up.payload.is_some());
    }

    #[test]
    fn identical_gradient_skips() {
        // If the gradient hasn't changed since the last upload, the
        // innovation is just the old quantization error — tiny — and the
        // error terms (3·(ε_now + ε_prev)) dominate, so LAQ skips.
        let algo = Laq::new(8, 1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(64)), 2);
        let g = grad(64, 3);
        algo.client_step(&mut dev, &g, &ctx_with_m(0, 4, 0.0));
        let up = algo.client_step(&mut dev, &g, &ctx_with_m(1, 4, 1e-12));
        assert!(up.payload.is_none(), "unchanged gradient should skip");
        assert_eq!(dev.skips, 1);
    }

    #[test]
    fn changed_gradient_uploads() {
        let algo = Laq::new(8, 1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(64)), 4);
        algo.client_step(&mut dev, &grad(64, 5), &ctx_with_m(0, 100, 0.0));
        // A very different gradient ⇒ big innovation ⇒ upload.
        let g2: Vec<f32> = grad(64, 99).iter().map(|x| x * 10.0).collect();
        let up = algo.client_step(&mut dev, &g2, &ctx_with_m(1, 100, 1e-9));
        assert!(up.payload.is_some());
    }

    #[test]
    fn memory_depth_limits_history_use() {
        let algo = Laq::new(4, 10.0, 2);
        let dev = DeviceState::new(0, Arc::new(CapacityMask::full(8)), 6);
        let mut ctx = ctx_with_m(5, 2, 1.0);
        ctx.model_diff_history = vec![1.0, 1.0, 1000.0, 1000.0]; // old spikes ignored
        let thr = algo.threshold(&dev, 0.0, &ctx);
        // Only the first `memory = 2` slots count: (ξ/D)·(1+1)/(α²M²).
        let expect = 10.0 / 2.0 * 2.0 / (0.01 * 4.0);
        // α is f32 in the context, so compare with relative tolerance.
        assert!((thr - expect).abs() / expect < 1e-6, "{thr} vs {expect}");
    }

    #[test]
    fn skip_does_not_mutate_q_prev() {
        let algo = Laq::new(8, 1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(16)), 7);
        let g = grad(16, 8);
        algo.client_step(&mut dev, &g, &ctx_with_m(0, 4, 0.0));
        let snapshot = dev.q_prev.clone();
        let up = algo.client_step(&mut dev, &g, &ctx_with_m(1, 4, 0.0));
        assert!(up.payload.is_none());
        assert_eq!(dev.q_prev, snapshot);
    }
}
