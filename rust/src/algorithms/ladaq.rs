//! LAdaQ: the naive combination of AdaQuantFL's level rule with LAQ's
//! lazy-aggregation skip rule — the strawman the paper's Section II
//! dissects ("a naive approach is to quantize lazily aggregated
//! gradients with AdaQuantFL ... it fails to achieve efficient
//! communication").
//!
//! Both pathologies the paper predicts are reproduced by the benches:
//! the level keeps growing as the loss decays (driving per-upload bits
//! up), and the shrinking quantization error lowers the LAQ threshold,
//! raising upload frequency.

use super::laq::Laq;
use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::quant::levels::adaquantfl_level;
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug)]
pub struct LAdaQ {
    /// AdaQuantFL initial level `b₀` and cap.
    pub b0: u8,
    /// AdaQuantFL level cap.
    pub cap: u8,
    /// Inner LAQ (provides the skip threshold).
    laq: Laq,
}

impl LAdaQ {
    /// LAdaQ from AdaQuantFL level parameters and LAQ skip parameters.
    pub fn new(b0: u8, cap: u8, xi: f64, memory: usize) -> Self {
        Self {
            b0,
            cap,
            laq: Laq::new(8, xi, memory),
        }
    }

    fn level(&self, ctx: &RoundCtx) -> u8 {
        if ctx.round == 0 {
            self.b0
        } else {
            adaquantfl_level(ctx.init_loss, ctx.prev_loss, self.b0, self.cap)
        }
    }
}

impl Algorithm for LAdaQ {
    fn name(&self) -> &'static str {
        "LAdaQ"
    }

    fn incremental(&self) -> bool {
        true
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        let bits = self.level(ctx);
        let stats = super::innovation_stats(grad, &dev.q_prev, &dev.sections);
        let (dq, outcome) = super::quantize_innovation_step(dev, grad, bits, &stats);
        let skip = ctx.round > 0
            && outcome.dq_norm_sq <= self.laq.threshold(dev, outcome.err_norm_sq, ctx);
        if skip {
            dev.skips += 1;
            dev.scratch = dq;
            dev.body = outcome.packed.body;
            return ClientUpload::skip_at_level(bits);
        }
        for (q, &delta) in dev.q_prev.iter_mut().zip(dq.iter()) {
            *q += delta;
        }
        dev.uploads += 1;
        dev.prev_err_sq = outcome.err_norm_sq;
        dev.scratch = dq;
        ClientUpload {
            payload: Some(Payload::MidtreadDeltaPacked(outcome.packed)),
            level: Some(bits),
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_incremental(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::util::rng::Xoshiro256pp;
    use std::sync::Arc;

    #[test]
    fn level_follows_adaquantfl_rule() {
        let algo = LAdaQ::new(2, 32, 1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(16)), 1);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let grad: Vec<f32> = (0..16).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut ctx = RoundCtx::bare(1, 0.1, 0.0, 1.0);
        ctx.num_devices = 4;
        ctx.init_loss = 4.0;
        ctx.prev_loss = 0.04; // sqrt(100)·2 = 20
        let up = algo.client_step(&mut dev, &grad, &ctx);
        assert_eq!(up.level, Some(20));
    }

    #[test]
    fn skips_like_laq() {
        let algo = LAdaQ::new(2, 32, 1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(32)), 3);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let grad: Vec<f32> = (0..32).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut c0 = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        c0.num_devices = 4;
        algo.client_step(&mut dev, &grad, &c0);
        let mut c1 = RoundCtx::bare(1, 0.1, 0.0, 1e-12);
        c1.num_devices = 4;
        let up = algo.client_step(&mut dev, &grad, &c1);
        assert!(up.payload.is_none());
    }
}
