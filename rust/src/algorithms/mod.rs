//! The algorithm suite: AQUILA plus every baseline in Tables II/III.
//!
//! | Column in the tables | Implementation |
//! |---|---|
//! | QSGD   | [`qsgd::QsgdAlgo`] — fixed-level stochastic quantization, transmit every round |
//! | AdaQ   | [`adaquantfl::AdaQuantFl`] — AdaQuantFL global-loss level rule, transmit every round |
//! | LAQ    | [`laq::Laq`] — fixed-level lazily-aggregated quantization |
//! | LAdaQ  | [`ladaq::LAdaQ`] — the naive AdaQuantFL + LAQ combination |
//! | LENA   | [`lena::Lena`] — self-triggered raw-gradient uploads |
//! | MARINA | [`marina::Marina`] — periodic sync + compressed gradient differences |
//! | AQUILA | [`aquila::Aquila`] — this paper (eq. 8 skip rule + eq. 19 level rule) |
//!
//! Additional: [`fedavg::FedAvg`] (uncompressed reference) and
//! [`dadaquant::DAdaQuant`] (random-K doubly-adaptive baseline, paper
//! Section II).
//!
//! ## Split of responsibilities
//!
//! An [`Algorithm`] has a *client half* — given the device's local
//! gradient (in the device's HeteroFL-gathered coordinate space), update
//! device state and decide what to upload — and a *server half* — fold
//! the round's decoded payloads into the server's step direction. The
//! coordinator (`crate::coordinator`) owns everything else: gradient
//! computation, masking, the wire round-trip and byte accounting, the
//! model update `θ^{k+1} = θ^k − α·direction`, and metrics.

pub mod adaquantfl;
pub mod aquila;
pub mod dadaquant;
pub mod fedavg;
pub mod ladaq;
pub mod laq;
pub mod lena;
pub mod marina;
pub mod qsgd;

use crate::hetero::{CapacityMask, MaskTable};
use crate::quant::midtread::{
    quantize_innovation_packed_buf, quantize_innovation_packed_sections_buf, quantize_packed_buf,
    quantize_sections_packed_buf, PackedOutcome,
};
use crate::quant::{PackedVec, Sections};
use crate::transport::wire::{self, Payload, PayloadView, UploadRef};
use crate::util::pool::parallel_for_shards;
use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::innovation_norms;
use std::sync::Arc;

/// Everything the server broadcasts that clients may consult. The paper
/// stresses (Section III-A) that AQUILA's criterion only needs the two
/// adjacent global models — i.e. `model_diff_sq` — while LAQ-style rules
/// need a `D`-deep history, reproduced here as `model_diff_history`.
#[derive(Clone, Debug)]
pub struct RoundCtx {
    /// Communication round `k` (0-based; round 0 is the bootstrap round
    /// of Algorithm 1 where every device uploads).
    pub round: usize,
    /// Total device count `M` (the LAQ/LENA thresholds divide by `M²`).
    pub num_devices: usize,
    /// Server learning rate `α`.
    pub alpha: f32,
    /// AQUILA tuning factor `β ≥ 0` (eq. 8).
    pub beta: f32,
    /// `‖θᵏ − θ^{k−1}‖₂²` — the exact model difference AQUILA uses.
    pub model_diff_sq: f64,
    /// Last `D` squared model differences, most recent first (LAQ/LENA).
    pub model_diff_history: Vec<f64>,
    /// `f(θ⁰)` estimate (AdaQuantFL numerator).
    pub init_loss: f64,
    /// `f(θ^{k−1})` estimate — average of last round's local losses.
    pub prev_loss: f64,
    /// Whether this is a MARINA synchronization round (coordinator flips
    /// a shared coin with probability `p_sync`).
    pub marina_sync: bool,
    /// Devices selected this round (`None` = all devices participate),
    /// decided by the run's `crate::selection::SelectionStrategy`.
    /// Invariant: sorted ascending and deduplicated (the coordinator
    /// engine normalizes strategy output), so membership tests are
    /// `O(log K)` — `is_selected` is called once per device per round.
    pub selected: Option<Vec<usize>>,
    /// DAdaQuant time-adaptive level (maintained server-side).
    pub dadaquant_level: u8,
}

impl RoundCtx {
    /// A minimal context for tests.
    pub fn bare(round: usize, alpha: f32, beta: f32, model_diff_sq: f64) -> Self {
        Self {
            round,
            num_devices: 1,
            alpha,
            beta,
            model_diff_sq,
            model_diff_history: vec![model_diff_sq],
            init_loss: 1.0,
            prev_loss: 1.0,
            marina_sync: round == 0,
            selected: None,
            dadaquant_level: 4,
        }
    }

    /// Is `device` participating this round? Binary search over the
    /// sorted selection set (see the `selected` field invariant).
    pub fn is_selected(&self, device: usize) -> bool {
        match &self.selected {
            None => true,
            Some(s) => s.binary_search(&device).is_ok(),
        }
    }
}

/// Per-device persistent state. Vectors live in the device's *gathered*
/// (mask-support) coordinate space of size `mask.support()`.
#[derive(Clone, Debug)]
pub struct DeviceState {
    /// Device index `m`.
    pub id: usize,
    /// The algorithm's reference vector: the stored quantized gradient
    /// `q_m^{k−1}` (mid-tread lazy family), the last *uploaded* gradient
    /// (LENA), or the previous local gradient (MARINA).
    pub q_prev: Vec<f32>,
    /// `‖ε_m‖²` of the last upload (LAQ's threshold term).
    pub prev_err_sq: f64,
    /// Scratch for dequantized innovations (avoids per-round allocation).
    pub scratch: Vec<f32>,
    /// Recycled packed wire-body buffer: the fused quantize→pack client
    /// steps take it (`std::mem::take`), hand it to the `_packed_buf`
    /// kernels, and the coordinator returns it via
    /// [`DeviceState::recycle`] after the payload is serialized — so
    /// steady-state rounds allocate nothing.
    pub body: Vec<u8>,
    /// Recycled ψ/magnitude code buffer for the unpacked payload forms
    /// (tests and legacy callers; the fused client steps use `body`).
    pub psi: Vec<u32>,
    /// Recycled QSGD sign buffer (see `psi`).
    pub signs: Vec<bool>,
    /// Recycled raw-f32 payload buffer (LENA/FedAvg/MARINA-sync; see
    /// `psi`).
    pub raw: Vec<f32>,
    /// Device-local RNG stream (stochastic quantizers).
    pub rng: Xoshiro256pp,
    /// Rounds in which this device uploaded a payload.
    pub uploads: u64,
    /// Rounds in which this device participated but skipped.
    pub skips: u64,
    /// HeteroFL capacity mask.
    pub mask: Arc<CapacityMask>,
    /// Quantization sections over the gathered vector, resolved by the
    /// engine from the problem's `ParamLayout`, the run's
    /// `quant_sections` spec, and this device's mask
    /// (`crate::quant::sections`). The default is the single global
    /// section — the pre-sectioning behavior.
    pub sections: Arc<Sections>,
}

impl DeviceState {
    /// Fresh device state (zero reference vector, device-keyed RNG
    /// stream, single global quantization section).
    pub fn new(id: usize, mask: Arc<CapacityMask>, seed: u64) -> Self {
        let sections = Arc::new(Sections::global(mask.support()));
        Self::with_sections(id, mask, sections, seed)
    }

    /// [`DeviceState::new`] with explicit quantization sections (must
    /// cover the mask's support).
    pub fn with_sections(
        id: usize,
        mask: Arc<CapacityMask>,
        sections: Arc<Sections>,
        seed: u64,
    ) -> Self {
        let support = mask.support();
        assert_eq!(sections.total(), support, "sections must cover the support");
        Self {
            id,
            q_prev: vec![0.0; support],
            prev_err_sq: 0.0,
            scratch: vec![0.0; support],
            body: Vec::new(),
            psi: Vec::new(),
            signs: Vec::new(),
            raw: Vec::new(),
            rng: Self::rng_stream(id, seed),
            uploads: 0,
            skips: 0,
            mask,
            sections,
        }
    }

    /// The id-keyed RNG stream a fresh device starts from. Exposed so
    /// checkpoint restore of RNG-less (v1) snapshots and the population
    /// spec agree on the derivation without duplicating the key.
    pub fn rng_stream(id: usize, seed: u64) -> Xoshiro256pp {
        Xoshiro256pp::stream(seed, 0xDE_u64 << 32 | id as u64)
    }

    /// Gathered dimension.
    pub fn support(&self) -> usize {
        self.mask.support()
    }

    /// Reclaim the code/sign/raw buffers of a payload this device just
    /// staged (after serialization), so the next round's client step
    /// reuses their capacity instead of allocating.
    pub fn recycle(&mut self, payload: Payload) {
        match payload {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
                self.psi = q.psi;
            }
            Payload::Qsgd(q) => {
                self.psi = q.mags;
                self.signs = q.signs;
            }
            Payload::RawDelta(v) | Payload::RawFull(v) => {
                self.raw = v;
            }
            Payload::MidtreadDeltaPacked(p)
            | Payload::MidtreadFullPacked(p)
            | Payload::QsgdPacked(p) => {
                self.body = p.body;
            }
        }
    }
}

/// What the client half returns.
#[derive(Clone, Debug)]
pub struct ClientUpload {
    /// `None` = the device skips this round (zero uplink bytes).
    pub payload: Option<Payload>,
    /// Quantization level used/computed this round (metrics; present
    /// even on skip rounds for the level-trace figures).
    pub level: Option<u8>,
}

impl ClientUpload {
    /// Skip this round without reporting a level.
    pub fn skip() -> Self {
        Self {
            payload: None,
            level: None,
        }
    }

    /// Skip this round but report the level the device computed.
    pub fn skip_at_level(level: u8) -> Self {
        Self {
            payload: None,
            level: Some(level),
        }
    }
}

/// Innovation norms of a device's round, computed once and shared by
/// the level rule, the skip rule, and the sectioned quantizer.
#[derive(Clone, Debug)]
pub struct InnovationStats {
    /// Global `‖v‖₂²` of the innovation `v = g − q_prev`.
    pub l2sq: f64,
    /// Global `‖v‖_∞`.
    pub linf: f32,
    /// Per-section `(‖v_s‖₂², ‖v_s‖_∞)`, one entry per quantization
    /// section. **Empty** when the device runs the default single
    /// global section (the globals above are that section's norms) —
    /// the default device phase stays allocation-free (§Perf).
    pub per_section: Vec<(f64, f32)>,
}

/// Compute [`InnovationStats`] for `v = g − q_prev` over `sections`.
/// The global (single-section) path is the exact
/// `util::vecmath::innovation_norms` pass the pre-sectioning client
/// steps ran — and allocates nothing — so global-mode traces stay
/// bit-identical and the zero-alloc steady state is preserved.
pub fn innovation_stats(g: &[f32], q_prev: &[f32], sections: &Sections) -> InnovationStats {
    if sections.is_global() {
        let (l2sq, linf) = innovation_norms(g, q_prev);
        return InnovationStats {
            l2sq,
            linf,
            per_section: Vec::new(),
        };
    }
    let mut per_section = Vec::with_capacity(sections.count());
    let mut l2sq = 0.0f64;
    let mut linf = 0.0f32;
    for r in sections.iter() {
        let (s_l2sq, s_linf) = innovation_norms(&g[r.clone()], &q_prev[r.clone()]);
        l2sq += s_l2sq;
        linf = linf.max(s_linf);
        per_section.push((s_l2sq, s_linf));
    }
    InnovationStats {
        l2sq,
        linf,
        per_section,
    }
}

/// Shared client-step core of the mid-tread innovation family (AQUILA,
/// LAQ, LAdaQ, MARINA): fused-quantize the innovation `g − q_prev` at
/// `bits` into the device's recycled `scratch`/`body` buffers, one scale
/// per quantization section, emitting the packed wire body directly
/// (§Perf — the codes `Vec<u32>` never exists). Returns the
/// reconstructed `Δq` (the taken scratch buffer — hand it back to
/// `dev.scratch` when done) and the packed outcome whose norms feed the
/// skip rules; the arithmetic and norms are bit-identical to the
/// pre-fusion unpacked path.
pub(crate) fn quantize_innovation_step(
    dev: &mut DeviceState,
    grad: &[f32],
    bits: u8,
    stats: &InnovationStats,
) -> (Vec<f32>, PackedOutcome) {
    let d = grad.len();
    let mut dq = std::mem::take(&mut dev.scratch);
    dq.resize(d, 0.0);
    let body = std::mem::take(&mut dev.body);
    let outcome = if dev.sections.is_global() {
        quantize_innovation_packed_buf(grad, &dev.q_prev, bits, stats.linf, &mut dq, body)
    } else {
        let sections = dev.sections.clone();
        let ranges: Vec<f32> = stats.per_section.iter().map(|&(_, li)| li).collect();
        quantize_innovation_packed_sections_buf(
            grad,
            &dev.q_prev,
            bits,
            &ranges,
            &sections,
            &mut dq,
            body,
        )
    };
    (dq, outcome)
}

/// Shared client-step core of the full-gradient mid-tread family
/// (AdaQuantFL, DAdaQuant): fused-quantize `grad` at `bits` into the
/// device's recycled `body` buffer, one scale per quantization section.
pub(crate) fn quantize_full_step(dev: &mut DeviceState, grad: &[f32], bits: u8) -> PackedVec {
    let body = std::mem::take(&mut dev.body);
    if dev.sections.is_global() {
        quantize_packed_buf(grad, bits, body)
    } else {
        let sections = dev.sections.clone();
        quantize_sections_packed_buf(grad, bits, &sections, body)
    }
}

/// Shared client-step core of the QSGD baseline: fused stochastic
/// quantize→pack of `grad` at `bits` into the device's recycled `body`
/// buffer, drawing from the device RNG stream in the exact order of the
/// unpacked path (so seeded traces are unchanged).
pub(crate) fn quantize_qsgd_step(dev: &mut DeviceState, grad: &[f32], bits: u8) -> PackedVec {
    let body = std::mem::take(&mut dev.body);
    if dev.sections.is_global() {
        crate::quant::qsgd::quantize_packed_buf(grad, bits, &mut dev.rng, body)
    } else {
        let sections = dev.sections.clone();
        crate::quant::qsgd::quantize_sections_packed_buf(grad, bits, &sections, &mut dev.rng, body)
    }
}

/// Minimum direction elements per fold shard: below this the
/// scatter-add is cheaper than a thread spawn, so the fold stays
/// serial (tests and tiny problems never pay scope overhead).
const FOLD_SHARD_MIN: usize = 8192;

/// Server-side aggregation state shared by all algorithms.
pub struct ServerAgg {
    /// The step direction: `θ^{k+1} = θᵏ − α · direction`. For the lazy
    /// family this is the running `q̄ = (1/M) Σ_m q_m` of Algorithm 1
    /// line 14–15 and persists across rounds; reset-style algorithms
    /// clear it each round.
    pub direction: Vec<f32>,
    /// Per-device capacity masks (scatter targets). A [`MaskTable`]
    /// rather than a dense vector so million-device populations sharing
    /// a couple of distinct masks cost O(distinct), not O(M).
    pub masks: MaskTable,
    /// Total device count `M`.
    pub m: usize,
    /// Worker threads for the shard-parallel fold (1 = serial).
    threads: usize,
    /// Positional per-upload weights staged for the next
    /// [`ServerAgg::accumulate`] call (buffered-async staleness
    /// weighting); consumed — and cleared — by that call.
    upload_weights: Vec<f32>,
}

impl ServerAgg {
    /// Aggregator over `full_dim` coordinates with a dense per-device
    /// mask vector (convenience wrapper over
    /// [`ServerAgg::with_table`]).
    pub fn new(full_dim: usize, masks: Vec<Arc<CapacityMask>>) -> Self {
        Self::with_table(full_dim, MaskTable::from(masks))
    }

    /// Aggregator over `full_dim` coordinates with a compact mask
    /// table.
    pub fn with_table(full_dim: usize, masks: MaskTable) -> Self {
        let m = masks.num_devices();
        Self {
            direction: vec![0.0; full_dim],
            masks,
            m,
            threads: 1,
            upload_weights: Vec::new(),
        }
    }

    /// Set the fold thread count (the coordinator engine passes its
    /// worker count; defaults to 1 = serial).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Clear the direction (reset-style algorithms).
    pub fn reset(&mut self) {
        self.direction.fill(0.0);
    }

    /// Stage positional per-upload weights for the *next*
    /// [`ServerAgg::accumulate`] call: upload `i`'s effective scale
    /// becomes `scale · weights[i]`. The buffered-async engine uses
    /// this to apply staleness decay through every algorithm's
    /// existing fold rule (each of which makes exactly one
    /// `accumulate` call per fold) without the `Algorithm` trait
    /// growing a weighted variant. The staged vector is consumed by
    /// the next call — weighted or not, it never leaks into a later
    /// fold.
    pub fn stage_upload_weights(&mut self, weights: Vec<f32>) {
        self.upload_weights = weights;
    }

    /// The shared fold core every algorithm routes through (§Perf):
    /// `direction += scale · Σ decode(p)` computed zero-copy — each
    /// upload's packed wire body is dequantize–scatter-added into
    /// `direction` shard-by-shard across `threads` workers, with no ψ
    /// or dense-scratch materialization.
    ///
    /// Determinism: shards partition the *output*; within a shard,
    /// uploads are applied in slice order, so every direction element
    /// accumulates contributions in exactly the serial fold's order —
    /// results are bit-identical for any thread count (property-tested
    /// in `rust/tests/prop_fold.rs`).
    pub fn accumulate(&mut self, uploads: &[UploadRef<'_>], scale: f32) {
        // Staged weights apply to exactly this call, even if it folds
        // nothing.
        let weights = std::mem::take(&mut self.upload_weights);
        if uploads.is_empty() {
            return;
        }
        assert!(
            weights.is_empty() || weights.len() == uploads.len(),
            "staged {} upload weights for {} uploads",
            weights.len(),
            uploads.len()
        );
        // Parse headers and resolve masks once, not once per shard.
        // With no weights staged each upload's scale is the caller's
        // `scale` verbatim, so the unweighted path stays bit-identical
        // to the pre-weighting fold.
        let dim = self.direction.len();
        let staged: Vec<(PayloadView<'_>, &CapacityMask, f32)> = uploads
            .iter()
            .enumerate()
            .map(|(i, up)| {
                let view = up.view();
                let mask = self.masks.get(up.device).as_ref();
                assert_eq!(
                    view.len,
                    mask.support(),
                    "payload length {} != device {} support {}",
                    view.len,
                    up.device,
                    mask.support()
                );
                // The shard scatter clamps to the output range, so a
                // dim mismatch must fail loudly here rather than drop
                // contributions silently.
                assert_eq!(
                    mask.full_dim, dim,
                    "device {} mask dim {} != direction dim {dim}",
                    up.device, mask.full_dim
                );
                let w = weights.get(i).map_or(scale, |w| scale * w);
                (view, mask, w)
            })
            .collect();
        parallel_for_shards(
            &mut self.direction,
            self.threads,
            FOLD_SHARD_MIN,
            |base, shard| {
                for (view, mask, w) in &staged {
                    view.scatter_add_shard(mask, *w, base, shard);
                }
            },
        );
    }

    /// Decode `payload` to its contribution and scatter-add `scale ×`
    /// it into the direction through the device's mask — single-payload
    /// convenience over [`ServerAgg::accumulate`] (tests, examples).
    pub fn add_scaled_payload(&mut self, device: usize, payload: &Payload, scale: f32) {
        let bytes = wire::encode(payload);
        self.accumulate(&[UploadRef { device, bytes: &bytes }], scale);
    }
}

/// A communication-efficient FL algorithm: client decision rule +
/// server fold rule. See module docs.
pub trait Algorithm: Send + Sync {
    /// Name as it appears in the paper's tables.
    fn name(&self) -> &'static str;

    /// Whether the server direction persists across rounds (lazy
    /// aggregation family) or is recomputed from scratch each round.
    fn incremental(&self) -> bool;

    /// Client half. `grad` is the device's local gradient in gathered
    /// space (`dev.support()` long).
    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload;

    /// Server half: fold the round's delivered uploads (still in wire
    /// form — fold zero-copy via [`ServerAgg::accumulate`]) into
    /// `srv.direction`.
    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], ctx: &RoundCtx);
}

/// Standard reset-style fold: `direction = (1/|uploads|) Σ decode(p)`.
pub(crate) fn fold_average(srv: &mut ServerAgg, uploads: &[UploadRef<'_>]) {
    srv.reset();
    if uploads.is_empty() {
        return;
    }
    srv.accumulate(uploads, 1.0 / uploads.len() as f32);
}

/// Standard lazy fold: `q̄ += (1/M) Σ decode(Δq)`.
pub(crate) fn fold_incremental(srv: &mut ServerAgg, uploads: &[UploadRef<'_>]) {
    srv.accumulate(uploads, 1.0 / srv.m as f32);
}

/// Construct every algorithm of Tables II/III with the hyperparameters
/// used by the reproduction presets, `Arc`-owned for direct use with
/// `crate::coordinator::SessionBuilder`.
pub fn table_suite(beta: f32) -> Vec<Arc<dyn Algorithm>> {
    vec![
        Arc::new(qsgd::QsgdAlgo::new(8)),
        Arc::new(adaquantfl::AdaQuantFl::new(4, 32)),
        Arc::new(laq::Laq::new(8, 0.8, 10)),
        Arc::new(ladaq::LAdaQ::new(4, 32, 0.8, 10)),
        Arc::new(lena::Lena::new(0.8, 10)),
        Arc::new(marina::Marina::new(8, 0.1)),
        Arc::new(aquila::Aquila::new(beta)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;

    #[test]
    fn server_agg_scatter_respects_masks() {
        use crate::problems::ParamLayout;
        let layout = ParamLayout::contiguous(&[("w", vec![4, 4])]);
        let full = Arc::new(CapacityMask::full(16));
        let half = Arc::new(CapacityMask::from_layout(&layout, 0.5));
        let mut srv = ServerAgg::new(16, vec![full, half.clone()]);
        // Device 1 (masked) sends a 4-element payload.
        let p = Payload::RawFull(vec![1.0; half.support()]);
        srv.add_scaled_payload(1, &p, 2.0);
        let on: f32 = srv.direction.iter().sum();
        assert_eq!(on, 2.0 * half.support() as f32);
        for (i, &x) in srv.direction.iter().enumerate() {
            let in_mask = half.indices.contains(&(i as u32));
            assert_eq!(x != 0.0, in_mask, "index {i}");
        }
    }

    #[test]
    #[should_panic]
    fn server_agg_rejects_wrong_length() {
        let full = Arc::new(CapacityMask::full(8));
        let mut srv = ServerAgg::new(8, vec![full]);
        let p = Payload::MidtreadFull(quantize(&[1.0, 2.0], 4));
        srv.add_scaled_payload(0, &p, 1.0);
    }

    #[test]
    fn fold_average_of_two() {
        use crate::transport::wire::{upload_refs, EncodedUpload};
        let full = Arc::new(CapacityMask::full(2));
        let mut srv = ServerAgg::new(2, vec![full.clone(), full]);
        let staged = vec![
            EncodedUpload::encode(0, &Payload::RawFull(vec![2.0, 0.0])),
            EncodedUpload::encode(1, &Payload::RawFull(vec![0.0, 4.0])),
        ];
        fold_average(&mut srv, &upload_refs(&staged));
        assert_eq!(srv.direction, vec![1.0, 2.0]);
        // Re-fold resets rather than accumulates.
        fold_average(&mut srv, &upload_refs(&staged));
        assert_eq!(srv.direction, vec![1.0, 2.0]);
    }

    #[test]
    fn fold_incremental_accumulates_over_m() {
        use crate::transport::wire::{upload_refs, EncodedUpload};
        let full = Arc::new(CapacityMask::full(1));
        let masks = vec![full.clone(), full.clone(), full.clone(), full];
        let mut srv = ServerAgg::new(1, masks);
        let staged = vec![EncodedUpload::encode(0, &Payload::RawDelta(vec![4.0]))];
        fold_incremental(&mut srv, &upload_refs(&staged));
        assert_eq!(srv.direction, vec![1.0]); // 4.0 / M=4
        fold_incremental(&mut srv, &upload_refs(&staged));
        assert_eq!(srv.direction, vec![2.0]); // persists
    }

    #[test]
    fn recycle_returns_buffers() {
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(4)), 1);
        dev.recycle(Payload::MidtreadDelta(quantize(&[1.0, 2.0, 3.0, 4.0], 4)));
        assert_eq!(dev.psi.len(), 4);
        dev.recycle(Payload::RawFull(vec![1.0; 4]));
        assert_eq!(dev.raw.len(), 4);
        let packed = crate::quant::midtread::quantize_packed_buf(&[1.0, 2.0, 3.0, 4.0], 4, Vec::new());
        let body_len = packed.body.len();
        dev.recycle(Payload::MidtreadFullPacked(packed));
        assert_eq!(dev.body.len(), body_len);
    }

    #[test]
    fn is_selected_binary_search_matches_membership() {
        let mut ctx = RoundCtx::bare(1, 0.1, 0.25, 0.0);
        assert!(ctx.is_selected(0) && ctx.is_selected(99)); // None = all
        ctx.selected = Some(vec![0, 3, 4, 9]);
        for d in 0..12 {
            assert_eq!(ctx.is_selected(d), [0, 3, 4, 9].contains(&d), "{d}");
        }
        ctx.selected = Some(Vec::new());
        assert!(!ctx.is_selected(0));
    }

    #[test]
    fn table_suite_has_paper_columns() {
        let suite = table_suite(0.25);
        let names: Vec<&str> = suite.iter().map(|a| a.name()).collect();
        assert_eq!(
            names,
            vec!["QSGD", "AdaQuantFL", "LAQ", "LAdaQ", "LENA", "MARINA", "AQUILA"]
        );
    }
}
