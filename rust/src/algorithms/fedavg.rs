//! Uncompressed FedSGD/FedAvg-style reference: every device uploads its
//! raw 32-bit gradient every round. Not a column of the paper's tables
//! but the natural "no compression" anchor every ratio is computed
//! against.

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};

/// See module docs.
#[derive(Clone, Debug, Default)]
pub struct FedAvg;

impl Algorithm for FedAvg {
    fn name(&self) -> &'static str {
        "FedAvg"
    }

    fn incremental(&self) -> bool {
        false
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], _ctx: &RoundCtx) -> ClientUpload {
        dev.uploads += 1;
        let mut raw = std::mem::take(&mut dev.raw);
        raw.clear();
        raw.extend_from_slice(grad);
        ClientUpload {
            payload: Some(Payload::RawFull(raw)),
            level: None,
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_average(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use std::sync::Arc;

    #[test]
    fn direction_is_exact_average() {
        let algo = FedAvg;
        let full = Arc::new(CapacityMask::full(3));
        let mut d0 = DeviceState::new(0, full.clone(), 1);
        let mut d1 = DeviceState::new(1, full.clone(), 2);
        let ctx = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        let u0 = algo.client_step(&mut d0, &[1.0, 2.0, 3.0], &ctx);
        let u1 = algo.client_step(&mut d1, &[3.0, 2.0, 1.0], &ctx);
        let mut srv = ServerAgg::new(3, vec![full.clone(), full]);
        let staged = vec![
            crate::transport::wire::EncodedUpload::encode(0, &u0.payload.unwrap()),
            crate::transport::wire::EncodedUpload::encode(1, &u1.payload.unwrap()),
        ];
        algo.server_fold(&mut srv, &crate::transport::wire::upload_refs(&staged), &ctx);
        assert_eq!(srv.direction, vec![2.0, 2.0, 2.0]);
    }
}
