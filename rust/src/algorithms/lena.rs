//! LENA baseline (Ghadikolaei, Stich & Jaggi, 2021 [25]):
//! communication-efficient distributed learning with **self-triggered**
//! gradient uploads — lazy aggregation of *unquantized* gradients.
//!
//! The device uploads the raw innovation `g_m^k − ĝ_m` (where `ĝ_m` is
//! its last uploaded gradient) only when the innovation is large
//! relative to recent global movement:
//!
//! ```text
//! ‖g_m^k − ĝ_m‖² > (ξ/(α²M²)) · (1/D) Σ_{d'=1}^{D} ‖θ^{k+1−d'} − θ^{k−d'}‖²
//! ```
//!
//! No quantization: each upload costs `32·d` payload bits, so LENA's
//! savings come purely from round skipping (visible in Tables II/III
//! where LENA's totals sit close to the unquantized scale of QSGD×4).

use super::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use crate::transport::wire::{Payload, UploadRef};
use crate::util::vecmath::innovation_norms;

/// See module docs.
#[derive(Clone, Debug)]
pub struct Lena {
    /// Trigger weight `ξ`.
    pub xi: f64,
    /// Memory depth `D`.
    pub memory: usize,
}

impl Lena {
    /// LENA with trigger threshold factor `ξ` over `memory` rounds.
    pub fn new(xi: f64, memory: usize) -> Self {
        assert!(memory >= 1);
        Self { xi, memory }
    }

    fn threshold(&self, ctx: &RoundCtx) -> f64 {
        let d_slots = self.memory.min(ctx.model_diff_history.len());
        if d_slots == 0 {
            return 0.0;
        }
        let acc: f64 = ctx.model_diff_history[..d_slots].iter().sum();
        let alpha2 = ctx.alpha as f64 * ctx.alpha as f64;
        let m = ctx.num_devices.max(1) as f64;
        self.xi * acc / (self.memory as f64 * alpha2 * m * m)
    }
}

impl Algorithm for Lena {
    fn name(&self) -> &'static str {
        "LENA"
    }

    fn incremental(&self) -> bool {
        true
    }

    fn client_step(&self, dev: &mut DeviceState, grad: &[f32], ctx: &RoundCtx) -> ClientUpload {
        let (innov_sq, _linf) = innovation_norms(grad, &dev.q_prev);
        let skip = ctx.round > 0 && innov_sq <= self.threshold(ctx);
        if skip {
            dev.skips += 1;
            return ClientUpload::skip();
        }
        // Raw innovation (into the recycled raw buffer); device
        // reference becomes the exact gradient.
        let mut delta = std::mem::take(&mut dev.raw);
        delta.clear();
        delta.extend(grad.iter().zip(&dev.q_prev).map(|(g, q)| g - q));
        dev.q_prev.copy_from_slice(grad);
        dev.uploads += 1;
        ClientUpload {
            payload: Some(Payload::RawDelta(delta)),
            level: None,
        }
    }

    fn server_fold(&self, srv: &mut ServerAgg, uploads: &[UploadRef<'_>], _ctx: &RoundCtx) {
        super::fold_incremental(srv, uploads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::CapacityMask;
    use crate::util::rng::Xoshiro256pp;
    use std::sync::Arc;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn uploads_exact_innovation() {
        let algo = Lena::new(1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(8)), 1);
        let g0 = grad(8, 1);
        let mut ctx = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        ctx.num_devices = 4;
        let up = algo.client_step(&mut dev, &g0, &ctx);
        match up.payload.unwrap() {
            Payload::RawDelta(d) => assert_eq!(d, g0),
            p => panic!("wrong payload {p:?}"),
        }
        // Reference now equals the gradient exactly (no quantization).
        assert_eq!(dev.q_prev, g0);
    }

    #[test]
    fn identical_gradient_skips_when_model_still() {
        let algo = Lena::new(1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(32)), 2);
        let g = grad(32, 3);
        let mut c0 = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        c0.num_devices = 2;
        algo.client_step(&mut dev, &g, &c0);
        let mut c1 = RoundCtx::bare(1, 0.1, 0.0, 0.0);
        c1.num_devices = 2;
        c1.model_diff_history = vec![0.0];
        // Innovation is exactly zero ⇒ 0 ≤ 0 ⇒ skip.
        let up = algo.client_step(&mut dev, &g, &c1);
        assert!(up.payload.is_none());
    }

    #[test]
    fn big_innovation_uploads() {
        let algo = Lena::new(1.0, 10);
        let mut dev = DeviceState::new(0, Arc::new(CapacityMask::full(32)), 4);
        let mut c = RoundCtx::bare(0, 0.1, 0.0, 0.0);
        c.num_devices = 100;
        algo.client_step(&mut dev, &grad(32, 5), &c);
        let big: Vec<f32> = grad(32, 6).iter().map(|x| x * 100.0).collect();
        let mut c1 = RoundCtx::bare(1, 0.1, 0.0, 1e-6);
        c1.num_devices = 100;
        assert!(algo.client_step(&mut dev, &big, &c1).payload.is_some());
    }
}
