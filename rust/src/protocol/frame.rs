//! Length-prefixed framing: the byte layer under every protocol
//! message.
//!
//! A frame is `len: u32 LE | kind: u8 | body[len]`. The length covers
//! the body only, is capped at [`MAX_FRAME_BYTES`] (so a garbage
//! header cannot provoke an unbounded allocation), and the kind byte
//! selects the [`super::messages`] decoder. Wire-v2 payloads ride
//! inside round-result bodies verbatim — framing never re-encodes
//! them.

use super::ProtocolError;

/// Bytes in a frame header (`u32` length + kind byte).
pub const HEADER_BYTES: usize = 5;

/// Hard cap on a frame body. Generous enough for a full model
/// broadcast (64 Mi parameters) while bounding what a malformed or
/// hostile header can make the receiver allocate.
pub const MAX_FRAME_BYTES: u32 = 256 << 20;

/// One decoded frame: a message kind plus its undecoded body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// Message kind byte (see [`super::messages`]).
    pub kind: u8,
    /// Message body, still encoded.
    pub body: Vec<u8>,
}

/// Append the frame for (`kind`, `body`) to `out`.
///
/// # Panics
/// If `body` exceeds [`MAX_FRAME_BYTES`] — senders construct bodies
/// from bounded model state, so an oversized body is a programming
/// error, not a peer failure.
pub fn encode_frame(kind: u8, body: &[u8], out: &mut Vec<u8>) {
    assert!(
        body.len() <= MAX_FRAME_BYTES as usize,
        "frame body {} exceeds MAX_FRAME_BYTES",
        body.len()
    );
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(body);
}

/// Decode one frame from the front of `bytes`; returns the frame and
/// the number of bytes it consumed. Never panics on malformed input:
/// a short buffer is [`ProtocolError::Truncated`], an oversized
/// length is [`ProtocolError::FrameTooLarge`].
pub fn decode_frame(bytes: &[u8]) -> Result<(Frame, usize), ProtocolError> {
    if bytes.len() < HEADER_BYTES {
        return Err(ProtocolError::Truncated {
            need: HEADER_BYTES,
            have: bytes.len(),
        });
    }
    let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    if len > MAX_FRAME_BYTES {
        return Err(ProtocolError::FrameTooLarge {
            len,
            max: MAX_FRAME_BYTES,
        });
    }
    let kind = bytes[4];
    let total = HEADER_BYTES + len as usize;
    if bytes.len() < total {
        return Err(ProtocolError::Truncated {
            need: total,
            have: bytes.len(),
        });
    }
    Ok((
        Frame {
            kind,
            body: bytes[HEADER_BYTES..total].to_vec(),
        },
        total,
    ))
}

/// Incremental frame assembler for byte-stream transports.
///
/// Feed it reads of any size; it buffers a partial header or body
/// across calls, so a read timeout mid-frame never desynchronizes the
/// stream — the next [`FrameReader::consume`] resumes exactly where
/// the last one stopped.
#[derive(Debug, Default)]
pub struct FrameReader {
    header: [u8; HEADER_BYTES],
    have_header: usize,
    body: Vec<u8>,
    body_len: usize,
    in_body: bool,
}

impl FrameReader {
    /// Fresh reader at a frame boundary.
    pub fn new() -> Self {
        Self::default()
    }

    /// How many more bytes the current frame needs (header bytes while
    /// the header is incomplete, then body bytes).
    pub fn wanted(&self) -> usize {
        if self.in_body {
            self.body_len - self.body.len()
        } else {
            HEADER_BYTES - self.have_header
        }
    }

    /// Push `chunk` (the bytes just read from the stream; callers read
    /// at most [`FrameReader::wanted`] at a time so a chunk never
    /// spans a frame boundary). Returns the completed frame, if this
    /// chunk finished one.
    pub fn consume(&mut self, chunk: &[u8]) -> Result<Option<Frame>, ProtocolError> {
        debug_assert!(chunk.len() <= self.wanted());
        if !self.in_body {
            let n = chunk.len().min(HEADER_BYTES - self.have_header);
            self.header[self.have_header..self.have_header + n].copy_from_slice(&chunk[..n]);
            self.have_header += n;
            if self.have_header < HEADER_BYTES {
                return Ok(None);
            }
            let len = u32::from_le_bytes([
                self.header[0],
                self.header[1],
                self.header[2],
                self.header[3],
            ]);
            if len > MAX_FRAME_BYTES {
                return Err(ProtocolError::FrameTooLarge {
                    len,
                    max: MAX_FRAME_BYTES,
                });
            }
            self.body_len = len as usize;
            self.body.clear();
            self.in_body = true;
        } else {
            self.body.extend_from_slice(chunk);
        }
        if self.body.len() < self.body_len {
            return Ok(None);
        }
        let frame = Frame {
            kind: self.header[4],
            body: std::mem::take(&mut self.body),
        };
        self.have_header = 0;
        self.body_len = 0;
        self.in_body = false;
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let mut buf = Vec::new();
        encode_frame(0x03, b"hello", &mut buf);
        let (frame, used) = decode_frame(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(frame.kind, 0x03);
        assert_eq!(frame.body, b"hello");
    }

    #[test]
    fn truncated_and_oversized() {
        assert!(matches!(decode_frame(&[1, 0]), Err(ProtocolError::Truncated { .. })));
        let mut buf = Vec::new();
        encode_frame(0x01, &[9; 16], &mut buf);
        buf.truncate(10);
        assert!(matches!(decode_frame(&buf), Err(ProtocolError::Truncated { .. })));
        let huge = (MAX_FRAME_BYTES + 1).to_le_bytes();
        let bytes = [huge[0], huge[1], huge[2], huge[3], 0x01];
        assert!(matches!(decode_frame(&bytes), Err(ProtocolError::FrameTooLarge { .. })));
    }

    #[test]
    fn incremental_reassembly_byte_at_a_time() {
        let mut buf = Vec::new();
        encode_frame(0x42, &[7, 8, 9], &mut buf);
        let mut reader = FrameReader::new();
        let mut out = None;
        for &b in &buf {
            assert!(reader.wanted() > 0);
            if let Some(f) = reader.consume(&[b]).unwrap() {
                out = Some(f);
            }
        }
        let f = out.expect("frame completes on the last byte");
        assert_eq!(f.kind, 0x42);
        assert_eq!(f.body, vec![7, 8, 9]);
        // The reader is back at a frame boundary.
        assert_eq!(reader.wanted(), HEADER_BYTES);
    }

    #[test]
    fn empty_body_frame() {
        let mut buf = Vec::new();
        encode_frame(0x02, &[], &mut buf);
        assert_eq!(buf.len(), HEADER_BYTES);
        let (frame, _) = decode_frame(&buf).unwrap();
        assert!(frame.body.is_empty());
        let mut reader = FrameReader::new();
        let f = reader.consume(&buf).unwrap().expect("complete");
        assert_eq!(f.kind, 0x02);
    }
}
