//! The device side of the protocol: a thin client that runs the
//! *existing* device phase — gradient, capacity-mask gather,
//! `Algorithm::client_step`, wire-v2 encode — behind a [`Connection`].
//!
//! [`DeviceClient`] owns the same problem/algorithm/config the
//! coordinator was built from (both sides construct their state from
//! the shared seed; the rendezvous cross-checks it), claims a device
//! range at rendezvous, and then serves rounds: on every
//! [`Message::StartRound`] it computes each owned selected device and
//! reports a [`Message::RoundResult`] per device. Between rounds it
//! heartbeats so the coordinator can tell "slow" from "gone".
//!
//! Failure handling (DESIGN.md §Fault model):
//! [`DeviceClient::run_with`] dials through a [`Dial`] factory and
//! survives connection loss — it redials with capped exponential
//! backoff (deterministically jittered from the run seed) and resumes
//! through the rejoin handshake. Every computed [`RoundResult`] is
//! cached for the duration of its round, so a reconnecting client
//! *resends* byte-identical results instead of recomputing them — the
//! device RNG advances exactly once per computed round no matter how
//! many times the connection dies, which is what keeps a chaos-ridden
//! run's trace bit-identical to a fault-free one. What is *not*
//! supported is a client process that crashes and restarts from
//! scratch mid-run: its rebuilt device state would re-advance RNG
//! streams the run already consumed. Reconnection is same-process
//! only; a restarted *coordinator* is fine (that state checkpoints).

use super::messages::{Message, RoundResult};
use super::transport::{Connection, Dial};
use super::{CoordinatorState, ProtocolError, PROTOCOL_VERSION};
use crate::algorithms::{Algorithm, ClientUpload, DeviceState};
use crate::coordinator::{PopulationSpec, RunConfig};
use crate::hetero::{CapacityMask, MaskTable};
use crate::problems::{GradScratch, GradientSource};
use crate::transport::wire;
use crate::util::rng::Xoshiro256pp;
use std::collections::BTreeSet;
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long the client waits for the coordinator's welcome or rejoin
/// ack after sending its hello (the coordinator may be waiting on
/// other clients before it answers anyone's round traffic, but
/// welcomes and acks are sent immediately).
const WELCOME_TIMEOUT: Duration = Duration::from_secs(30);

/// Receive slice while deliberately silent (failure-injection mode):
/// short enough to notice the coordinator hanging up promptly.
const SILENT_SLICE: Duration = Duration::from_millis(500);

/// Stream id salt for the backoff jitter RNG (seeded from the run
/// seed, keyed by attempt — no free-running stream).
const BACKOFF_SALT: u64 = 0x00BA_C0FF;

/// One owned device's replicated engine-side state and buffers.
struct DeviceUnit {
    state: DeviceState,
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    scratch: GradScratch,
    wire_buf: Vec<u8>,
}

/// The live state a client carries *across* connections: its identity,
/// its device units (whose RNG streams must advance exactly once per
/// computed round), the per-round result cache the rejoin handshake
/// digests, and the resend hint from the last rejoin ack.
struct ClientCore {
    client_id: u32,
    lo: usize,
    units: Vec<DeviceUnit>,
    /// Cached results for `cache_round`, indexed like `units`; resent
    /// verbatim after a reconnect instead of recomputed.
    cache: Vec<Option<RoundResult>>,
    cache_round: Option<u32>,
    /// Devices the coordinator said are already staged for
    /// `hint_round` — must not be resent.
    hint: BTreeSet<u32>,
    hint_round: Option<u32>,
    rounds_served: usize,
    counted_round: Option<u32>,
    silent: bool,
}

/// What a finished client run reports back to its caller.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientReport {
    /// The id the coordinator assigned at rendezvous.
    pub client_id: u32,
    /// The contiguous device range this client computed.
    pub devices: Range<usize>,
    /// Rounds in which this client computed and reported results.
    pub rounds_served: usize,
}

/// A protocol client serving a range of devices — over one fixed
/// connection ([`DeviceClient::run`]) or resiliently through a dialer
/// with reconnect/resume ([`DeviceClient::run_with`]).
pub struct DeviceClient {
    problem: Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    cfg: RunConfig,
    masks: MaskTable,
    heartbeat: Duration,
    silent_after: Option<usize>,
    idle_timeout: Duration,
    retry_max: u32,
    retry_base: Duration,
    retry_cap: Duration,
}

impl DeviceClient {
    /// Build a client from the same problem/algorithm/config/masks the
    /// coordinator's session was built from — determinism depends on
    /// both sides agreeing, and the rendezvous verifies the seed and
    /// device count.
    ///
    /// # Panics
    /// If `masks` does not provide exactly one mask per device.
    pub fn new(
        problem: Arc<dyn GradientSource>,
        algo: Arc<dyn Algorithm>,
        cfg: RunConfig,
        masks: Vec<Arc<CapacityMask>>,
    ) -> Self {
        Self::with_mask_table(problem, algo, cfg, MaskTable::from(masks))
    }

    /// [`DeviceClient::new`] with a compact [`MaskTable`] — what a
    /// client serving a slice of a virtualized million-device
    /// population passes (a dense mask vector would be O(M) on its
    /// own).
    ///
    /// # Panics
    /// If `masks` does not cover exactly one mask per device.
    pub fn with_mask_table(
        problem: Arc<dyn GradientSource>,
        algo: Arc<dyn Algorithm>,
        cfg: RunConfig,
        masks: MaskTable,
    ) -> Self {
        assert_eq!(
            masks.num_devices(),
            problem.num_devices(),
            "need one mask per device"
        );
        Self {
            problem,
            algo,
            cfg,
            masks,
            heartbeat: Duration::from_millis(200),
            silent_after: None,
            idle_timeout: Duration::from_secs(30),
            retry_max: 10,
            retry_base: Duration::from_millis(50),
            retry_cap: Duration::from_secs(2),
        }
    }

    /// Heartbeat interval (must be well under the coordinator's
    /// `serve.heartbeat_timeout_ms`). Default 200 ms.
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.heartbeat = Duration::from_millis(ms.max(1));
        self
    }

    /// Failure injection for tests and the service example: after
    /// serving this many rounds the client goes silent — it stops
    /// reporting *and* heartbeating but keeps the connection open, so
    /// the coordinator can only detect it through heartbeat expiry.
    pub fn silent_after(mut self, rounds: usize) -> Self {
        self.silent_after = Some(rounds);
        self
    }

    /// Reconnect policy for [`DeviceClient::run_with`]: give up after
    /// `max_attempts` consecutive failures; sleep an exponentially
    /// growing backoff between attempts, starting at `base_ms` and
    /// capped at `cap_ms`. Defaults: 10 attempts, 50 ms, 2 s.
    pub fn reconnect(mut self, max_attempts: u32, base_ms: u64, cap_ms: u64) -> Self {
        self.retry_max = max_attempts;
        self.retry_base = Duration::from_millis(base_ms.max(1));
        self.retry_cap = Duration::from_millis(cap_ms.max(base_ms.max(1)));
        self
    }

    /// How long the coordinator may stay completely silent (no round
    /// traffic, no heartbeat replies) before the connection is
    /// declared dead and redialed. Default 30 s.
    pub fn idle_timeout_ms(mut self, ms: u64) -> Self {
        self.idle_timeout = Duration::from_millis(ms.max(1));
        self
    }

    /// Backoff before reconnect attempt `attempt` (1-based): capped
    /// exponential, jittered into `[0.5, 1.0]`× by a seed+attempt
    /// keyed RNG stream so concurrent clients don't thundering-herd
    /// yet every run schedules identically.
    fn backoff(&self, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(1).min(16);
        let raw = self.retry_base.saturating_mul(1 << exp).min(self.retry_cap);
        let mut rng = Xoshiro256pp::stream(self.cfg.seed, BACKOFF_SALT ^ u64::from(attempt));
        raw.mul_f64(0.5 + 0.5 * rng.next_f64())
    }

    /// Rendezvous on a fresh connection and build the per-device state.
    fn hello(&self, conn: &mut dyn Connection) -> Result<ClientCore, ProtocolError> {
        conn.send(&Message::Rendezvous {
            version: PROTOCOL_VERSION,
            want: 0,
        })?;
        let welcome = match conn.recv(WELCOME_TIMEOUT)? {
            Message::Welcome(w) => w,
            _ => return Err(ProtocolError::Violation("expected a welcome")),
        };
        let m = self.problem.num_devices();
        if welcome.num_devices as usize != m || welcome.seed != self.cfg.seed {
            return Err(ProtocolError::Violation("coordinator/client config mismatch"));
        }
        let lo = welcome.device_lo as usize;
        let count = welcome.device_count as usize;
        if lo + count > m {
            return Err(ProtocolError::Violation("assigned device range out of bounds"));
        }

        // Replicate the engine's per-device construction through the
        // same population spec the coordinator derives slots from
        // (same mask, same resolved sections, same seed-derived RNG
        // stream) so the client-side `client_step` is bit-identical to
        // the in-process device phase.
        let d = self.problem.dim();
        let population = PopulationSpec::new(
            &self.problem.layout(),
            self.masks.clone(),
            &self.cfg.quant_sections,
            self.cfg.seed,
        );
        let units: Vec<DeviceUnit> = (lo..lo + count)
            .map(|i| {
                let support = population.mask_of(i).support();
                DeviceUnit {
                    state: population.fresh_state(i),
                    grad_full: vec![0.0; d],
                    grad_gathered: Vec::with_capacity(support),
                    scratch: self.problem.make_scratch(),
                    wire_buf: Vec::new(),
                }
            })
            .collect();
        let cache = vec![None; count];
        Ok(ClientCore {
            client_id: welcome.client_id,
            lo,
            units,
            cache,
            cache_round: None,
            hint: BTreeSet::new(),
            hint_round: None,
            rounds_served: 0,
            counted_round: None,
            silent: false,
        })
    }

    /// Reclaim this client's slot on a fresh connection: offer the XOR
    /// fold of the cached result digests so the coordinator can dedupe
    /// what already arrived, and record its staged-device hint.
    fn rejoin(
        &self,
        core: &mut ClientCore,
        conn: &mut dyn Connection,
    ) -> Result<(), ProtocolError> {
        let digest = core.cache.iter().flatten().fold(0u64, |acc, r| acc ^ r.digest());
        conn.send(&Message::Rejoin {
            client_id: core.client_id,
            round: core.cache_round.unwrap_or(0),
            result_digest: digest,
        })?;
        let deadline = Instant::now() + WELCOME_TIMEOUT;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            match conn.recv(remaining) {
                Ok(Message::RejoinAck(ack)) => {
                    if ack.client_id != core.client_id
                        || ack.device_lo as usize != core.lo
                        || ack.device_count as usize != core.units.len()
                    {
                        return Err(ProtocolError::Violation("rejoin ack names a different slot"));
                    }
                    core.hint_round = Some(ack.round);
                    core.hint = ack.staged.into_iter().collect();
                    return Ok(());
                }
                // Stale round traffic can precede the ack; skip it.
                Ok(_) => {}
                Err(ProtocolError::Timeout) => {}
                Err(e) => return Err(e),
            }
        }
    }

    /// Serve rounds on an established connection. `Ok(())` means the
    /// coordinator announced `Finished`; any error means the
    /// connection is unusable (the resilient path redials, the
    /// single-connection path gives up).
    fn serve_loop(
        &self,
        core: &mut ClientCore,
        conn: &mut dyn Connection,
    ) -> Result<(), ProtocolError> {
        let d = self.problem.dim();
        let mut last_rx = Instant::now();
        loop {
            if core.silent {
                match conn.recv(SILENT_SLICE) {
                    Err(ProtocolError::Timeout) => continue,
                    // Silent mode deliberately plays dead; treat any
                    // hangup as the end of this client's run.
                    Err(_) => return Ok(()),
                    Ok(Message::EndRound {
                        state: CoordinatorState::Finished,
                        ..
                    }) => return Ok(()),
                    Ok(_) => continue,
                }
            }
            match conn.recv(self.heartbeat) {
                Err(ProtocolError::Timeout) => {
                    if last_rx.elapsed() >= self.idle_timeout {
                        return Err(ProtocolError::Timeout);
                    }
                    conn.send(&Message::Heartbeat)?;
                }
                Err(e) => return Err(e),
                Ok(msg) => {
                    last_rx = Instant::now();
                    match msg {
                        Message::StartRound(sr) => {
                            if sr.theta.len() != d {
                                return Err(ProtocolError::Violation(
                                    "broadcast model has wrong dim",
                                ));
                            }
                            self.serve_round(core, conn, &sr)?;
                        }
                        Message::EndRound {
                            state: CoordinatorState::Finished,
                            ..
                        } => return Ok(()),
                        Message::State(CoordinatorState::Finished) => return Ok(()),
                        // Other traffic (heartbeat replies, non-final
                        // end-rounds) carries no work.
                        _ => {}
                    }
                }
            }
        }
    }

    /// Compute one owned device's round result into its cache slot, if
    /// it is selected, not hinted as already staged, and not already
    /// cached. Touches only the unit's own state/buffers and its cache
    /// slot, so disjoint units compute concurrently without changing
    /// any result.
    fn compute_unit(
        &self,
        sr: &super::messages::StartRound,
        hinted: bool,
        hint: &BTreeSet<u32>,
        unit: &mut DeviceUnit,
        slot: &mut Option<RoundResult>,
    ) {
        let i = unit.state.id;
        if !sr.ctx.is_selected(i) || (hinted && hint.contains(&(i as u32))) || slot.is_some() {
            return;
        }
        let loss = self
            .problem
            .local_grad(i, &sr.theta, &mut unit.grad_full, &mut unit.scratch);
        unit.state.mask.gather(&unit.grad_full, &mut unit.grad_gathered);
        let ClientUpload { payload, level } =
            self.algo.client_step(&mut unit.state, &unit.grad_gathered, &sr.ctx);
        let bytes = payload.map(|p| {
            wire::encode_into(&p, &mut unit.wire_buf);
            unit.state.recycle(p);
            unit.wire_buf.clone()
        });
        *slot = Some(RoundResult {
            round: sr.ctx.round as u32,
            device: i as u32,
            loss,
            level,
            uploads: unit.state.uploads,
            skips: unit.state.skips,
            payload: bytes,
        });
    }

    /// Compute-or-resend every owned selected device for one start
    /// round. A round seen for the first time clears the cache and
    /// computes (advancing device RNG streams); a replayed start round
    /// — after a reconnect, or duplicated by a fault — resends the
    /// cached bytes verbatim, minus whatever the rejoin ack said is
    /// already staged.
    ///
    /// Computation runs in parallel over the owned units (each worker
    /// owns a disjoint units/cache chunk pair; per-device work depends
    /// only on that device's own state and the broadcast context), then
    /// results are sent serially in ascending device order — so a
    /// served run's wire traffic is bit-identical to the in-process
    /// device phase at every thread count.
    fn serve_round(
        &self,
        core: &mut ClientCore,
        conn: &mut dyn Connection,
        sr: &super::messages::StartRound,
    ) -> Result<(), ProtocolError> {
        let k = sr.ctx.round as u32;
        if core.cache_round != Some(k) {
            core.cache_round = Some(k);
            core.cache.iter_mut().for_each(|s| *s = None);
        }
        let hinted = core.hint_round == Some(k);

        // ---- compute phase (parallel over owned units) -------------
        let n = core.units.len();
        let threads = if self.cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.cfg.threads
        }
        .max(1)
        .min(n.max(1));
        if threads <= 1 || n <= 1 {
            for (unit, slot) in core.units.iter_mut().zip(core.cache.iter_mut()) {
                self.compute_unit(sr, hinted, &core.hint, unit, slot);
            }
        } else {
            let chunk = n.div_ceil(threads);
            let hint = &core.hint;
            std::thread::scope(|scope| {
                for (units, cache) in
                    core.units.chunks_mut(chunk).zip(core.cache.chunks_mut(chunk))
                {
                    scope.spawn(move || {
                        for (unit, slot) in units.iter_mut().zip(cache.iter_mut()) {
                            self.compute_unit(sr, hinted, hint, unit, slot);
                        }
                    });
                }
            });
        }

        // ---- send phase (serial, ascending device order) -----------
        for (unit, slot) in core.units.iter().zip(core.cache.iter()) {
            let i = unit.state.id;
            if !sr.ctx.is_selected(i) {
                continue;
            }
            if hinted && core.hint.contains(&(i as u32)) {
                continue;
            }
            let r = slot.clone().expect("computed above");
            conn.send(&Message::RoundResult(r))?;
        }
        if core.counted_round != Some(k) {
            core.counted_round = Some(k);
            core.rounds_served += 1;
        }
        if let Some(n) = self.silent_after {
            if core.rounds_served >= n {
                core.silent = true;
            }
        }
        Ok(())
    }

    /// Build the report for whatever `core` has served so far.
    fn report(core: &ClientCore) -> ClientReport {
        ClientReport {
            client_id: core.client_id,
            devices: core.lo..core.lo + core.units.len(),
            rounds_served: core.rounds_served,
        }
    }

    /// Rendezvous over one fixed `conn` and serve rounds until the
    /// coordinator finishes (or hangs up). No reconnection: a dead
    /// connection ends the run (cleanly, as legacy callers expect).
    pub fn run(&self, conn: &mut dyn Connection) -> Result<ClientReport, ProtocolError> {
        let mut core = self.hello(conn)?;
        match self.serve_loop(&mut core, conn) {
            Ok(()) | Err(ProtocolError::Closed) => Ok(Self::report(&core)),
            Err(e) => Err(e),
        }
    }

    /// Serve resiliently through `dial`: every connection loss —
    /// including the very first dial finding nobody listening — is
    /// retried with capped exponential backoff, and each new
    /// connection resumes via the rejoin handshake. Returns once the
    /// coordinator announces the run finished, or with the last error
    /// after `retry_max` consecutive failures. Protocol violations
    /// (config mismatch, foreign ack) are never retried.
    pub fn run_with(&self, dial: &dyn Dial) -> Result<ClientReport, ProtocolError> {
        let mut core: Option<ClientCore> = None;
        let mut failures: u32 = 0;
        loop {
            let mut conn = match dial.dial() {
                Ok(c) => c,
                Err(e) => {
                    failures += 1;
                    if failures >= self.retry_max {
                        return Err(e);
                    }
                    std::thread::sleep(self.backoff(failures));
                    continue;
                }
            };
            let admitted = if let Some(c) = core.as_mut() {
                self.rejoin(c, conn.as_mut())
            } else {
                match self.hello(conn.as_mut()) {
                    Ok(c) => {
                        core = Some(c);
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            };
            if let Err(e) = admitted {
                if matches!(e, ProtocolError::Violation(_)) {
                    return Err(e);
                }
                failures += 1;
                if failures >= self.retry_max {
                    return Err(e);
                }
                std::thread::sleep(self.backoff(failures));
                continue;
            }
            failures = 0;
            let c = core.as_mut().expect("admission populated the core");
            match self.serve_loop(c, conn.as_mut()) {
                Ok(()) => return Ok(Self::report(c)),
                Err(e) => {
                    if matches!(e, ProtocolError::Violation(_)) {
                        return Err(e);
                    }
                    failures += 1;
                    if failures >= self.retry_max {
                        return Err(e);
                    }
                    std::thread::sleep(self.backoff(failures));
                }
            }
        }
    }
}
