//! The device side of the protocol: a thin client that runs the
//! *existing* device phase — gradient, capacity-mask gather,
//! `Algorithm::client_step`, wire-v2 encode — behind a [`Connection`].
//!
//! [`DeviceClient`] owns the same problem/algorithm/config the
//! coordinator was built from (both sides construct their state from
//! the shared seed; the rendezvous cross-checks it), claims a device
//! range at rendezvous, and then serves rounds: on every
//! [`Message::StartRound`] it computes each owned selected device and
//! reports a [`Message::RoundResult`] per device. Between rounds it
//! heartbeats so the coordinator can tell "slow" from "gone".

use super::messages::{Message, RoundResult};
use super::transport::Connection;
use super::{CoordinatorState, ProtocolError, PROTOCOL_VERSION};
use crate::algorithms::{Algorithm, ClientUpload, DeviceState};
use crate::coordinator::RunConfig;
use crate::hetero::CapacityMask;
use crate::problems::{GradScratch, GradientSource};
use crate::transport::wire;
use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

/// How long the client waits for the coordinator's welcome after
/// sending its rendezvous (the coordinator may be waiting on other
/// clients before it answers anyone's round traffic, but welcomes are
/// sent immediately).
const WELCOME_TIMEOUT: Duration = Duration::from_secs(30);

/// Receive slice while deliberately silent (failure-injection mode):
/// short enough to notice the coordinator hanging up promptly.
const SILENT_SLICE: Duration = Duration::from_millis(500);

/// One owned device's replicated engine-side state and buffers.
struct DeviceUnit {
    state: DeviceState,
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    scratch: GradScratch,
    wire_buf: Vec<u8>,
}

/// What a finished client run reports back to its caller.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientReport {
    /// The id the coordinator assigned at rendezvous.
    pub client_id: u32,
    /// The contiguous device range this client computed.
    pub devices: Range<usize>,
    /// Rounds in which this client computed and reported results.
    pub rounds_served: usize,
}

/// A protocol client serving a range of devices over one connection.
pub struct DeviceClient {
    problem: Arc<dyn GradientSource>,
    algo: Arc<dyn Algorithm>,
    cfg: RunConfig,
    masks: Vec<Arc<CapacityMask>>,
    heartbeat: Duration,
    silent_after: Option<usize>,
}

impl DeviceClient {
    /// Build a client from the same problem/algorithm/config/masks the
    /// coordinator's session was built from — determinism depends on
    /// both sides agreeing, and the rendezvous verifies the seed and
    /// device count.
    ///
    /// # Panics
    /// If `masks` does not provide exactly one mask per device.
    pub fn new(
        problem: Arc<dyn GradientSource>,
        algo: Arc<dyn Algorithm>,
        cfg: RunConfig,
        masks: Vec<Arc<CapacityMask>>,
    ) -> Self {
        assert_eq!(masks.len(), problem.num_devices(), "need one mask per device");
        Self {
            problem,
            algo,
            cfg,
            masks,
            heartbeat: Duration::from_millis(200),
            silent_after: None,
        }
    }

    /// Heartbeat interval (must be well under the coordinator's
    /// `serve.heartbeat_timeout_ms`). Default 200 ms.
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.heartbeat = Duration::from_millis(ms.max(1));
        self
    }

    /// Failure injection for tests and the service example: after
    /// serving this many rounds the client goes silent — it stops
    /// reporting *and* heartbeating but keeps the connection open, so
    /// the coordinator can only detect it through heartbeat expiry.
    pub fn silent_after(mut self, rounds: usize) -> Self {
        self.silent_after = Some(rounds);
        self
    }

    /// Rendezvous over `conn` and serve rounds until the coordinator
    /// finishes (or hangs up).
    pub fn run(&self, conn: &mut dyn Connection) -> Result<ClientReport, ProtocolError> {
        conn.send(&Message::Rendezvous {
            version: PROTOCOL_VERSION,
            want: 0,
        })?;
        let welcome = match conn.recv(WELCOME_TIMEOUT)? {
            Message::Welcome(w) => w,
            _ => return Err(ProtocolError::Violation("expected a welcome")),
        };
        let m = self.problem.num_devices();
        if welcome.num_devices as usize != m || welcome.seed != self.cfg.seed {
            return Err(ProtocolError::Violation("coordinator/client config mismatch"));
        }
        let lo = welcome.device_lo as usize;
        let count = welcome.device_count as usize;
        if lo + count > m {
            return Err(ProtocolError::Violation("assigned device range out of bounds"));
        }

        // Replicate the engine's per-device construction (same mask,
        // same resolved sections, same seed-derived RNG stream) so the
        // client-side `client_step` is bit-identical to the in-process
        // device phase.
        let d = self.problem.dim();
        let layout = self.problem.layout();
        let mut units: Vec<DeviceUnit> = (lo..lo + count)
            .map(|i| {
                let mask = self.masks[i].clone();
                let sections = Arc::new(self.cfg.quant_sections.resolve(&layout, &mask));
                DeviceUnit {
                    state: DeviceState::with_sections(i, mask.clone(), sections, self.cfg.seed),
                    grad_full: vec![0.0; d],
                    grad_gathered: Vec::with_capacity(mask.support()),
                    scratch: self.problem.make_scratch(),
                    wire_buf: Vec::new(),
                }
            })
            .collect();

        let mut report = ClientReport {
            client_id: welcome.client_id,
            devices: lo..lo + count,
            rounds_served: 0,
        };
        let mut silent = false;
        loop {
            if silent {
                match conn.recv(SILENT_SLICE) {
                    Err(ProtocolError::Timeout) => continue,
                    Err(_) => break,
                    Ok(Message::EndRound {
                        state: CoordinatorState::Finished,
                        ..
                    }) => break,
                    Ok(_) => continue,
                }
            }
            match conn.recv(self.heartbeat) {
                Err(ProtocolError::Timeout) => conn.send(&Message::Heartbeat)?,
                Err(ProtocolError::Closed) => break,
                Err(e) => return Err(e),
                Ok(Message::StartRound(sr)) => {
                    if sr.theta.len() != d {
                        return Err(ProtocolError::Violation("broadcast model has wrong dim"));
                    }
                    for unit in units.iter_mut() {
                        let i = unit.state.id;
                        if !sr.ctx.is_selected(i) {
                            continue;
                        }
                        let loss = self.problem.local_grad(
                            i,
                            &sr.theta,
                            &mut unit.grad_full,
                            &mut unit.scratch,
                        );
                        unit.state.mask.gather(&unit.grad_full, &mut unit.grad_gathered);
                        let ClientUpload { payload, level } =
                            self.algo.client_step(&mut unit.state, &unit.grad_gathered, &sr.ctx);
                        let bytes = payload.map(|p| {
                            wire::encode_into(&p, &mut unit.wire_buf);
                            unit.state.recycle(p);
                            unit.wire_buf.clone()
                        });
                        conn.send(&Message::RoundResult(RoundResult {
                            round: sr.ctx.round as u32,
                            device: i as u32,
                            loss,
                            level,
                            uploads: unit.state.uploads,
                            skips: unit.state.skips,
                            payload: bytes,
                        }))?;
                    }
                    report.rounds_served += 1;
                    if let Some(n) = self.silent_after {
                        if report.rounds_served >= n {
                            silent = true;
                        }
                    }
                }
                Ok(Message::EndRound {
                    state: CoordinatorState::Finished,
                    ..
                }) => break,
                Ok(Message::State(CoordinatorState::Finished)) => break,
                // Other traffic (heartbeat replies, non-final
                // end-rounds) carries no work.
                Ok(_) => {}
            }
        }
        Ok(report)
    }
}
