//! The protocol message set and its byte codec.
//!
//! Client → coordinator: [`Message::Rendezvous`], [`Message::Heartbeat`],
//! [`Message::RoundResult`], [`Message::Rejoin`]. Coordinator → client:
//! [`Message::Welcome`], [`Message::State`], [`Message::StartRound`],
//! [`Message::EndRound`], [`Message::RejoinAck`].
//!
//! Every numeric field is little-endian and floats travel as raw IEEE
//! bit patterns (`to_le_bytes`/`from_le_bytes`), so a decoded
//! [`RoundCtx`] is bit-identical to the one the coordinator built —
//! the determinism guarantee rests on this. Decoding is total: any
//! byte body yields `Ok` or a typed [`ProtocolError`], never a panic.
//! An embedded upload is validated against the wire-v2 codec at decode
//! time, so wire failures surface as composed protocol errors at the
//! message boundary.

use super::{CoordinatorState, ProtocolError};
use crate::algorithms::RoundCtx;
use crate::transport::wire;

/// Frame kind bytes, one per message.
pub mod kind {
    /// [`super::Message::Rendezvous`].
    pub const RENDEZVOUS: u8 = 0x01;
    /// [`super::Message::Heartbeat`].
    pub const HEARTBEAT: u8 = 0x02;
    /// [`super::Message::RoundResult`].
    pub const ROUND_RESULT: u8 = 0x03;
    /// [`super::Message::Rejoin`].
    pub const REJOIN: u8 = 0x04;
    /// [`super::Message::Welcome`].
    pub const WELCOME: u8 = 0x11;
    /// [`super::Message::State`].
    pub const STATE: u8 = 0x12;
    /// [`super::Message::StartRound`].
    pub const START_ROUND: u8 = 0x13;
    /// [`super::Message::EndRound`].
    pub const END_ROUND: u8 = 0x14;
    /// [`super::Message::RejoinAck`].
    pub const REJOIN_ACK: u8 = 0x15;
}

/// The coordinator's reply to a successful rendezvous: which devices
/// the client now serves, plus the run parameters it must match.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Welcome {
    /// Coordinator-assigned client index (0-based).
    pub client_id: u32,
    /// First device id in the client's contiguous range.
    pub device_lo: u32,
    /// Number of devices in the range.
    pub device_count: u32,
    /// Total device count `M` of the run (cross-checked against the
    /// client's locally built problem).
    pub num_devices: u32,
    /// Configured horizon `K`.
    pub rounds: u32,
    /// Run seed (cross-checked so both sides derive identical device
    /// RNG streams).
    pub seed: u64,
}

/// The start-round broadcast: the full [`RoundCtx`] every client rule
/// will see this round plus the current global model.
#[derive(Clone, Debug)]
pub struct StartRound {
    /// Round context, reconstructed bit-exactly on the client.
    pub ctx: RoundCtx,
    /// Current global model θᵏ.
    pub theta: Vec<f32>,
}

/// One device's round outcome, reported by the client that serves it:
/// what `Algorithm::client_step` produced (upload bytes or a skip)
/// plus the bookkeeping the coordinator's selection view mirrors.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundResult {
    /// Round this result belongs to.
    pub round: u32,
    /// Reporting device id.
    pub device: u32,
    /// Local loss at θᵏ.
    pub loss: f64,
    /// Quantization level the client rule chose (upload or skip
    /// beacon), if any.
    pub level: Option<u8>,
    /// Device's cumulative upload count after this round.
    pub uploads: u64,
    /// Device's cumulative skip count after this round.
    pub skips: u64,
    /// Serialized wire-v2 upload, absent when the device skipped.
    pub payload: Option<Vec<u8>>,
}

impl RoundResult {
    /// Content digest (FNV-1a 64 over the encoded body) used by the
    /// rejoin handshake: a reconnecting client XOR-folds the digests of
    /// its cached results so the coordinator can tell whether what it
    /// already staged matches what the client would resend. XOR makes
    /// the fold order-independent, matching the per-device staging
    /// model where arrival order never matters.
    pub fn digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(PRIME);
            }
        };
        // Same canonical field order as `encode_body`, so the digest is
        // a pure function of the bytes that travel.
        eat(&self.round.to_le_bytes());
        eat(&self.device.to_le_bytes());
        eat(&self.loss.to_le_bytes());
        eat(&[u8::from(self.level.is_some()), self.level.unwrap_or(0)]);
        eat(&self.uploads.to_le_bytes());
        eat(&self.skips.to_le_bytes());
        match &self.payload {
            Some(bytes) => {
                eat(&[1]);
                eat(&(bytes.len() as u32).to_le_bytes());
                eat(bytes);
            }
            None => eat(&[0]),
        }
        h
    }
}

/// The coordinator's reply to a [`Message::Rejoin`]: the range the
/// client holds, the round the run is currently in, and which of the
/// client's devices already have a staged result this round (so the
/// client resends only what is missing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RejoinAck {
    /// The client index being re-admitted (echoes the rejoin).
    pub client_id: u32,
    /// First device id in the client's contiguous range.
    pub device_lo: u32,
    /// Number of devices in the range.
    pub device_count: u32,
    /// The coordinator's current round (the horizon `K` itself when
    /// the run already finished).
    pub round: u32,
    /// Device ids in the client's range whose round results are
    /// already staged for `round`; the client must not resend these.
    pub staged: Vec<u32>,
}

/// One protocol message (see the module docs for direction and flow).
#[derive(Clone, Debug)]
pub enum Message {
    /// Client hello: claim a device range.
    Rendezvous {
        /// Must equal [`super::PROTOCOL_VERSION`].
        version: u16,
        /// Devices requested; 0 = accept the coordinator's share.
        want: u32,
    },
    /// Liveness beacon; the coordinator answers with [`Message::State`].
    Heartbeat,
    /// Per-device round outcome.
    RoundResult(RoundResult),
    /// Reconnect hello: a client that already holds live device state
    /// for this run reclaims its range and offers a digest of the
    /// results it cached for `round`, so the coordinator can dedupe
    /// replays instead of double-counting.
    Rejoin {
        /// The client index originally assigned by [`Welcome`].
        client_id: u32,
        /// The round the client's cached results belong to (0 when it
        /// has none).
        round: u32,
        /// XOR fold of [`RoundResult::digest`] over the cached
        /// results (0 when none).
        result_digest: u64,
    },
    /// Rendezvous accepted; device range assigned.
    Welcome(Welcome),
    /// Heartbeat reply carrying the coordinator state.
    State(CoordinatorState),
    /// Round begins: context + model broadcast.
    StartRound(Box<StartRound>),
    /// Round complete; announces the next state.
    EndRound {
        /// The round that just completed.
        round: u32,
        /// Its global training loss (diagnostic; clients display it).
        train_loss: f64,
        /// State the coordinator moves to.
        state: CoordinatorState,
    },
    /// Rejoin accepted; tells the client where the run is and what it
    /// must not resend.
    RejoinAck(RejoinAck),
}

impl Message {
    /// This message's frame kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            Message::Rendezvous { .. } => kind::RENDEZVOUS,
            Message::Heartbeat => kind::HEARTBEAT,
            Message::RoundResult(_) => kind::ROUND_RESULT,
            Message::Rejoin { .. } => kind::REJOIN,
            Message::Welcome(_) => kind::WELCOME,
            Message::State(_) => kind::STATE,
            Message::StartRound(_) => kind::START_ROUND,
            Message::EndRound { .. } => kind::END_ROUND,
            Message::RejoinAck(_) => kind::REJOIN_ACK,
        }
    }

    /// Serialize the message body (frame kind excluded) into `out`,
    /// which is cleared first.
    pub fn encode_body(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            Message::Rendezvous { version, want } => {
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&want.to_le_bytes());
            }
            Message::Heartbeat => {}
            Message::RoundResult(r) => {
                out.extend_from_slice(&r.round.to_le_bytes());
                out.extend_from_slice(&r.device.to_le_bytes());
                out.extend_from_slice(&r.loss.to_le_bytes());
                out.push(u8::from(r.level.is_some()));
                out.push(r.level.unwrap_or(0));
                out.extend_from_slice(&r.uploads.to_le_bytes());
                out.extend_from_slice(&r.skips.to_le_bytes());
                match &r.payload {
                    Some(bytes) => {
                        out.push(1);
                        out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                        out.extend_from_slice(bytes);
                    }
                    None => out.push(0),
                }
            }
            Message::Rejoin {
                client_id,
                round,
                result_digest,
            } => {
                out.extend_from_slice(&client_id.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&result_digest.to_le_bytes());
            }
            Message::Welcome(w) => {
                out.extend_from_slice(&w.client_id.to_le_bytes());
                out.extend_from_slice(&w.device_lo.to_le_bytes());
                out.extend_from_slice(&w.device_count.to_le_bytes());
                out.extend_from_slice(&w.num_devices.to_le_bytes());
                out.extend_from_slice(&w.rounds.to_le_bytes());
                out.extend_from_slice(&w.seed.to_le_bytes());
            }
            Message::State(s) => encode_state(*s, out),
            Message::StartRound(sr) => {
                let ctx = &sr.ctx;
                out.extend_from_slice(&(ctx.round as u32).to_le_bytes());
                out.extend_from_slice(&(ctx.num_devices as u32).to_le_bytes());
                out.extend_from_slice(&ctx.alpha.to_le_bytes());
                out.extend_from_slice(&ctx.beta.to_le_bytes());
                out.extend_from_slice(&ctx.model_diff_sq.to_le_bytes());
                out.extend_from_slice(&ctx.init_loss.to_le_bytes());
                out.extend_from_slice(&ctx.prev_loss.to_le_bytes());
                let flags = u8::from(ctx.marina_sync) | (u8::from(ctx.selected.is_some()) << 1);
                out.push(flags);
                out.push(ctx.dadaquant_level);
                out.extend_from_slice(&(ctx.model_diff_history.len() as u32).to_le_bytes());
                for &h in &ctx.model_diff_history {
                    out.extend_from_slice(&h.to_le_bytes());
                }
                if let Some(sel) = &ctx.selected {
                    out.extend_from_slice(&(sel.len() as u32).to_le_bytes());
                    for &i in sel {
                        out.extend_from_slice(&(i as u32).to_le_bytes());
                    }
                }
                out.extend_from_slice(&(sr.theta.len() as u32).to_le_bytes());
                for &t in &sr.theta {
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
            Message::EndRound {
                round,
                train_loss,
                state,
            } => {
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&train_loss.to_le_bytes());
                encode_state(*state, out);
            }
            Message::RejoinAck(a) => {
                out.extend_from_slice(&a.client_id.to_le_bytes());
                out.extend_from_slice(&a.device_lo.to_le_bytes());
                out.extend_from_slice(&a.device_count.to_le_bytes());
                out.extend_from_slice(&a.round.to_le_bytes());
                out.extend_from_slice(&(a.staged.len() as u32).to_le_bytes());
                for &d in &a.staged {
                    out.extend_from_slice(&d.to_le_bytes());
                }
            }
        }
    }

    /// Decode a message from a frame's kind byte and body. Total:
    /// malformed input yields a typed error, never a panic, and
    /// trailing bytes are rejected (a length-confused peer must not
    /// half-parse).
    pub fn decode(kind_byte: u8, body: &[u8]) -> Result<Message, ProtocolError> {
        let mut r = Reader::new(body);
        let msg = match kind_byte {
            kind::RENDEZVOUS => Message::Rendezvous {
                version: r.u16()?,
                want: r.u32()?,
            },
            kind::HEARTBEAT => Message::Heartbeat,
            kind::ROUND_RESULT => {
                let round = r.u32()?;
                let device = r.u32()?;
                let loss = r.f64()?;
                let has_level = r.flag()?;
                let level_byte = r.u8()?;
                let level = has_level.then_some(level_byte);
                let uploads = r.u64()?;
                let skips = r.u64()?;
                let payload = if r.flag()? {
                    let len = r.u32()? as usize;
                    let bytes = r.bytes(len)?.to_vec();
                    // Validate the embedded upload now, composing wire
                    // failures into the protocol error at the message
                    // boundary — downstream folding may then trust it.
                    wire::view(&bytes)?;
                    Some(bytes)
                } else {
                    None
                };
                Message::RoundResult(RoundResult {
                    round,
                    device,
                    loss,
                    level,
                    uploads,
                    skips,
                    payload,
                })
            }
            kind::REJOIN => Message::Rejoin {
                client_id: r.u32()?,
                round: r.u32()?,
                result_digest: r.u64()?,
            },
            kind::WELCOME => Message::Welcome(Welcome {
                client_id: r.u32()?,
                device_lo: r.u32()?,
                device_count: r.u32()?,
                num_devices: r.u32()?,
                rounds: r.u32()?,
                seed: r.u64()?,
            }),
            kind::STATE => Message::State(decode_state(&mut r)?),
            kind::START_ROUND => {
                let round = r.u32()? as usize;
                let num_devices = r.u32()? as usize;
                let alpha = r.f32()?;
                let beta = r.f32()?;
                let model_diff_sq = r.f64()?;
                let init_loss = r.f64()?;
                let prev_loss = r.f64()?;
                let flags = r.u8()?;
                if flags & !0b11 != 0 {
                    return Err(ProtocolError::Malformed("start-round flags"));
                }
                let dadaquant_level = r.u8()?;
                let hist_len = r.checked_len("diff history")?;
                let mut model_diff_history = Vec::with_capacity(hist_len);
                for _ in 0..hist_len {
                    model_diff_history.push(r.f64()?);
                }
                let selected = if flags & 0b10 != 0 {
                    let n = r.checked_len("selection list")?;
                    let mut sel = Vec::with_capacity(n);
                    for _ in 0..n {
                        sel.push(r.u32()? as usize);
                    }
                    Some(sel)
                } else {
                    None
                };
                let theta_len = r.checked_len("theta")?;
                let mut theta = Vec::with_capacity(theta_len);
                for _ in 0..theta_len {
                    theta.push(r.f32()?);
                }
                Message::StartRound(Box::new(StartRound {
                    ctx: RoundCtx {
                        round,
                        num_devices,
                        alpha,
                        beta,
                        model_diff_sq,
                        model_diff_history,
                        init_loss,
                        prev_loss,
                        marina_sync: flags & 0b01 != 0,
                        selected,
                        dadaquant_level,
                    },
                    theta,
                }))
            }
            kind::END_ROUND => Message::EndRound {
                round: r.u32()?,
                train_loss: r.f64()?,
                state: decode_state(&mut r)?,
            },
            kind::REJOIN_ACK => {
                let client_id = r.u32()?;
                let device_lo = r.u32()?;
                let device_count = r.u32()?;
                let round = r.u32()?;
                let n = r.checked_len("staged list")?;
                let mut staged = Vec::with_capacity(n);
                for _ in 0..n {
                    staged.push(r.u32()?);
                }
                Message::RejoinAck(RejoinAck {
                    client_id,
                    device_lo,
                    device_count,
                    round,
                    staged,
                })
            }
            other => return Err(ProtocolError::UnknownKind(other)),
        };
        if r.remaining() != 0 {
            return Err(ProtocolError::Malformed("trailing bytes"));
        }
        Ok(msg)
    }
}

fn encode_state(s: CoordinatorState, out: &mut Vec<u8>) {
    let (tag, round) = match s {
        CoordinatorState::Standby => (0u8, 0u32),
        CoordinatorState::Round(k) => (1, k),
        CoordinatorState::Finished => (2, 0),
    };
    out.push(tag);
    out.extend_from_slice(&round.to_le_bytes());
}

fn decode_state(r: &mut Reader<'_>) -> Result<CoordinatorState, ProtocolError> {
    let tag = r.u8()?;
    let round = r.u32()?;
    match tag {
        0 => Ok(CoordinatorState::Standby),
        1 => Ok(CoordinatorState::Round(round)),
        2 => Ok(CoordinatorState::Finished),
        _ => Err(ProtocolError::Malformed("state tag")),
    }
}

/// Bounds-checked little-endian reader over a message body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.remaining() < n {
            return Err(ProtocolError::Truncated {
                need: n,
                have: self.remaining(),
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.bytes(1)?[0])
    }

    /// A 0/1 boolean byte; anything else is malformed (a corrupted
    /// flag must not silently decode as `true`).
    fn flag(&mut self) -> Result<bool, ProtocolError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(ProtocolError::Malformed("flag byte")),
        }
    }

    fn u16(&mut self) -> Result<u16, ProtocolError> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32, ProtocolError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, ProtocolError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A `u32` element count that must be coverable by the bytes still
    /// in the body (each element is at least one byte), so a hostile
    /// length cannot drive `Vec::with_capacity` beyond the frame size.
    fn checked_len(&mut self, what: &'static str) -> Result<usize, ProtocolError> {
        let n = self.u32()? as usize;
        if n > self.remaining() {
            return Err(ProtocolError::Malformed(what));
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &Message) -> Message {
        let mut body = Vec::new();
        msg.encode_body(&mut body);
        Message::decode(msg.kind(), &body).expect("round trip decodes")
    }

    #[test]
    fn rendezvous_and_heartbeat() {
        match round_trip(&Message::Rendezvous { version: 1, want: 4 }) {
            Message::Rendezvous { version, want } => {
                assert_eq!((version, want), (1, 4));
            }
            other => panic!("wrong decode: {other:?}"),
        }
        assert!(matches!(round_trip(&Message::Heartbeat), Message::Heartbeat));
    }

    #[test]
    fn round_result_with_payload() {
        use crate::quant::midtread::quantize;
        use crate::transport::wire::Payload;
        let p = Payload::MidtreadDelta(quantize(&[0.5, -1.0, 2.0, 0.0], 4));
        let bytes = wire::encode(&p);
        let msg = Message::RoundResult(RoundResult {
            round: 3,
            device: 7,
            loss: 0.125,
            level: Some(4),
            uploads: 2,
            skips: 1,
            payload: Some(bytes.clone()),
        });
        match round_trip(&msg) {
            Message::RoundResult(r) => {
                assert_eq!(r.payload.as_deref(), Some(bytes.as_slice()));
                assert_eq!((r.round, r.device, r.level), (3, 7, Some(4)));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn round_result_rejects_bad_embedded_payload() {
        let msg = Message::RoundResult(RoundResult {
            round: 0,
            device: 0,
            loss: 0.0,
            level: None,
            uploads: 0,
            skips: 0,
            payload: Some(vec![0xFF; 12]),
        });
        let mut body = Vec::new();
        msg.encode_body(&mut body);
        let err = Message::decode(kind::ROUND_RESULT, &body);
        assert!(matches!(err, Err(ProtocolError::Wire(_))));
    }

    #[test]
    fn start_round_ctx_is_bit_exact() {
        let ctx = RoundCtx {
            round: 5,
            num_devices: 10,
            alpha: 0.1,
            beta: 0.25,
            model_diff_sq: 1.5e-3,
            model_diff_history: vec![1.0, 0.5, 0.25],
            init_loss: 2.3,
            prev_loss: 1.1,
            marina_sync: true,
            selected: Some(vec![1, 4, 9]),
            dadaquant_level: 6,
        };
        let msg = Message::StartRound(Box::new(StartRound {
            ctx: ctx.clone(),
            theta: vec![0.25, -0.5, f32::MIN_POSITIVE],
        }));
        match round_trip(&msg) {
            Message::StartRound(sr) => {
                assert_eq!(sr.ctx.round, ctx.round);
                assert_eq!(sr.ctx.selected, ctx.selected);
                assert_eq!(sr.ctx.marina_sync, ctx.marina_sync);
                assert_eq!(sr.ctx.model_diff_sq.to_bits(), ctx.model_diff_sq.to_bits());
                assert_eq!(sr.ctx.model_diff_history, ctx.model_diff_history);
                assert_eq!(sr.theta[2].to_bits(), f32::MIN_POSITIVE.to_bits());
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn state_and_end_round() {
        for s in [
            CoordinatorState::Standby,
            CoordinatorState::Round(17),
            CoordinatorState::Finished,
        ] {
            match round_trip(&Message::State(s)) {
                Message::State(got) => assert_eq!(got, s),
                other => panic!("wrong decode: {other:?}"),
            }
        }
        match round_trip(&Message::EndRound {
            round: 9,
            train_loss: 0.75,
            state: CoordinatorState::Finished,
        }) {
            Message::EndRound { round, state, .. } => {
                assert_eq!(round, 9);
                assert_eq!(state, CoordinatorState::Finished);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn decode_never_panics_on_garbage() {
        let kinds = [
            kind::RENDEZVOUS,
            kind::HEARTBEAT,
            kind::ROUND_RESULT,
            kind::WELCOME,
            kind::STATE,
            kind::START_ROUND,
            kind::END_ROUND,
            kind::REJOIN,
            kind::REJOIN_ACK,
            0x00,
            0x7F,
            0xFF,
        ];
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(11);
        for k in kinds {
            for len in [0usize, 1, 4, 17, 64, 257] {
                let body: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
                let _ = Message::decode(k, &body);
            }
        }
    }

    #[test]
    fn rejoin_round_trips() {
        match round_trip(&Message::Rejoin {
            client_id: 2,
            round: 5,
            result_digest: 0xDEAD_BEEF_0123_4567,
        }) {
            Message::Rejoin {
                client_id,
                round,
                result_digest,
            } => {
                assert_eq!(
                    (client_id, round, result_digest),
                    (2, 5, 0xDEAD_BEEF_0123_4567)
                );
            }
            other => panic!("wrong decode: {other:?}"),
        }
        let ack = RejoinAck {
            client_id: 1,
            device_lo: 2,
            device_count: 2,
            round: 7,
            staged: vec![2, 3],
        };
        match round_trip(&Message::RejoinAck(ack.clone())) {
            Message::RejoinAck(got) => assert_eq!(got, ack),
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn digest_tracks_content_and_is_order_free_under_xor() {
        let base = RoundResult {
            round: 3,
            device: 7,
            loss: 0.125,
            level: Some(4),
            uploads: 2,
            skips: 1,
            payload: None,
        };
        let mut other = base.clone();
        other.device = 8;
        assert_eq!(base.digest(), base.clone().digest());
        assert_ne!(base.digest(), other.digest(), "digest sees the device id");
        let mut tweaked = base.clone();
        tweaked.loss = 0.25;
        assert_ne!(base.digest(), tweaked.digest(), "digest sees the loss bits");
        // XOR-fold is arrival-order independent, like staging itself.
        let digests = [base.digest(), other.digest(), tweaked.digest()];
        let fwd = digests.iter().fold(0u64, |acc, d| acc ^ d);
        let rev = digests.iter().rev().fold(0u64, |acc, d| acc ^ d);
        assert_eq!(fwd, rev, "xor fold ignores arrival order");
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut body = Vec::new();
        Message::Heartbeat.encode_body(&mut body);
        body.push(0);
        assert!(matches!(
            Message::decode(kind::HEARTBEAT, &body),
            Err(ProtocolError::Malformed("trailing bytes"))
        ));
    }
}
