//! Protocol transports: one [`Transport`] trait, two carriers.
//!
//! * [`TcpTransport`] / [`TcpConnection`] — std-only TCP with
//!   per-message read/write timeouts. The receive path assembles
//!   frames incrementally ([`super::frame::FrameReader`]), so a read
//!   timeout mid-frame never desynchronizes the stream.
//! * [`LoopbackHub`] / [`LoopbackConnection`] — an in-process duplex
//!   pair over `Mutex<VecDeque>` + `Condvar` queues, so every protocol
//!   test (and the CI service example) runs deterministically with no
//!   sockets at all. The loopback carries the same [`Frame`]s the TCP
//!   byte stream does — tests can inject raw malformed frames with
//!   [`LoopbackConnection::send_raw`].
//!
//! Connections are split across threads with [`Connection::try_clone`]:
//! the coordinator gives each client a reader thread (blocking `recv`)
//! while the service loop keeps the writer half. One clone must own
//! each direction — the trait does not arbitrate concurrent readers.

use super::frame::{self, Frame, FrameReader};
use super::messages::Message;
use super::ProtocolError;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One end of a protocol conversation: framed, typed, timeout-bounded.
pub trait Connection: Send {
    /// Send one message (blocking, bounded by the transport's write
    /// timeout).
    fn send(&mut self, msg: &Message) -> Result<(), ProtocolError>;

    /// Receive the next message, waiting at most `timeout`. Returns
    /// [`ProtocolError::Timeout`] if none arrives in the window — the
    /// connection stays usable and a partially received frame resumes
    /// on the next call.
    fn recv(&mut self, timeout: Duration) -> Result<Message, ProtocolError>;

    /// A second handle on the same connection, for splitting the read
    /// and write directions across threads.
    fn try_clone(&self) -> Result<Box<dyn Connection>, ProtocolError>;

    /// Send one raw frame (kind byte + body verbatim), bypassing the
    /// message encoder. The fault-injection layer
    /// ([`super::chaos::ChaosConnection`]) uses this to put corrupted
    /// or truncated frames on the wire; ordinary protocol code never
    /// needs it.
    fn send_raw_frame(&mut self, kind: u8, body: &[u8]) -> Result<(), ProtocolError>;
}

/// Client side of a transport: a factory for fresh connections to one
/// coordinator. This is the unit of reconnection —
/// [`super::DeviceClient::run_with`] redials through it after a
/// connection dies.
pub trait Dial: Send + Sync {
    /// Open a new connection to the coordinator.
    fn dial(&self) -> Result<Box<dyn Connection>, ProtocolError>;
}

/// Server side of a transport: yields one [`Connection`] per client.
pub trait Transport: Send {
    /// Accept the next incoming connection, waiting at most `timeout`.
    fn accept(&mut self, timeout: Duration) -> Result<Box<dyn Connection>, ProtocolError>;
}

// ---------------------------------------------------------------- TCP

/// Map an i/o failure from a timed read/write: `WouldBlock`/`TimedOut`
/// become the typed [`ProtocolError::Timeout`], everything else stays
/// an i/o error.
fn io_err(e: std::io::Error) -> ProtocolError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ProtocolError::Timeout,
        _ => ProtocolError::Io(e),
    }
}

/// Dial failures worth retrying while the connect window is open: the
/// listener may not have bound yet, or the accept backlog hiccuped.
fn dial_retryable(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::ConnectionRefused
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::Interrupted
    )
}

/// TCP listener implementing [`Transport`].
pub struct TcpTransport {
    listener: TcpListener,
}

impl TcpTransport {
    /// Bind the listener (non-blocking accept; [`Transport::accept`]
    /// polls it against its timeout).
    pub fn bind(addr: &str) -> Result<Self, ProtocolError> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Self { listener })
    }

    /// The bound address (port 0 binds resolve to a real port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr, ProtocolError> {
        Ok(self.listener.local_addr()?)
    }
}

impl Transport for TcpTransport {
    fn accept(&mut self, timeout: Duration) -> Result<Box<dyn Connection>, ProtocolError> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => return Ok(Box::new(TcpConnection::from_stream(stream)?)),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(ProtocolError::Timeout);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(ProtocolError::Io(e)),
            }
        }
    }
}

/// A framed TCP connection with per-message timeouts.
pub struct TcpConnection {
    stream: TcpStream,
    reader: FrameReader,
    write_buf: Vec<u8>,
    body_buf: Vec<u8>,
    write_timeout: Duration,
}

impl TcpConnection {
    /// Default bound on a single blocking send.
    const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

    /// Connect to a coordinator at `addr`, waiting at most `timeout`
    /// for the TCP handshake.
    ///
    /// A refused or reset dial retries with a short growing backoff
    /// inside the `timeout` window instead of failing permanently —
    /// the listener may simply not be up yet (a client started before
    /// the coordinator binds still rendezvouses). Only when the window
    /// closes does the attempt surface as [`ProtocolError::Timeout`].
    pub fn connect(addr: &str, timeout: Duration) -> Result<Self, ProtocolError> {
        use std::net::ToSocketAddrs;
        let sock = addr
            .to_socket_addrs()?
            .next()
            .ok_or(ProtocolError::Malformed("address resolves to nothing"))?;
        let deadline = Instant::now() + timeout;
        let mut pause = Duration::from_millis(10);
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            match TcpStream::connect_timeout(&sock, remaining) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) if dial_retryable(e.kind()) => {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    std::thread::sleep(pause.min(remaining));
                    pause = (pause * 2).min(Duration::from_millis(500));
                }
                Err(e) => return Err(io_err(e)),
            }
        }
    }

    fn from_stream(stream: TcpStream) -> Result<Self, ProtocolError> {
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true)?;
        stream.set_write_timeout(Some(Self::WRITE_TIMEOUT))?;
        Ok(Self {
            stream,
            reader: FrameReader::new(),
            write_buf: Vec::new(),
            body_buf: Vec::new(),
            write_timeout: Self::WRITE_TIMEOUT,
        })
    }
}

impl Connection for TcpConnection {
    fn send(&mut self, msg: &Message) -> Result<(), ProtocolError> {
        msg.encode_body(&mut self.body_buf);
        self.write_buf.clear();
        frame::encode_frame(msg.kind(), &self.body_buf, &mut self.write_buf);
        self.stream.set_write_timeout(Some(self.write_timeout))?;
        self.stream.write_all(&self.write_buf).map_err(io_err)?;
        self.stream.flush().map_err(io_err)
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, ProtocolError> {
        let deadline = Instant::now() + timeout;
        let mut chunk = [0u8; 64 * 1024];
        loop {
            // Read at most what the current frame still needs, so a
            // chunk never crosses a frame boundary and no bytes are
            // buffered outside the assembler.
            let want = self.reader.wanted().min(chunk.len());
            let remaining = deadline.saturating_duration_since(Instant::now());
            // A zero read timeout means "no timeout" to the OS; clamp
            // so an expired deadline still gets one short poll.
            self.stream
                .set_read_timeout(Some(remaining.max(Duration::from_millis(1))))?;
            match self.stream.read(&mut chunk[..want]) {
                Ok(0) => return Err(ProtocolError::Closed),
                Ok(n) => {
                    if let Some(f) = self.reader.consume(&chunk[..n])? {
                        return Message::decode(f.kind, &f.body);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    let e = io_err(e);
                    if !matches!(e, ProtocolError::Timeout) || Instant::now() >= deadline {
                        return Err(e);
                    }
                }
            }
        }
    }

    fn try_clone(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        Ok(Box::new(Self {
            stream: self.stream.try_clone()?,
            reader: FrameReader::new(),
            write_buf: Vec::new(),
            body_buf: Vec::new(),
            write_timeout: self.write_timeout,
        }))
    }

    fn send_raw_frame(&mut self, kind: u8, body: &[u8]) -> Result<(), ProtocolError> {
        self.write_buf.clear();
        frame::encode_frame(kind, body, &mut self.write_buf);
        self.stream.set_write_timeout(Some(self.write_timeout))?;
        self.stream.write_all(&self.write_buf).map_err(io_err)?;
        self.stream.flush().map_err(io_err)
    }
}

/// Client-side factory for [`TcpConnection`]s — [`Dial`] over TCP.
pub struct TcpDialer {
    addr: String,
    timeout: Duration,
}

impl TcpDialer {
    /// A dialer for `addr`, bounding each dial attempt by `timeout`.
    pub fn new(addr: impl Into<String>, timeout: Duration) -> Self {
        Self {
            addr: addr.into(),
            timeout,
        }
    }
}

impl Dial for TcpDialer {
    fn dial(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        Ok(Box::new(TcpConnection::connect(&self.addr, self.timeout)?))
    }
}

// ----------------------------------------------------------- loopback

/// One direction of a loopback pair: a closable frame queue.
struct FrameQueue {
    state: Mutex<(VecDeque<Frame>, bool)>,
    cv: Condvar,
}

impl FrameQueue {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        })
    }

    fn push(&self, frame: Frame) -> Result<(), ProtocolError> {
        let mut st = self.state.lock().expect("loopback queue poisoned");
        if st.1 {
            return Err(ProtocolError::Closed);
        }
        st.0.push_back(frame);
        self.cv.notify_all();
        Ok(())
    }

    fn pop(&self, timeout: Duration) -> Result<Frame, ProtocolError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("loopback queue poisoned");
        loop {
            if let Some(f) = st.0.pop_front() {
                return Ok(f);
            }
            if st.1 {
                return Err(ProtocolError::Closed);
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            let (guard, _) = self
                .cv
                .wait_timeout(st, remaining)
                .expect("loopback queue poisoned");
            st = guard;
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().expect("loopback queue poisoned");
        st.1 = true;
        self.cv.notify_all();
    }
}

/// Closes both queue directions when the last clone of *one* end
/// drops, so a peer blocked in `recv` drains what was already queued
/// and then wakes with [`ProtocolError::Closed`] instead of waiting
/// out its timeout. Each end of a pair owns its own token.
struct CloseToken {
    a: Arc<FrameQueue>,
    b: Arc<FrameQueue>,
}

impl Drop for CloseToken {
    fn drop(&mut self) {
        self.a.close();
        self.b.close();
    }
}

/// In-process duplex connection end (see [`LoopbackHub`]).
pub struct LoopbackConnection {
    tx: Arc<FrameQueue>,
    rx: Arc<FrameQueue>,
    body_buf: Vec<u8>,
    _token: Arc<CloseToken>,
}

impl LoopbackConnection {
    /// A connected pair of ends (no hub involved — direct tests).
    pub fn pair() -> (Self, Self) {
        let ab = FrameQueue::new();
        let ba = FrameQueue::new();
        let left = Self {
            tx: ab.clone(),
            rx: ba.clone(),
            body_buf: Vec::new(),
            _token: Arc::new(CloseToken {
                a: ab.clone(),
                b: ba.clone(),
            }),
        };
        let right = Self {
            tx: ba.clone(),
            rx: ab.clone(),
            body_buf: Vec::new(),
            _token: Arc::new(CloseToken { a: ab, b: ba }),
        };
        (left, right)
    }

    /// Inject a raw frame, bypassing the message encoder — the
    /// conformance suite uses this to feed the coordinator malformed
    /// and unknown-kind frames.
    pub fn send_raw(&self, kind: u8, body: Vec<u8>) -> Result<(), ProtocolError> {
        self.tx.push(Frame { kind, body })
    }
}

impl Connection for LoopbackConnection {
    fn send(&mut self, msg: &Message) -> Result<(), ProtocolError> {
        msg.encode_body(&mut self.body_buf);
        self.tx.push(Frame {
            kind: msg.kind(),
            body: self.body_buf.clone(),
        })
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, ProtocolError> {
        let f = self.rx.pop(timeout)?;
        Message::decode(f.kind, &f.body)
    }

    fn try_clone(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        Ok(Box::new(Self {
            tx: self.tx.clone(),
            rx: self.rx.clone(),
            body_buf: Vec::new(),
            _token: self._token.clone(),
        }))
    }

    fn send_raw_frame(&mut self, kind: u8, body: &[u8]) -> Result<(), ProtocolError> {
        self.tx.push(Frame {
            kind,
            body: body.to_vec(),
        })
    }
}

/// In-process transport: clients dial the hub, the coordinator
/// accepts — same protocol flow as TCP, zero sockets, fully
/// deterministic for CI.
pub struct LoopbackHub {
    pending: Arc<(Mutex<VecDeque<LoopbackConnection>>, Condvar)>,
}

impl LoopbackHub {
    /// An empty hub.
    pub fn new() -> Self {
        Self {
            pending: Arc::new((Mutex::new(VecDeque::new()), Condvar::new())),
        }
    }

    /// A dialer handle for client threads.
    pub fn dialer(&self) -> LoopbackDialer {
        LoopbackDialer {
            pending: self.pending.clone(),
        }
    }
}

impl Default for LoopbackHub {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for LoopbackHub {
    fn accept(&mut self, timeout: Duration) -> Result<Box<dyn Connection>, ProtocolError> {
        let deadline = Instant::now() + timeout;
        let (lock, cv) = &*self.pending;
        let mut q = lock.lock().expect("loopback hub poisoned");
        loop {
            if let Some(conn) = q.pop_front() {
                return Ok(Box::new(conn));
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            let (guard, _) = cv
                .wait_timeout(q, remaining)
                .expect("loopback hub poisoned");
            q = guard;
        }
    }
}

/// Client-side handle on a [`LoopbackHub`].
#[derive(Clone)]
pub struct LoopbackDialer {
    pending: Arc<(Mutex<VecDeque<LoopbackConnection>>, Condvar)>,
}

impl LoopbackDialer {
    /// Open a new connection to the hub's coordinator.
    pub fn connect(&self) -> LoopbackConnection {
        let (client, server) = LoopbackConnection::pair();
        let (lock, cv) = &*self.pending;
        lock.lock().expect("loopback hub poisoned").push_back(server);
        cv.notify_all();
        client
    }
}

impl Dial for LoopbackDialer {
    fn dial(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        Ok(Box::new(self.connect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_round_trip_and_close() {
        let (mut a, mut b) = LoopbackConnection::pair();
        a.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            b.recv(Duration::from_millis(100)).unwrap(),
            Message::Heartbeat
        ));
        assert!(matches!(
            b.recv(Duration::from_millis(10)),
            Err(ProtocolError::Timeout)
        ));
        drop(a);
        assert!(matches!(
            b.recv(Duration::from_millis(10)),
            Err(ProtocolError::Closed)
        ));
    }

    #[test]
    fn loopback_raw_injection_decodes_as_error() {
        let (a, mut b) = LoopbackConnection::pair();
        a.send_raw(0xEE, vec![1, 2, 3]).unwrap();
        assert!(matches!(
            b.recv(Duration::from_millis(100)),
            Err(ProtocolError::UnknownKind(0xEE))
        ));
    }

    #[test]
    fn hub_accepts_dialed_connections() {
        let mut hub = LoopbackHub::new();
        let dialer = hub.dialer();
        assert!(matches!(
            hub.accept(Duration::from_millis(10)),
            Err(ProtocolError::Timeout)
        ));
        let mut client = dialer.connect();
        let mut server = hub.accept(Duration::from_millis(100)).unwrap();
        client
            .send(&Message::Rendezvous { version: 1, want: 0 })
            .unwrap();
        assert!(matches!(
            server.recv(Duration::from_millis(100)).unwrap(),
            Message::Rendezvous { version: 1, want: 0 }
        ));
        server.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            client.recv(Duration::from_millis(100)).unwrap(),
            Message::Heartbeat
        ));
    }

    #[test]
    fn send_raw_frame_matches_inherent_injection() {
        let (mut a, mut b) = LoopbackConnection::pair();
        Connection::send_raw_frame(&mut a, 0xEE, &[1, 2, 3]).unwrap();
        assert!(matches!(
            b.recv(Duration::from_millis(100)),
            Err(ProtocolError::UnknownKind(0xEE))
        ));
    }

    #[test]
    fn loopback_dialer_implements_dial() {
        let mut hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let mut client = dialer.dial().expect("loopback dial cannot fail");
        let mut server = hub.accept(Duration::from_millis(100)).unwrap();
        client.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            server.recv(Duration::from_millis(100)).unwrap(),
            Message::Heartbeat
        ));
    }

    #[test]
    fn tcp_connect_retries_until_listener_binds() {
        // Reserve a port, free it, and bind it back only after the
        // client has already started dialing: the refused dials must
        // retry inside the timeout window instead of failing outright.
        let probe = TcpListener::bind("127.0.0.1:0").expect("probe bind");
        let addr = probe.local_addr().expect("probe addr").to_string();
        drop(probe);
        let dial_addr = addr.clone();
        let handle = std::thread::spawn(move || {
            TcpConnection::connect(&dial_addr, Duration::from_secs(10)).map(|_| ())
        });
        std::thread::sleep(Duration::from_millis(150));
        let mut transport = TcpTransport::bind(&addr).expect("late bind");
        let accepted = transport.accept(Duration::from_secs(10));
        assert!(accepted.is_ok(), "late-bound listener sees the dial");
        handle
            .join()
            .expect("dial thread")
            .expect("dial succeeds after listener appears");
    }

    #[test]
    fn tcp_connect_times_out_when_nothing_binds() {
        let probe = TcpListener::bind("127.0.0.1:0").expect("probe bind");
        let addr = probe.local_addr().expect("probe addr").to_string();
        drop(probe);
        let t0 = Instant::now();
        let err = TcpConnection::connect(&addr, Duration::from_millis(200));
        assert!(matches!(err, Err(ProtocolError::Timeout)));
        assert!(t0.elapsed() >= Duration::from_millis(150), "window honored");
    }

    #[test]
    fn tcp_round_trip_with_timeouts() {
        let mut transport = TcpTransport::bind("127.0.0.1:0").expect("bind");
        let addr = transport.local_addr().expect("addr").to_string();
        let handle = std::thread::spawn(move || {
            let mut client =
                TcpConnection::connect(&addr, Duration::from_secs(5)).expect("connect");
            client
                .send(&Message::Rendezvous { version: 1, want: 2 })
                .unwrap();
            match client.recv(Duration::from_secs(5)).unwrap() {
                Message::State(s) => s,
                other => panic!("wrong reply: {other:?}"),
            }
        });
        let mut server = transport.accept(Duration::from_secs(5)).expect("accept");
        assert!(matches!(
            server.recv(Duration::from_secs(5)).unwrap(),
            Message::Rendezvous { version: 1, want: 2 }
        ));
        // No second message in flight: recv times out cleanly...
        assert!(matches!(
            server.recv(Duration::from_millis(20)),
            Err(ProtocolError::Timeout)
        ));
        // ...and the stream still carries the next frame intact.
        server
            .send(&Message::State(super::super::CoordinatorState::Standby))
            .unwrap();
        let got = handle.join().expect("client thread");
        assert_eq!(got, super::super::CoordinatorState::Standby);
    }
}
