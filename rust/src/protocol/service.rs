//! The coordinator side of the protocol: an owned
//! [`crate::coordinator::Session`] driven over a [`Transport`].
//!
//! [`CoordinatorService::run`] walks the coordinator state machine:
//! standby (accept + rendezvous until every device range is claimed),
//! then one `Round(k)` per configured round — broadcast
//! [`Message::StartRound`], collect [`Message::RoundResult`]s into the
//! engine's staging slots, close the round — then `Finished`.
//!
//! Liveness is heartbeat-based: each client gets a reader thread whose
//! receive timeout is the heartbeat window, so a client silent that
//! long (crashed, hung, or partitioned) is declared dead and its
//! unreported devices are folded as skips and counted as stragglers —
//! the protocol analogue of the channel simulation's deadline
//! stragglers, steered by the same
//! [`crate::transport::scenario::StragglerPolicy`]: `AdmitLate` grants
//! one extra heartbeat window past the round deadline, `Drop` does not.
//!
//! Failure is recoverable, not just tolerated (DESIGN.md §Fault
//! model). While any slot is dead the service polls `accept` for
//! reconnecting clients; a [`Message::Rejoin`] mid-round reclaims the
//! client's slot, and the per-round digest book decides whether what
//! is already staged matches what the client would resend (keep it)
//! or must be unstaged and collected again (resync) — either way no
//! result is ever folded twice. A client that dies mid-round has its
//! staged partial uploads cleared on retirement, so a later rejoin
//! cannot leave a stale half-round in the fold. With
//! [`CoordinatorService::checkpoint_to`] the service stamps its
//! serve-state onto periodic engine snapshots; a killed coordinator
//! restarted with [`CoordinatorService::resume_from`] re-enters
//! `Round(n)` and waiting clients rejoin in standby.
//!
//! Determinism: results are staged per device id and folded in device
//! order by the engine, so message arrival order, client count, and
//! transport choice cannot perturb the trace (see the module docs of
//! [`crate::protocol`]). Rejoined clients resend byte-identical cached
//! results, so reconnection preserves the guarantee.

use super::messages::{Message, RejoinAck, RoundResult, StartRound, Welcome};
use super::transport::{Connection, Transport};
use super::{CoordinatorState, ProtocolError, ServeSpec, PROTOCOL_VERSION};
use crate::coordinator::checkpoint::{Checkpoint, ServeState};
use crate::coordinator::engine::RoundEngine;
use crate::coordinator::{Session, SessionParts};
use crate::metrics::RunTrace;
use crate::transport::scenario::StragglerPolicy;
use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Accept-poll slice while a round is degraded (at least one dead
/// slot) and during standby, so heartbeats keep being answered between
/// polls.
const ACCEPT_POLL: Duration = Duration::from_millis(50);

/// Accept-poll slice inside the round collect loop — short, because
/// pending results should keep draining while we watch for rejoiners.
const REJOIN_POLL: Duration = Duration::from_millis(5);

/// Event-queue wait slice while a round is degraded; bounds how long a
/// freshly dialed rejoiner waits before the next accept poll.
const EVENT_POLL: Duration = Duration::from_millis(20);

/// Budget for a freshly accepted connection to identify itself
/// (rendezvous or rejoin) before it is dropped.
const HELLO_WINDOW: Duration = Duration::from_millis(1_000);

/// One client slot: the writer half of its current connection (if
/// any) plus the contiguous device range it computes. (The reader half
/// lives in a per-connection thread feeding the service's event
/// queue.) `gen` counts installed connections so events from a
/// superseded reader thread can be told apart from the current one.
struct ClientSlot {
    conn: Option<Box<dyn Connection>>,
    devices: Range<usize>,
    alive: bool,
    gen: u64,
}

impl ClientSlot {
    /// Send on the live connection; `false` when there is none or the
    /// send fails (the caller retires the slot).
    fn send(&mut self, msg: &Message) -> bool {
        match &mut self.conn {
            Some(conn) => conn.send(msg).is_ok(),
            None => false,
        }
    }
}

/// What the per-connection reader threads feed the service loop.
enum Event {
    /// A message from client `client_id`.
    Msg(usize, Message),
    /// The reader of connection generation `gen` saw an error or
    /// heartbeat-window silence.
    Dead(usize, u64),
}

/// Everything one round's collection tracks: which selected devices
/// still owe a result, which are currently unreachable (owner dead,
/// waiting for a rejoin), and the digest of every result staged so far
/// (the replay-dedup ledger the rejoin handshake checks against).
struct RoundBook {
    round: usize,
    pending: BTreeSet<usize>,
    lost: BTreeSet<usize>,
    staged: BTreeMap<usize, u64>,
}

/// Shared wiring every admission path needs: the event channel, the
/// reader-thread handles, and the reader liveness window.
struct Wiring<'a> {
    tx: &'a mpsc::Sender<Event>,
    readers: &'a mut Vec<JoinHandle<()>>,
    hb_timeout: Duration,
}

/// What standby tells a fresh client about the run.
struct HelloInfo {
    num_devices: usize,
    rounds: usize,
    seed: u64,
    start_round: usize,
}

/// Mark a client dead and release its connection. With a round book,
/// its pending devices move to `lost` (a rejoin can still rescue them
/// before the deadline) and its already-staged partial results are
/// cleared from the engine — a dead client's half-round must never
/// linger in the fold, or a later rejoin would double-count.
fn retire(c: &mut ClientSlot, engine: &mut RoundEngine, book: Option<&mut RoundBook>) {
    if !c.alive {
        return;
    }
    c.alive = false;
    c.conn = None;
    let Some(book) = book else { return };
    for d in c.devices.clone() {
        if book.pending.remove(&d) {
            book.lost.insert(d);
        }
        if book.staged.remove(&d).is_some() {
            engine.unstage(d);
            book.lost.insert(d);
        }
    }
}

/// Install a fresh connection into a slot: bump the generation, spawn
/// its reader thread, and mark the slot alive.
fn install(
    c: &mut ClientSlot,
    ci: usize,
    conn: Box<dyn Connection>,
    w: &mut Wiring<'_>,
) -> Result<(), ProtocolError> {
    let mut rd = conn.try_clone()?;
    c.gen += 1;
    let gen = c.gen;
    let tx = w.tx.clone();
    let hb_timeout = w.hb_timeout;
    w.readers.push(std::thread::spawn(move || loop {
        match rd.recv(hb_timeout) {
            Ok(msg) => {
                if tx.send(Event::Msg(ci, msg)).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = tx.send(Event::Dead(ci, gen));
                return;
            }
        }
    }));
    c.conn = Some(conn);
    c.alive = true;
    Ok(())
}

/// Stage one remote result if it belongs to this round, to the sending
/// client's device range, and is still owed (a misbehaving client
/// cannot write outside its assignment, replay an old round, or
/// double-report a device). The digest of what was folded is recorded
/// for the rejoin handshake.
fn stage(engine: &mut RoundEngine, devices: &Range<usize>, book: &mut RoundBook, r: RoundResult) {
    let d = r.device as usize;
    if r.round as usize != book.round || !devices.contains(&d) || !book.pending.remove(&d) {
        return;
    }
    let digest = r.digest();
    if engine.stage_remote(d, r.loss, r.level, r.payload.as_deref(), (r.uploads, r.skips)) {
        book.staged.insert(d, digest);
    }
}

/// Fold one reader-thread event into the current round.
fn handle_event(
    ev: Event,
    clients: &mut [ClientSlot],
    engine: &mut RoundEngine,
    book: &mut RoundBook,
) {
    match ev {
        Event::Dead(ci, gen) => {
            if clients[ci].gen == gen {
                retire(&mut clients[ci], engine, Some(book));
            }
        }
        Event::Msg(ci, Message::Heartbeat) => {
            let state = Message::State(CoordinatorState::Round(book.round as u32));
            let c = &mut clients[ci];
            if c.alive && !c.send(&state) {
                retire(c, engine, Some(book));
            }
        }
        Event::Msg(ci, Message::RoundResult(r)) => {
            stage(engine, &clients[ci].devices, book, r);
        }
        // Anything else out of order (a late rendezvous, a stale
        // result, a rejoin on an established connection) is ignored.
        Event::Msg(..) => {}
    }
}

/// Complete one standby admission on a fresh connection: tolerate
/// heartbeats, then either welcome a version-matched rendezvous into
/// the lowest free slot or re-admit a rejoining client into the slot
/// it names (a resumed coordinator's standby is all rejoins). Anything
/// else drops the connection without consuming a slot.
fn admit_standby(
    mut conn: Box<dyn Connection>,
    clients: &mut [ClientSlot],
    hello: &HelloInfo,
    w: &mut Wiring<'_>,
) {
    let deadline = Instant::now() + HELLO_WINDOW;
    let claim = loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return;
        }
        match conn.recv(remaining) {
            Ok(Message::Heartbeat) => {
                if conn.send(&Message::State(CoordinatorState::Standby)).is_err() {
                    return;
                }
            }
            Ok(Message::Rendezvous { version, .. }) => {
                if version != PROTOCOL_VERSION {
                    return;
                }
                break None;
            }
            Ok(Message::Rejoin { client_id, .. }) => break Some(client_id as usize),
            Err(ProtocolError::Timeout) => {}
            Ok(_) | Err(_) => return,
        }
    };
    let ci = match claim {
        Some(id) if id < clients.len() => id,
        Some(_) => return,
        None => match clients.iter().position(|c| !c.alive) {
            Some(id) => id,
            None => return,
        },
    };
    let c = &mut clients[ci];
    let reply = match claim {
        None => Message::Welcome(Welcome {
            client_id: ci as u32,
            device_lo: c.devices.start as u32,
            device_count: c.devices.len() as u32,
            num_devices: hello.num_devices as u32,
            rounds: hello.rounds as u32,
            seed: hello.seed,
        }),
        // Nothing is staged in standby: the client resends its cached
        // results (byte-identical) once the round starts.
        Some(_) => Message::RejoinAck(RejoinAck {
            client_id: ci as u32,
            device_lo: c.devices.start as u32,
            device_count: c.devices.len() as u32,
            round: hello.start_round as u32,
            staged: Vec::new(),
        }),
    };
    if conn.send(&reply).is_err() {
        return;
    }
    // Supersede any half-dead previous connection: the old reader's
    // events carry a stale generation and are ignored.
    c.alive = false;
    c.conn = None;
    let _ = install(c, ci, conn, w);
}

/// Admit a mid-round reconnection. The client offers the XOR fold of
/// its cached result digests; if it matches what this round already
/// staged from its range, the staging is kept and the ack lists those
/// devices so the client skips resending them. On any mismatch (stale
/// round, partial arrival) the range is unstaged and collected afresh
/// — the client resends byte-identical cached results, so either path
/// folds the same bytes exactly once. The current start-round message
/// is replayed after the ack so a client that never saw it can begin.
fn admit_rejoin(
    mut conn: Box<dyn Connection>,
    clients: &mut [ClientSlot],
    engine: &mut RoundEngine,
    book: &mut RoundBook,
    start: &Message,
    w: &mut Wiring<'_>,
) {
    let state_now = Message::State(CoordinatorState::Round(book.round as u32));
    let deadline = Instant::now() + HELLO_WINDOW;
    let (client_id, round, digest) = loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return;
        }
        match conn.recv(remaining) {
            Ok(Message::Heartbeat) => {
                if conn.send(&state_now).is_err() {
                    return;
                }
            }
            Ok(Message::Rejoin {
                client_id,
                round,
                result_digest,
            }) => break (client_id as usize, round as usize, result_digest),
            Err(ProtocolError::Timeout) => {}
            // A fresh mid-run rendezvous (or garbage) cannot join an
            // in-flight run; drop it.
            Ok(_) | Err(_) => return,
        }
    };
    if client_id >= clients.len() {
        return;
    }
    let range = clients[client_id].devices.clone();
    let mut staged_in_range = Vec::new();
    let mut server_digest = 0u64;
    for (&d, &h) in book.staged.range(range.clone()) {
        staged_in_range.push(d);
        server_digest ^= h;
    }
    let replay_safe = round == book.round && digest == server_digest;
    let ack = Message::RejoinAck(RejoinAck {
        client_id: client_id as u32,
        device_lo: range.start as u32,
        device_count: range.len() as u32,
        round: book.round as u32,
        staged: if replay_safe {
            staged_in_range.iter().map(|&d| d as u32).collect()
        } else {
            Vec::new()
        },
    });
    if conn.send(&ack).is_err() || conn.send(start).is_err() {
        return;
    }
    let c = &mut clients[client_id];
    c.alive = false;
    c.conn = None;
    if !replay_safe {
        for d in staged_in_range {
            book.staged.remove(&d);
            engine.unstage(d);
            book.pending.insert(d);
        }
    }
    for d in range {
        if book.lost.remove(&d) {
            book.pending.insert(d);
        }
    }
    let _ = install(c, client_id, conn, w);
}

/// Answer a connection that dials in after the horizon completed: a
/// rejoining client is told the run is over (ack round = the horizon
/// itself) and handed the final end-round notice, so a client that
/// lost the original `Finished` broadcast to a fault still terminates
/// cleanly instead of redialing forever.
fn farewell(mut conn: Box<dyn Connection>, clients: &[ClientSlot], rounds: usize, last_loss: f64) {
    let deadline = Instant::now() + HELLO_WINDOW;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return;
        }
        match conn.recv(remaining) {
            Ok(Message::Heartbeat) => {
                if conn.send(&Message::State(CoordinatorState::Finished)).is_err() {
                    return;
                }
            }
            Ok(Message::Rejoin { client_id, .. }) => {
                let Some(c) = clients.get(client_id as usize) else {
                    return;
                };
                let ack = Message::RejoinAck(RejoinAck {
                    client_id,
                    device_lo: c.devices.start as u32,
                    device_count: c.devices.len() as u32,
                    round: rounds as u32,
                    staged: Vec::new(),
                });
                let end = Message::EndRound {
                    round: rounds.saturating_sub(1) as u32,
                    train_loss: last_loss,
                    state: CoordinatorState::Finished,
                };
                let _ = conn.send(&ack).and_then(|_| conn.send(&end));
                return;
            }
            Err(ProtocolError::Timeout) => {}
            Ok(_) | Err(_) => return,
        }
    }
}

/// A [`Session`] served over a transport: the remote counterpart of
/// [`Session::run`], producing the identical [`RunTrace`] for the same
/// seed and configuration — including under injected faults, as long
/// as every disconnected client rejoins before the round deadline.
pub struct CoordinatorService {
    session: Session,
    serve: ServeSpec,
    checkpoint: Option<(PathBuf, usize)>,
    halt_after: Option<usize>,
    start_round: usize,
}

impl CoordinatorService {
    /// Wrap a built session in the service front-end.
    pub fn new(session: Session, serve: ServeSpec) -> Self {
        Self {
            session,
            serve,
            checkpoint: None,
            halt_after: None,
            start_round: 0,
        }
    }

    /// Write a checkpoint (engine snapshot + serve-state) to `path`
    /// every `every` rounds and after the final one, so a killed
    /// coordinator can be restarted with `--serve --resume`.
    pub fn checkpoint_to(mut self, path: impl Into<PathBuf>, every: usize) -> Self {
        self.checkpoint = Some((path.into(), every.max(1)));
        self
    }

    /// Test hook: return from [`CoordinatorService::run`] right after
    /// checkpointing round `round`, *without* the end-round broadcast
    /// or run-end teardown — the observable behavior of a coordinator
    /// killed at that point. Clients see their connections close and
    /// enter their reconnect loops.
    pub fn halt_after_round(mut self, round: usize) -> Self {
        self.halt_after = Some(round);
        self
    }

    /// Restore a checkpoint produced by a previous serve run: the
    /// engine state is restored, the run re-enters the recorded round,
    /// and the serve-state (client count, hence device ranges) is
    /// adopted so rejoining clients land in their original slots.
    /// Returns the round the resumed run starts at.
    pub fn resume_from(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        let next = self.session.restore(ckpt)?;
        self.start_round = next;
        if let Some(ss) = &ckpt.serve_state {
            self.serve.clients = ss.clients;
        }
        Ok(next)
    }

    /// The serve configuration this service runs under.
    pub fn serve_spec(&self) -> &ServeSpec {
        &self.serve
    }

    /// Read-only access to the underlying session (model, counters).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Drive the full run over `transport`. Blocks until the horizon
    /// completes (or standby times out) and returns the trace (only
    /// the rounds executed by this call when resuming).
    ///
    /// Client failures after rendezvous never abort the run: a dead
    /// client's devices stop reporting and are folded as skips and
    /// counted as stragglers — unless the client rejoins before the
    /// round deadline, in which case the round completes as if the
    /// fault never happened. Only transport-level failures during
    /// standby (nobody claims a device range in time) are errors.
    pub fn run(&mut self, transport: &mut dyn Transport) -> Result<RunTrace, ProtocolError> {
        let meta = self.session.meta();
        let rounds = meta.rounds;
        let seed = self.session.config().seed;
        let start_round = self.start_round;
        let n_clients = self.serve.clients.max(1);
        let hb_timeout = Duration::from_millis(self.serve.heartbeat_timeout_ms.max(1));
        let round_timeout = Duration::from_millis(self.serve.round_timeout_ms.max(1));
        let accept_timeout = Duration::from_millis(self.serve.accept_timeout_ms.max(1));

        let SessionParts {
            engine,
            problem,
            algo,
            strategy,
            observers,
        } = self.session.parts();
        let m = engine.num_devices();
        let hello = HelloInfo {
            num_devices: m,
            rounds,
            seed,
            start_round,
        };

        // ---- standby: accept until every device range is claimed ----
        let (tx, events) = mpsc::channel::<Event>();
        let mut readers: Vec<JoinHandle<()>> = Vec::new();
        let mut wiring = Wiring {
            tx: &tx,
            readers: &mut readers,
            hb_timeout,
        };
        let mut clients: Vec<ClientSlot> = (0..n_clients)
            .map(|id| ClientSlot {
                conn: None,
                devices: id * m / n_clients..(id + 1) * m / n_clients,
                alive: false,
                gen: 0,
            })
            .collect();
        let deadline = Instant::now() + accept_timeout;
        while clients.iter().any(|c| !c.alive) {
            // Keep answering heartbeats of already-admitted clients so
            // they do not give up on a slow standby.
            while let Ok(ev) = events.try_recv() {
                match ev {
                    Event::Dead(ci, gen) => {
                        if clients[ci].gen == gen {
                            retire(&mut clients[ci], engine, None);
                        }
                    }
                    Event::Msg(ci, Message::Heartbeat) => {
                        let c = &mut clients[ci];
                        if c.alive && !c.send(&Message::State(CoordinatorState::Standby)) {
                            retire(c, engine, None);
                        }
                    }
                    Event::Msg(..) => {}
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            match transport.accept(remaining.min(ACCEPT_POLL)) {
                Ok(conn) => admit_standby(conn, &mut clients, &hello, &mut wiring),
                Err(ProtocolError::Timeout) => {}
                Err(e) => return Err(e),
            }
        }

        // Device -> client index (total: the ranges partition 0..m).
        let mut owner = vec![0usize; m];
        for (ci, c) in clients.iter().enumerate() {
            for d in c.devices.clone() {
                owner[d] = ci;
            }
        }

        let grace = match engine.network().policy() {
            StragglerPolicy::AdmitLate => hb_timeout,
            StragglerPolicy::Drop => Duration::ZERO,
        };

        for obs in observers.iter_mut() {
            obs.on_run_start(&meta);
        }
        let mut trace = RunTrace {
            algorithm: meta.algorithm.clone(),
            dataset: meta.dataset.clone(),
            split: meta.split.clone(),
            rounds: Vec::with_capacity(rounds.saturating_sub(start_round)),
        };

        for k in start_round..rounds {
            // ---- Round(k): broadcast context + model ----------------
            let ctx = engine.begin_round(k, &mut *strategy);
            engine.stage_reset(&ctx);
            let start = Message::StartRound(Box::new(StartRound {
                ctx: ctx.clone(),
                theta: engine.theta().to_vec(),
            }));
            let mut book = RoundBook {
                round: k,
                pending: BTreeSet::new(),
                lost: BTreeSet::new(),
                staged: BTreeMap::new(),
            };
            for c in clients.iter_mut() {
                if c.alive && !c.send(&start) {
                    retire(c, engine, None);
                }
            }
            for d in 0..m {
                if !ctx.is_selected(d) {
                    continue;
                }
                if clients[owner[d]].alive {
                    book.pending.insert(d);
                } else {
                    book.lost.insert(d);
                }
            }

            // ---- collect results until done or deadline -------------
            // `lost` devices keep the loop open too: their client may
            // still rejoin and deliver before the deadline.
            let deadline = Instant::now() + round_timeout + grace;
            while !book.pending.is_empty() || !book.lost.is_empty() {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                let mut drained = false;
                while let Ok(ev) = events.try_recv() {
                    drained = true;
                    handle_event(ev, &mut clients, engine, &mut book);
                }
                if drained {
                    continue; // re-check completion before blocking
                }
                let degraded = clients.iter().any(|c| !c.alive);
                if degraded {
                    if let Ok(conn) = transport.accept(remaining.min(REJOIN_POLL)) {
                        admit_rejoin(conn, &mut clients, engine, &mut book, &start, &mut wiring);
                        continue;
                    }
                }
                let step = if degraded { EVENT_POLL } else { remaining };
                if let Ok(ev) = events.recv_timeout(remaining.min(step)) {
                    handle_event(ev, &mut clients, engine, &mut book);
                }
            }
            let missing = book.pending.len() + book.lost.len();

            // ---- close the round ------------------------------------
            let mut rec = engine.finish_round(problem, algo, ctx);
            rec.stragglers += missing;
            engine.note_stragglers(missing as u64);
            for obs in observers.iter_mut() {
                obs.on_round(&rec);
            }
            if let Some((path, every)) = &self.checkpoint {
                if (k + 1) % every == 0 || k + 1 == rounds {
                    let mut ckpt = engine.snapshot(k + 1);
                    ckpt.serve_state = Some(ServeState {
                        clients: n_clients,
                        staged: book.staged.keys().map(|&d| d as u32).collect(),
                    });
                    if let Err(e) = ckpt.save(path) {
                        eprintln!("warning: checkpoint to {} failed: {e}", path.display());
                    }
                }
            }
            let train_loss = rec.train_loss;
            trace.rounds.push(rec);
            if self.halt_after == Some(k) {
                // Simulated crash: no end-round broadcast, no run-end
                // teardown — just vanish. Dropping the connections is
                // what the clients observe.
                drop(clients);
                drop(wiring);
                drop(tx);
                for h in readers {
                    let _ = h.join();
                }
                return Ok(trace);
            }
            let next = if k + 1 == rounds {
                CoordinatorState::Finished
            } else {
                CoordinatorState::Round(k as u32 + 1)
            };
            let end = Message::EndRound {
                round: k as u32,
                train_loss,
                state: next,
            };
            for c in clients.iter_mut() {
                if c.alive && !c.send(&end) {
                    retire(c, engine, None);
                }
            }
        }

        for obs in observers.iter_mut() {
            obs.on_run_end();
        }

        // ---- finish linger --------------------------------------
        // If any fault occurred, a client may have lost the Finished
        // notice and be mid-reconnect; keep the door open for one
        // liveness window so it learns the run is over.
        let faulted = clients.iter().any(|c| c.gen != 1 || !c.alive);
        if faulted && rounds > start_round {
            let last_loss = trace.rounds.last().map_or(f64::NAN, |r| r.train_loss);
            let deadline = Instant::now() + hb_timeout;
            loop {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                while let Ok(ev) = events.try_recv() {
                    match ev {
                        Event::Dead(ci, gen) => {
                            if clients[ci].gen == gen {
                                retire(&mut clients[ci], engine, None);
                            }
                        }
                        Event::Msg(ci, Message::Heartbeat) => {
                            let c = &mut clients[ci];
                            if c.alive && !c.send(&Message::State(CoordinatorState::Finished)) {
                                retire(c, engine, None);
                            }
                        }
                        Event::Msg(..) => {}
                    }
                }
                if let Ok(conn) = transport.accept(remaining.min(ACCEPT_POLL)) {
                    farewell(conn, &clients, rounds, last_loss);
                }
            }
        }

        // Closing the writer halves wakes every client; each reader
        // thread then exits within one heartbeat window at most.
        drop(clients);
        drop(wiring);
        drop(tx);
        for h in readers {
            let _ = h.join();
        }
        Ok(trace)
    }
}
