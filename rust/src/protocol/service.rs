//! The coordinator side of the protocol: an owned
//! [`crate::coordinator::Session`] driven over a [`Transport`].
//!
//! [`CoordinatorService::run`] walks the coordinator state machine:
//! standby (accept + rendezvous until every device range is claimed),
//! then one `Round(k)` per configured round — broadcast
//! [`Message::StartRound`], collect [`Message::RoundResult`]s into the
//! engine's staging slots, close the round — then `Finished`.
//!
//! Liveness is heartbeat-based: each client gets a reader thread whose
//! receive timeout is the heartbeat window, so a client silent that
//! long (crashed, hung, or partitioned) is declared dead and its
//! unreported devices are folded as skips and counted as stragglers —
//! the protocol analogue of the channel simulation's deadline
//! stragglers, steered by the same
//! [`crate::transport::scenario::StragglerPolicy`]: `AdmitLate` grants
//! one extra heartbeat window past the round deadline, `Drop` does not.
//!
//! Determinism: results are staged per device id and folded in device
//! order by the engine, so message arrival order, client count, and
//! transport choice cannot perturb the trace (see the module docs of
//! [`crate::protocol`]).

use super::messages::{Message, RoundResult, StartRound, Welcome};
use super::transport::{Connection, Transport};
use super::{CoordinatorState, ProtocolError, ServeSpec, PROTOCOL_VERSION};
use crate::coordinator::engine::RoundEngine;
use crate::coordinator::{Session, SessionParts};
use crate::metrics::RunTrace;
use crate::transport::scenario::StragglerPolicy;
use std::collections::BTreeSet;
use std::ops::Range;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One connected client: the writer half of its connection plus the
/// contiguous device range it computes. (The reader half lives in a
/// per-client thread feeding the service's event queue.)
struct ClientSlot {
    conn: Box<dyn Connection>,
    devices: Range<usize>,
    alive: bool,
}

/// What the per-client reader threads feed the service loop.
enum Event {
    /// A message from client `client_id`.
    Msg(usize, Message),
    /// The client's reader saw an error or heartbeat-window silence.
    Dead(usize),
}

/// Mark a client dead and move its still-pending devices to the
/// round's missing count.
fn retire(c: &mut ClientSlot, pending: &mut BTreeSet<usize>, missing: &mut usize) {
    if !c.alive {
        return;
    }
    c.alive = false;
    for d in c.devices.clone() {
        if pending.remove(&d) {
            *missing += 1;
        }
    }
}

/// Complete one rendezvous on a fresh connection: tolerate heartbeats,
/// require a version-matched [`Message::Rendezvous`], answer with
/// `welcome`. Returns `false` (drop the connection, do not consume the
/// device range) on anything else.
fn handshake(
    conn: &mut dyn Connection,
    welcome: &Welcome,
    deadline: Instant,
    step: Duration,
) -> bool {
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return false;
        }
        match conn.recv(remaining.min(step)) {
            Ok(Message::Heartbeat) => {
                if conn.send(&Message::State(CoordinatorState::Standby)).is_err() {
                    return false;
                }
            }
            Ok(Message::Rendezvous { version, .. }) => {
                return version == PROTOCOL_VERSION
                    && conn.send(&Message::Welcome(welcome.clone())).is_ok();
            }
            Ok(_) => return false,
            Err(ProtocolError::Timeout) => {}
            Err(_) => return false,
        }
    }
}

/// A [`Session`] served over a transport: the remote counterpart of
/// [`Session::run`], producing the identical [`RunTrace`] for the same
/// seed and configuration.
pub struct CoordinatorService {
    session: Session,
    serve: ServeSpec,
}

impl CoordinatorService {
    /// Wrap a built session in the service front-end.
    pub fn new(session: Session, serve: ServeSpec) -> Self {
        Self { session, serve }
    }

    /// The serve configuration this service runs under.
    pub fn serve_spec(&self) -> &ServeSpec {
        &self.serve
    }

    /// Read-only access to the underlying session (model, counters).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Drive the full run over `transport`. Blocks until the horizon
    /// completes (or standby times out) and returns the trace.
    ///
    /// Client failures after rendezvous never abort the run: a dead
    /// client's devices simply stop reporting and are folded as skips,
    /// counted as stragglers. Only transport-level failures during
    /// standby (nobody claims a device range in time) are errors.
    pub fn run(&mut self, transport: &mut dyn Transport) -> Result<RunTrace, ProtocolError> {
        let meta = self.session.meta();
        let rounds = meta.rounds;
        let m = self.session.parts().engine.num_devices();
        let seed = self.session.config().seed;
        let n_clients = self.serve.clients.max(1);
        let hb_timeout = Duration::from_millis(self.serve.heartbeat_timeout_ms.max(1));
        let round_timeout = Duration::from_millis(self.serve.round_timeout_ms.max(1));
        let accept_timeout = Duration::from_millis(self.serve.accept_timeout_ms.max(1));

        // ---- standby: accept until every device range is claimed ----
        let (tx, events) = mpsc::channel::<Event>();
        let mut clients: Vec<ClientSlot> = Vec::with_capacity(n_clients);
        let mut readers = Vec::with_capacity(n_clients);
        let deadline = Instant::now() + accept_timeout;
        while clients.len() < n_clients {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            let mut conn = transport.accept(remaining)?;
            let id = clients.len();
            let devices = id * m / n_clients..(id + 1) * m / n_clients;
            let welcome = Welcome {
                client_id: id as u32,
                device_lo: devices.start as u32,
                device_count: devices.len() as u32,
                num_devices: m as u32,
                rounds: rounds as u32,
                seed,
            };
            if !handshake(conn.as_mut(), &welcome, deadline, hb_timeout) {
                continue;
            }
            let mut rd = conn.try_clone()?;
            let tx = tx.clone();
            readers.push(std::thread::spawn(move || loop {
                match rd.recv(hb_timeout) {
                    Ok(msg) => {
                        if tx.send(Event::Msg(id, msg)).is_err() {
                            return;
                        }
                    }
                    Err(_) => {
                        let _ = tx.send(Event::Dead(id));
                        return;
                    }
                }
            }));
            clients.push(ClientSlot {
                conn,
                devices,
                alive: true,
            });
        }

        // Device -> client index (total: the ranges partition 0..m).
        let mut owner = vec![0usize; m];
        for (ci, c) in clients.iter().enumerate() {
            for d in c.devices.clone() {
                owner[d] = ci;
            }
        }

        let SessionParts {
            engine,
            problem,
            algo,
            strategy,
            observers,
        } = self.session.parts();
        let grace = match engine.network().policy() {
            StragglerPolicy::AdmitLate => hb_timeout,
            StragglerPolicy::Drop => Duration::ZERO,
        };

        for obs in observers.iter_mut() {
            obs.on_run_start(&meta);
        }
        let mut trace = RunTrace {
            algorithm: meta.algorithm.clone(),
            dataset: meta.dataset.clone(),
            split: meta.split.clone(),
            rounds: Vec::with_capacity(rounds),
        };

        for k in 0..rounds {
            // ---- Round(k): broadcast context + model ----------------
            let ctx = engine.begin_round(k, &mut *strategy);
            engine.stage_reset(&ctx);
            let start = Message::StartRound(Box::new(StartRound {
                ctx: ctx.clone(),
                theta: engine.theta().to_vec(),
            }));
            let state_now = CoordinatorState::Round(k as u32);
            let mut pending = BTreeSet::new();
            let mut missing = 0usize;
            for c in clients.iter_mut() {
                if c.alive && c.conn.send(&start).is_err() {
                    c.alive = false;
                }
            }
            for d in 0..m {
                if !ctx.is_selected(d) {
                    continue;
                }
                if clients[owner[d]].alive {
                    pending.insert(d);
                } else {
                    missing += 1;
                }
            }

            // ---- collect results until done or deadline -------------
            let deadline = Instant::now() + round_timeout + grace;
            while !pending.is_empty() {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                let Ok(ev) = events.recv_timeout(remaining) else {
                    break;
                };
                match ev {
                    Event::Dead(ci) => retire(&mut clients[ci], &mut pending, &mut missing),
                    Event::Msg(ci, Message::Heartbeat) => {
                        let c = &mut clients[ci];
                        if c.alive && c.conn.send(&Message::State(state_now)).is_err() {
                            retire(c, &mut pending, &mut missing);
                        }
                    }
                    Event::Msg(ci, Message::RoundResult(r)) => {
                        stage(engine, &clients[ci].devices, k, &mut pending, r);
                    }
                    // Anything else out of order (a late rendezvous, a
                    // stale result) is tolerated and ignored.
                    Event::Msg(_, _) => {}
                }
            }
            missing += pending.len();

            // ---- close the round ------------------------------------
            let mut rec = engine.finish_round(problem, algo, ctx);
            rec.stragglers += missing;
            engine.note_stragglers(missing as u64);
            for obs in observers.iter_mut() {
                obs.on_round(&rec);
            }
            let next = if k + 1 == rounds {
                CoordinatorState::Finished
            } else {
                CoordinatorState::Round(k as u32 + 1)
            };
            let end = Message::EndRound {
                round: k as u32,
                train_loss: rec.train_loss,
                state: next,
            };
            for c in clients.iter_mut() {
                if c.alive && c.conn.send(&end).is_err() {
                    c.alive = false;
                }
            }
            trace.rounds.push(rec);
        }

        for obs in observers.iter_mut() {
            obs.on_run_end();
        }
        // Closing the writer halves wakes every client; each reader
        // thread then exits within one heartbeat window at most.
        drop(clients);
        drop(tx);
        for h in readers {
            let _ = h.join();
        }
        Ok(trace)
    }
}

/// Stage one remote result if it belongs to this round and to the
/// sending client's device range (a misbehaving client cannot write
/// outside its assignment or replay an old round).
fn stage(
    engine: &mut RoundEngine,
    devices: &Range<usize>,
    round: usize,
    pending: &mut BTreeSet<usize>,
    r: RoundResult,
) {
    let d = r.device as usize;
    if r.round as usize != round || !devices.contains(&d) || !pending.remove(&d) {
        return;
    }
    engine.stage_remote(d, r.loss, r.level, r.payload.as_deref(), (r.uploads, r.skips));
}
