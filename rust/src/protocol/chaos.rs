//! Deterministic fault injection for the protocol stack.
//!
//! [`ChaosTransport`] / [`ChaosConnection`] / [`ChaosDialer`] are
//! decorators over any [`Transport`] / [`Connection`] / [`Dial`] that
//! inject the failure modes a served run must survive: connection
//! drops, send/recv stalls, partial frames followed by hangup, byte
//! corruption, duplicate delivery, and coordinator-side accept
//! failure. Probabilities and the chaos seed come from a [`ChaosSpec`]
//! (TOML `[chaos]` table or the `--chaos` CLI grammar).
//!
//! Every fault decision is drawn from a *fresh* RNG stream keyed on
//! `(chaos seed, fault kind, connection id, lane, op index)` — there
//! is no free-running fault stream anywhere (the PR 4 channel-fault
//! lesson), so a replay with the same seed and the same connection
//! history injects exactly the same faults, and a disabled probability
//! short-circuits before any RNG is built (the pass-through overhead
//! bench relies on this).
//!
//! Fault semantics are chosen so that *every* injected fault is
//! detectable by the peer: corruption flips the frame kind's high bit
//! (no kind uses it, so decode fails as `UnknownKind`), a partial
//! frame truncates the body and then kills the connection (decode
//! fails as `Truncated`/`Malformed`), and drops kill both directions
//! of the connection. The frame layer carries no checksum, so an
//! *undetectable* payload flip would silently poison the fold — chaos
//! therefore models detectable corruption, which is what the
//! reconnect/rejoin machinery can actually recover from.

use super::messages::Message;
use super::transport::{Connection, Dial, Transport};
use super::ProtocolError;
use crate::util::rng::Xoshiro256pp;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Fault-kind salts for the per-decision RNG streams.
const SALT_DROP: u64 = 0xD209_0000_0000_0001;
const SALT_STALL: u64 = 0xD209_0000_0000_0002;
const SALT_PARTIAL: u64 = 0xD209_0000_0000_0003;
const SALT_CORRUPT: u64 = 0xD209_0000_0000_0004;
const SALT_DUP: u64 = 0xD209_0000_0000_0005;
const SALT_ACCEPT: u64 = 0xD209_0000_0000_0006;

/// Golden-ratio mixers separating the id axes in the stream key.
const PHI: u64 = 0x9E37_79B9_7F4A_7C15;
const PHI2: u64 = 0xD1B5_4A32_D192_ED03;

/// Fault-injection configuration: per-fault probabilities plus the
/// chaos seed. All-zero probabilities (the default) mean chaos is
/// off and the decorators pass messages through untouched.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosSpec {
    /// Probability an op kills the connection (both directions).
    pub drop_p: f64,
    /// Probability an op stalls for [`ChaosSpec::stall_ms`] first.
    pub stall_p: f64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Probability a send emits only half the frame and then hangs up.
    pub partial_p: f64,
    /// Probability a send is corrupted (detectably — see module docs).
    pub corrupt_p: f64,
    /// Probability a sent message is delivered twice.
    pub dup_p: f64,
    /// Probability the coordinator drops a freshly accepted
    /// connection before reading anything from it.
    pub accept_p: f64,
    /// Seed for every fault decision stream.
    pub seed: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        Self {
            drop_p: 0.0,
            stall_p: 0.0,
            stall_ms: 20,
            partial_p: 0.0,
            corrupt_p: 0.0,
            dup_p: 0.0,
            accept_p: 0.0,
            seed: 0,
        }
    }
}

impl ChaosSpec {
    /// The `--chaos` grammar, echoed in parse errors and `repro list`.
    pub const SYNTAX: &'static str =
        "off | KEY=V[,KEY=V...] with keys drop|stall|partial|corrupt|dup|accept (prob in [0,1]), stall_ms, seed";

    /// Parse the CLI grammar: `off`, or comma-separated `key=value`
    /// pairs, e.g. `drop=0.05,dup=0.02,seed=7`. Probabilities must lie
    /// in `[0,1]`. Returns `None` on any unknown key or bad value.
    pub fn parse(s: &str) -> Option<Self> {
        let mut spec = Self::default();
        let s = s.trim();
        if s.is_empty() {
            return None;
        }
        if s == "off" {
            return Some(spec);
        }
        for item in s.split(',') {
            let (key, val) = item.split_once('=')?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "stall_ms" => spec.stall_ms = val.parse::<u64>().ok()?,
                "seed" => spec.seed = val.parse::<u64>().ok()?,
                _ => {
                    let p = val.parse::<f64>().ok()?;
                    if !(0.0..=1.0).contains(&p) {
                        return None;
                    }
                    match key {
                        "drop" => spec.drop_p = p,
                        "stall" => spec.stall_p = p,
                        "partial" => spec.partial_p = p,
                        "corrupt" => spec.corrupt_p = p,
                        "dup" => spec.dup_p = p,
                        "accept" => spec.accept_p = p,
                        _ => return None,
                    }
                }
            }
        }
        Some(spec)
    }

    /// Whether any fault has a nonzero probability.
    pub fn is_enabled(&self) -> bool {
        self.drop_p > 0.0
            || self.stall_p > 0.0
            || self.partial_p > 0.0
            || self.corrupt_p > 0.0
            || self.dup_p > 0.0
            || self.accept_p > 0.0
    }

    /// Wrap a server-side transport; accepted connections get chaos
    /// injected and the accept path itself can fail.
    pub fn wrap_transport(self, inner: Box<dyn Transport>) -> ChaosTransport {
        ChaosTransport {
            inner,
            spec: Arc::new(self),
            accepted: 0,
        }
    }

    /// Wrap a client-side dialer. `actor` distinguishes concurrent
    /// clients sharing one spec so their fault streams never collide
    /// (connection ids become `actor << 32 | dial index`).
    pub fn wrap_dial(self, inner: Box<dyn Dial>, actor: u64) -> ChaosDialer {
        ChaosDialer {
            inner,
            spec: Arc::new(self),
            actor,
            dialed: AtomicU64::new(0),
        }
    }

    /// One fault decision, keyed on `(seed, salt, conn, lane, op)`.
    /// Builds no RNG when the probability is zero — the disabled path
    /// is a handful of branches.
    fn roll(&self, salt: u64, conn: u64, lane: u64, op: u64, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let key = salt ^ conn.wrapping_mul(PHI) ^ lane.rotate_left(17) ^ op.wrapping_mul(PHI2);
        Xoshiro256pp::stream(self.seed, key).bernoulli(p)
    }
}

impl std::fmt::Display for ChaosSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.is_enabled() {
            return write!(f, "off");
        }
        let mut parts: Vec<String> = Vec::new();
        for (key, p) in [
            ("drop", self.drop_p),
            ("stall", self.stall_p),
            ("partial", self.partial_p),
            ("corrupt", self.corrupt_p),
            ("dup", self.dup_p),
            ("accept", self.accept_p),
        ] {
            if p > 0.0 {
                parts.push(format!("{key}={p}"));
            }
        }
        if self.stall_p > 0.0 {
            parts.push(format!("stall_ms={}", self.stall_ms));
        }
        parts.push(format!("seed={}", self.seed));
        write!(f, "{}", parts.join(","))
    }
}

/// Fault-injecting [`Connection`] decorator. Clones share the dead
/// flag (a drop kills both directions, like a real socket) but each
/// clone rolls faults on its own lane, so the reader and writer halves
/// have independent, fully deterministic fault sequences regardless of
/// thread interleaving.
pub struct ChaosConnection {
    inner: Box<dyn Connection>,
    spec: Arc<ChaosSpec>,
    conn_id: u64,
    lane: u64,
    next_lane: Arc<AtomicU64>,
    ops: u64,
    dead: Arc<AtomicBool>,
    body_buf: Vec<u8>,
}

impl ChaosConnection {
    /// Wrap `inner`, keying this connection's fault streams on
    /// `conn_id`.
    pub fn new(inner: Box<dyn Connection>, spec: Arc<ChaosSpec>, conn_id: u64) -> Self {
        Self {
            inner,
            spec,
            conn_id,
            lane: 0,
            next_lane: Arc::new(AtomicU64::new(1)),
            ops: 0,
            dead: Arc::new(AtomicBool::new(false)),
            body_buf: Vec::new(),
        }
    }

    fn roll(&self, salt: u64, op: u64, p: f64) -> bool {
        self.spec.roll(salt, self.conn_id, self.lane, op, p)
    }

    fn kill(&self) -> ProtocolError {
        self.dead.store(true, Ordering::Relaxed);
        ProtocolError::Closed
    }
}

impl Connection for ChaosConnection {
    fn send(&mut self, msg: &Message) -> Result<(), ProtocolError> {
        if self.dead.load(Ordering::Relaxed) {
            return Err(ProtocolError::Closed);
        }
        let op = self.ops;
        self.ops += 1;
        if self.roll(SALT_DROP, op, self.spec.drop_p) {
            return Err(self.kill());
        }
        if self.roll(SALT_STALL, op, self.spec.stall_p) {
            std::thread::sleep(Duration::from_millis(self.spec.stall_ms));
        }
        if self.roll(SALT_PARTIAL, op, self.spec.partial_p) {
            // Half the frame, then hangup: the peer's decoder sees a
            // truncated body and retires the connection.
            msg.encode_body(&mut self.body_buf);
            let half = self.body_buf.len() / 2;
            let body = std::mem::take(&mut self.body_buf);
            let res = self.inner.send_raw_frame(msg.kind(), &body[..half]);
            self.body_buf = body;
            res?;
            return Err(self.kill());
        }
        if self.roll(SALT_CORRUPT, op, self.spec.corrupt_p) {
            // Detectable corruption: no kind byte uses the high bit,
            // so the peer decodes `UnknownKind` and retires the
            // connection (see module docs for why the flip must be
            // detectable).
            msg.encode_body(&mut self.body_buf);
            let body = std::mem::take(&mut self.body_buf);
            let res = self.inner.send_raw_frame(msg.kind() | 0x80, &body);
            self.body_buf = body;
            return res;
        }
        self.inner.send(msg)?;
        if self.roll(SALT_DUP, op, self.spec.dup_p) {
            self.inner.send(msg)?;
        }
        Ok(())
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, ProtocolError> {
        if self.dead.load(Ordering::Relaxed) {
            return Err(ProtocolError::Closed);
        }
        let op = self.ops;
        self.ops += 1;
        if self.roll(SALT_DROP, op, self.spec.drop_p) {
            return Err(self.kill());
        }
        if self.roll(SALT_STALL, op, self.spec.stall_p) {
            std::thread::sleep(Duration::from_millis(self.spec.stall_ms));
        }
        self.inner.recv(timeout)
    }

    fn try_clone(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        Ok(Box::new(Self {
            inner: self.inner.try_clone()?,
            spec: self.spec.clone(),
            conn_id: self.conn_id,
            lane: self.next_lane.fetch_add(1, Ordering::Relaxed),
            next_lane: self.next_lane.clone(),
            ops: 0,
            dead: self.dead.clone(),
            body_buf: Vec::new(),
        }))
    }

    fn send_raw_frame(&mut self, kind: u8, body: &[u8]) -> Result<(), ProtocolError> {
        // Raw injection is a test instrument; chaos does not re-fault it.
        self.inner.send_raw_frame(kind, body)
    }
}

/// Fault-injecting [`Transport`] decorator: accepted connections are
/// wrapped in [`ChaosConnection`]s (connection id = accept index), and
/// with probability `accept_p` a freshly accepted connection is
/// dropped on the floor before the handshake — the client sees an
/// immediate close and must redial.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    spec: Arc<ChaosSpec>,
    accepted: u64,
}

impl Transport for ChaosTransport {
    fn accept(&mut self, timeout: Duration) -> Result<Box<dyn Connection>, ProtocolError> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(ProtocolError::Timeout);
            }
            let conn = self.inner.accept(remaining)?;
            let id = self.accepted;
            self.accepted += 1;
            if self.spec.roll(SALT_ACCEPT, id, 0, 0, self.spec.accept_p) {
                drop(conn);
                continue;
            }
            return Ok(Box::new(ChaosConnection::new(conn, self.spec.clone(), id)));
        }
    }
}

/// Fault-injecting [`Dial`] decorator for the client side; each dialed
/// connection gets its own chaos stream (`actor << 32 | dial index`).
pub struct ChaosDialer {
    inner: Box<dyn Dial>,
    spec: Arc<ChaosSpec>,
    actor: u64,
    dialed: AtomicU64,
}

impl Dial for ChaosDialer {
    fn dial(&self) -> Result<Box<dyn Connection>, ProtocolError> {
        let n = self.dialed.fetch_add(1, Ordering::Relaxed);
        let conn = self.inner.dial()?;
        Ok(Box::new(ChaosConnection::new(
            conn,
            self.spec.clone(),
            (self.actor << 32) | (n & 0xFFFF_FFFF),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::transport::{LoopbackConnection, LoopbackHub};

    fn wrap_pair(spec: ChaosSpec) -> (ChaosConnection, LoopbackConnection) {
        let (a, b) = LoopbackConnection::pair();
        (ChaosConnection::new(Box::new(a), Arc::new(spec), 1), b)
    }

    #[test]
    fn parse_grammar_and_display() {
        assert_eq!(ChaosSpec::parse("off"), Some(ChaosSpec::default()));
        let spec = ChaosSpec::parse("drop=0.1,dup=0.25,stall=0.5,stall_ms=7,seed=42")
            .expect("valid grammar");
        assert_eq!(spec.drop_p, 0.1);
        assert_eq!(spec.dup_p, 0.25);
        assert_eq!((spec.stall_p, spec.stall_ms, spec.seed), (0.5, 7, 42));
        assert!(spec.is_enabled());
        let shown = spec.to_string();
        assert_eq!(ChaosSpec::parse(&shown), Some(spec), "display re-parses");
        assert_eq!(ChaosSpec::parse("drop=1.5"), None, "prob out of range");
        assert_eq!(ChaosSpec::parse("bogus=0.5"), None, "unknown key");
        assert_eq!(ChaosSpec::parse(""), None, "empty spec");
        assert!(!ChaosSpec::default().is_enabled());
        assert_eq!(ChaosSpec::default().to_string(), "off");
    }

    #[test]
    fn decisions_are_seed_keyed_and_reproducible() {
        let spec = ChaosSpec {
            drop_p: 0.5,
            seed: 9,
            ..ChaosSpec::default()
        };
        for op in 0..64u64 {
            let a = spec.roll(SALT_DROP, 3, 0, op, spec.drop_p);
            let b = spec.roll(SALT_DROP, 3, 0, op, spec.drop_p);
            assert_eq!(a, b, "same key, same decision");
        }
        let flips: Vec<bool> = (0..64)
            .map(|op| spec.roll(SALT_DROP, 3, 0, op, spec.drop_p))
            .collect();
        assert!(flips.iter().any(|&f| f) && flips.iter().any(|&f| !f));
        let other_seed = ChaosSpec { seed: 10, ..spec };
        let flips2: Vec<bool> = (0..64)
            .map(|op| other_seed.roll(SALT_DROP, 3, 0, op, other_seed.drop_p))
            .collect();
        assert_ne!(flips, flips2, "seed changes the fault pattern");
    }

    #[test]
    fn disabled_spec_passes_through_untouched() {
        let (mut tx, mut rx) = wrap_pair(ChaosSpec::default());
        for _ in 0..32 {
            tx.send(&Message::Heartbeat).unwrap();
            assert!(matches!(
                rx.recv(Duration::from_millis(100)).unwrap(),
                Message::Heartbeat
            ));
        }
    }

    #[test]
    fn drop_kills_both_directions() {
        let spec = ChaosSpec {
            drop_p: 1.0,
            ..ChaosSpec::default()
        };
        let (mut tx, _rx) = wrap_pair(spec);
        let mut reader = tx.try_clone().unwrap();
        assert!(matches!(
            tx.send(&Message::Heartbeat),
            Err(ProtocolError::Closed)
        ));
        assert!(matches!(
            reader.recv(Duration::from_millis(10)),
            Err(ProtocolError::Closed)
        ));
    }

    #[test]
    fn corrupt_is_always_detectable() {
        let spec = ChaosSpec {
            corrupt_p: 1.0,
            ..ChaosSpec::default()
        };
        let (mut tx, mut rx) = wrap_pair(spec);
        tx.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            rx.recv(Duration::from_millis(100)),
            Err(ProtocolError::UnknownKind(_))
        ));
    }

    #[test]
    fn partial_truncates_then_hangs_up() {
        let spec = ChaosSpec {
            partial_p: 1.0,
            ..ChaosSpec::default()
        };
        let (mut tx, mut rx) = wrap_pair(spec);
        let err = tx.send(&Message::Rendezvous { version: 1, want: 0 });
        assert!(matches!(err, Err(ProtocolError::Closed)));
        // The peer got half a rendezvous body: decode must fail.
        assert!(rx.recv(Duration::from_millis(100)).is_err());
    }

    #[test]
    fn dup_delivers_twice() {
        let spec = ChaosSpec {
            dup_p: 1.0,
            ..ChaosSpec::default()
        };
        let (mut tx, mut rx) = wrap_pair(spec);
        tx.send(&Message::Heartbeat).unwrap();
        for _ in 0..2 {
            assert!(matches!(
                rx.recv(Duration::from_millis(100)).unwrap(),
                Message::Heartbeat
            ));
        }
        assert!(matches!(
            rx.recv(Duration::from_millis(10)),
            Err(ProtocolError::Timeout)
        ));
    }

    #[test]
    fn accept_failure_drops_the_connection() {
        let spec = ChaosSpec {
            accept_p: 1.0,
            ..ChaosSpec::default()
        };
        let hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let mut transport = spec.wrap_transport(Box::new(hub));
        let mut client = dialer.connect();
        // The only pending connection is dropped on accept, so the
        // accept call times out and the client sees a dead peer.
        assert!(matches!(
            transport.accept(Duration::from_millis(50)),
            Err(ProtocolError::Timeout)
        ));
        assert!(matches!(
            client.recv(Duration::from_millis(10)),
            Err(ProtocolError::Closed)
        ));
    }
}
