//! Coordinator-as-a-service: a transport-agnostic FL protocol over
//! wire v2.
//!
//! The in-process [`crate::coordinator::RoundEngine`] treats the
//! device exchange as a function call; this module makes it a real
//! protocol (DESIGN.md §Protocol). An explicit coordinator state
//! machine
//!
//! ```text
//! Standby ──all devices claimed──▶ Round(0) ─▶ … ─▶ Round(K−1) ─▶ Finished
//! ```
//!
//! exchanges framed messages — rendezvous / heartbeat / start-round
//! (model broadcast + selection + quantization schedule) / upload /
//! end-round — over a length-prefixed [`frame`] layer that carries the
//! existing wire-v2 payload encoding verbatim. Two transports sit
//! behind the one [`Transport`] trait: a std-only TCP server
//! (thread-per-connection, read/write timeouts) and an in-process
//! duplex [`LoopbackHub`] so every protocol test runs deterministically
//! in CI. A thin [`DeviceClient`] drives the existing
//! [`crate::algorithms::DeviceState`]/quantize path on the far side;
//! heartbeat-based liveness maps dead clients onto the existing
//! [`crate::transport::scenario::StragglerPolicy`].
//!
//! Determinism guarantee: a seeded run driven through
//! [`CoordinatorService`] produces a [`crate::metrics::RunTrace`]
//! bit-identical to the same run executed in-process, over either
//! transport, regardless of client count or message arrival order —
//! results are staged into per-device slots and folded in device-id
//! order, and every `RoundCtx` field round-trips losslessly through
//! the start-round broadcast.
//!
//! Failure is a first-class condition (DESIGN.md §Fault model): the
//! [`chaos`] decorators inject deterministic seed-keyed faults into
//! any transport, clients reconnect with capped exponential backoff
//! and resume mid-round through the rejoin handshake
//! ([`Message::Rejoin`] / [`messages::RejoinAck`]) without
//! double-counting (per-round staged-result digests dedupe replays,
//! and a dying client's half-round staging is cleared), and the
//! coordinator checkpoints serve-state each round so a killed process
//! restarted with `--serve --resume` re-enters `Round(n)` with the
//! trace still bit-identical to an uninterrupted run.

use crate::transport::wire::WireError;

pub mod chaos;
pub mod client;
pub mod frame;
pub mod messages;
pub mod service;
pub mod transport;

pub use chaos::{ChaosConnection, ChaosDialer, ChaosSpec, ChaosTransport};
pub use client::{ClientReport, DeviceClient};
pub use frame::Frame;
pub use messages::Message;
pub use service::CoordinatorService;
pub use transport::{
    Connection, Dial, LoopbackDialer, LoopbackHub, TcpConnection, TcpDialer, TcpTransport,
    Transport,
};

/// Protocol revision carried in every rendezvous; bumped on any frame
/// or message layout change. Version 2 adds the rejoin/rejoin-ack
/// reconnection handshake.
pub const PROTOCOL_VERSION: u16 = 2;

/// Typed failure for every protocol layer — framing, message codec,
/// transport i/o, and state machine — composing with the wire codec's
/// [`WireError`] so protocol and payload failures propagate through
/// one `?` chain without stringly matching.
#[derive(Debug, thiserror::Error)]
pub enum ProtocolError {
    /// Underlying socket/stream failure.
    #[error("i/o: {0}")]
    Io(#[from] std::io::Error),
    /// No complete frame arrived within the allotted window.
    #[error("timed out waiting for a frame")]
    Timeout,
    /// The peer closed the connection.
    #[error("connection closed by peer")]
    Closed,
    /// A frame header announced a body larger than the hard cap.
    #[error("frame of {len} bytes exceeds the {max}-byte limit")]
    FrameTooLarge {
        /// Announced body length.
        len: u32,
        /// The [`frame::MAX_FRAME_BYTES`] cap.
        max: u32,
    },
    /// A message body ended before a fixed-size field.
    #[error("message truncated: need {need} bytes, have {have}")]
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes remaining in the body.
        have: usize,
    },
    /// A frame carried a kind byte no message decodes from.
    #[error("unknown message kind {0:#04x}")]
    UnknownKind(u8),
    /// A structurally invalid message body (bad flag, trailing bytes,
    /// inconsistent lengths).
    #[error("malformed message: {0}")]
    Malformed(&'static str),
    /// An embedded wire-v2 payload failed to decode.
    #[error(transparent)]
    Wire(#[from] WireError),
    /// A well-formed message arrived in a state that forbids it.
    #[error("protocol violation: {0}")]
    Violation(&'static str),
}

/// The coordinator's externally visible state, echoed to clients in
/// heartbeat replies and end-round notices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoordinatorState {
    /// Waiting for clients to rendezvous and claim devices.
    Standby,
    /// Executing the given communication round.
    Round(u32),
    /// The configured horizon completed; clients may disconnect.
    Finished,
}

/// Configuration for the coordinator service and its clients — the
/// TOML `[serve]` block (`serve.addr`, `serve.clients`, ...) and the
/// `--serve` / `--connect` CLI flags.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    /// TCP listen address for `--serve` (`serve.addr`).
    pub addr: String,
    /// Number of clients the coordinator waits for in standby before
    /// starting round 0; devices are split into that many contiguous
    /// ranges (`serve.clients`).
    pub clients: usize,
    /// Client heartbeat interval in milliseconds (`serve.heartbeat_ms`).
    pub heartbeat_ms: u64,
    /// Server-side liveness window: a client silent this long is
    /// declared dead and its unreported devices become stragglers
    /// (`serve.heartbeat_timeout_ms`).
    pub heartbeat_timeout_ms: u64,
    /// Per-round collection deadline (`serve.round_timeout_ms`).
    pub round_timeout_ms: u64,
    /// Standby window for all clients to rendezvous
    /// (`serve.accept_timeout_ms`).
    pub accept_timeout_ms: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            clients: 1,
            heartbeat_ms: 200,
            heartbeat_timeout_ms: 2_000,
            round_timeout_ms: 30_000,
            accept_timeout_ms: 10_000,
        }
    }
}
