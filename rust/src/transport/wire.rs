//! Wire format for device→server uploads.
//!
//! Every table and figure reports *actual serialized bytes × 8*, so all
//! uploads round-trip through this encoding in the simulator: the client
//! encodes, the transport counts `bytes.len()`, the server decodes.
//!
//! Layout (little-endian):
//!
//! ```text
//! [0]      tag: u8       payload kind
//! [1]      bits: u8      quantization level (0 for raw payloads)
//! [2..6]   scale: f32    range R (mid-tread) or ‖v‖₂ (QSGD); 0 for raw
//! [6..10]  len: u32      element count d (or |support| under HeteroFL)
//! [10..]   body          packed codes / sign bitmap + codes / raw f32
//! ```

use crate::quant::midtread::QuantizedVec;
use crate::quant::packing;
use crate::quant::qsgd::QsgdVec;

/// Header size in bytes (tag + bits + scale + len).
pub const HEADER_BYTES: usize = 10;

/// A device upload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Mid-tread-quantized gradient *innovation* `Δq_m` — lazy
    /// aggregation family (AQUILA, LAQ, LAdaQ). Server folds
    /// incrementally: `q̄ += Δq/M`.
    MidtreadDelta(QuantizedVec),
    /// Mid-tread-quantized *full* gradient (AdaQuantFL, DAdaQuant).
    MidtreadFull(QuantizedVec),
    /// QSGD stochastically-quantized full gradient.
    Qsgd(QsgdVec),
    /// Raw f32 gradient innovation (LENA trigger uploads, MARINA
    /// correction steps are quantized — see `algorithms::marina`).
    RawDelta(Vec<f32>),
    /// Raw f32 full gradient (FedAvg baseline, MARINA sync rounds).
    RawFull(Vec<f32>),
}

const TAG_MT_DELTA: u8 = 1;
const TAG_MT_FULL: u8 = 2;
const TAG_QSGD: u8 = 3;
const TAG_RAW_DELTA: u8 = 4;
const TAG_RAW_FULL: u8 = 5;

/// Error from [`decode`].
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("message truncated: need {need} bytes, have {have}")]
    Truncated { need: usize, have: usize },
    #[error("unknown payload tag {0}")]
    UnknownTag(u8),
    #[error("invalid bits field {0}")]
    BadBits(u8),
}

impl Payload {
    /// Element count carried by this payload.
    pub fn len(&self) -> usize {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => q.dim(),
            Payload::Qsgd(q) => q.dim(),
            Payload::RawDelta(v) | Payload::RawFull(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantization level used, if any (for metrics).
    pub fn level(&self) -> Option<u8> {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => Some(q.bits),
            Payload::Qsgd(q) => Some(q.bits),
            _ => None,
        }
    }
}

/// Serialize a payload to wire bytes.
pub fn encode(p: &Payload) -> Vec<u8> {
    let (tag, bits, scale, n) = match p {
        Payload::MidtreadDelta(q) => (TAG_MT_DELTA, q.bits, q.range, q.dim()),
        Payload::MidtreadFull(q) => (TAG_MT_FULL, q.bits, q.range, q.dim()),
        Payload::Qsgd(q) => (TAG_QSGD, q.bits, q.norm, q.dim()),
        Payload::RawDelta(v) => (TAG_RAW_DELTA, 0, 0.0, v.len()),
        Payload::RawFull(v) => (TAG_RAW_FULL, 0, 0.0, v.len()),
    };
    let body_len = match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
            packing::packed_len(q.dim(), q.bits)
        }
        Payload::Qsgd(q) => q.dim().div_ceil(8) + packing::packed_len(q.dim(), q.bits),
        Payload::RawDelta(v) | Payload::RawFull(v) => 4 * v.len(),
    };
    let mut out = Vec::with_capacity(HEADER_BYTES + body_len);
    out.push(tag);
    out.push(bits);
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
            out.extend_from_slice(&packing::pack(&q.psi, q.bits));
        }
        Payload::Qsgd(q) => {
            out.extend_from_slice(&packing::pack_signs(&q.signs));
            out.extend_from_slice(&packing::pack(&q.mags, q.bits));
        }
        Payload::RawDelta(v) | Payload::RawFull(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

/// Deserialize wire bytes back into a payload.
pub fn decode(bytes: &[u8]) -> Result<Payload, WireError> {
    if bytes.len() < HEADER_BYTES {
        return Err(WireError::Truncated {
            need: HEADER_BYTES,
            have: bytes.len(),
        });
    }
    let tag = bytes[0];
    let bits = bytes[1];
    let scale = f32::from_le_bytes(bytes[2..6].try_into().unwrap());
    let n = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
    let body = &bytes[HEADER_BYTES..];
    let need_body = |need: usize| -> Result<(), WireError> {
        if body.len() < need {
            Err(WireError::Truncated {
                need: HEADER_BYTES + need,
                have: bytes.len(),
            })
        } else {
            Ok(())
        }
    };
    match tag {
        TAG_MT_DELTA | TAG_MT_FULL => {
            if !(1..=32).contains(&bits) {
                return Err(WireError::BadBits(bits));
            }
            need_body(packing::packed_len(n, bits))?;
            let psi = packing::unpack(body, bits, n);
            let q = QuantizedVec {
                bits,
                range: scale,
                psi,
            };
            Ok(if tag == TAG_MT_DELTA {
                Payload::MidtreadDelta(q)
            } else {
                Payload::MidtreadFull(q)
            })
        }
        TAG_QSGD => {
            if !(1..=31).contains(&bits) {
                return Err(WireError::BadBits(bits));
            }
            let sign_bytes = n.div_ceil(8);
            need_body(sign_bytes + packing::packed_len(n, bits))?;
            let signs = packing::unpack_signs(&body[..sign_bytes], n);
            let mags = packing::unpack(&body[sign_bytes..], bits, n);
            Ok(Payload::Qsgd(QsgdVec {
                bits,
                norm: scale,
                mags,
                signs,
            }))
        }
        TAG_RAW_DELTA | TAG_RAW_FULL => {
            need_body(4 * n)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(f32::from_le_bytes(
                    body[4 * i..4 * i + 4].try_into().unwrap(),
                ));
            }
            Ok(if tag == TAG_RAW_DELTA {
                Payload::RawDelta(v)
            } else {
                Payload::RawFull(v)
            })
        }
        t => Err(WireError::UnknownTag(t)),
    }
}

/// Exact wire size in bits without encoding (used by size assertions and
/// fast-path accounting; must agree with `encode(p).len() * 8` — tested).
pub fn wire_bits(p: &Payload) -> u64 {
    let body_bytes = match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
            packing::packed_len(q.dim(), q.bits)
        }
        Payload::Qsgd(q) => q.dim().div_ceil(8) + packing::packed_len(q.dim(), q.bits),
        Payload::RawDelta(v) | Payload::RawFull(v) => 4 * v.len(),
    };
    ((HEADER_BYTES + body_bytes) * 8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;
    use crate::quant::qsgd;
    use crate::util::rng::Xoshiro256pp;

    fn sample_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn midtread_roundtrip() {
        let v = sample_vec(300, 1);
        for bits in [1u8, 3, 8, 13] {
            let q = quantize(&v, bits);
            for p in [
                Payload::MidtreadDelta(q.clone()),
                Payload::MidtreadFull(q.clone()),
            ] {
                let enc = encode(&p);
                assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
                assert_eq!(decode(&enc).unwrap(), p);
            }
        }
    }

    #[test]
    fn qsgd_roundtrip() {
        let v = sample_vec(127, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let q = qsgd::quantize(&v, 4, &mut rng);
        let p = Payload::Qsgd(q);
        let enc = encode(&p);
        assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
        assert_eq!(decode(&enc).unwrap(), p);
    }

    #[test]
    fn raw_roundtrip() {
        let v = sample_vec(64, 4);
        for p in [Payload::RawDelta(v.clone()), Payload::RawFull(v.clone())] {
            let enc = encode(&p);
            assert_eq!(enc.len(), HEADER_BYTES + 256);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn quantized_is_smaller_than_raw() {
        let v = sample_vec(10_000, 5);
        let raw = encode(&Payload::RawFull(v.clone()));
        let q4 = encode(&Payload::MidtreadFull(quantize(&v, 4)));
        // 4-bit packing ⇒ ~8x smaller than f32.
        assert!(q4.len() * 7 < raw.len(), "{} vs {}", q4.len(), raw.len());
    }

    #[test]
    fn empty_payloads() {
        for p in [
            Payload::RawFull(vec![]),
            Payload::MidtreadDelta(quantize(&[], 4)),
        ] {
            let enc = encode(&p);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99; 16]).is_err()); // unknown tag
        let v = sample_vec(32, 6);
        let mut enc = encode(&Payload::RawFull(v));
        enc.truncate(20); // truncated body
        assert!(decode(&enc).is_err());
        // Bad bits for midtread.
        let mut enc2 = encode(&Payload::MidtreadFull(quantize(&[1.0, 2.0], 4)));
        enc2[1] = 0;
        assert!(decode(&enc2).is_err());
    }

    #[test]
    fn level_accessor() {
        let v = sample_vec(8, 7);
        assert_eq!(Payload::MidtreadFull(quantize(&v, 6)).level(), Some(6));
        assert_eq!(Payload::RawFull(v).level(), None);
    }
}
