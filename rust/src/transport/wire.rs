//! Wire format for device→server uploads.
//!
//! Every table and figure reports *actual serialized bytes × 8*, so all
//! uploads round-trip through this encoding in the simulator: the client
//! encodes, the transport counts `bytes.len()`, the server decodes.
//!
//! Layout (little-endian):
//!
//! ```text
//! [0]      tag: u8       payload kind
//! [1]      bits: u8      quantization level (0 for raw payloads)
//! [2..6]   scale: f32    range R (mid-tread) or ‖v‖₂ (QSGD); 0 for raw
//! [6..10]  len: u32      element count d (or |support| under HeteroFL)
//! [10..]   body          packed codes / sign bitmap + codes / raw f32
//! ```
//!
//! Two server-side representations exist:
//!
//! * [`Payload`] — owned, codes materialized (`Vec<u32>` ψ). Client-side
//!   staging and tests use this.
//! * [`PayloadView`] — borrowed, zero-copy: the header is parsed, the
//!   body stays *packed* in the received byte buffer. The aggregation
//!   pipeline folds straight from views via the fused
//!   dequantize–scatter kernels (`PayloadView::scatter_add_shard`), so
//!   a 4-bit upload is never inflated to `Vec<u32>` + dense f32 scratch
//!   on its way into `direction` (§Perf in DESIGN.md).

use crate::hetero::CapacityMask;
use crate::quant::midtread::{self, QuantizedVec};
use crate::quant::packing;
use crate::quant::qsgd::{self, QsgdVec};

/// Header size in bytes (tag + bits + scale + len).
pub const HEADER_BYTES: usize = 10;

/// A device upload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Mid-tread-quantized gradient *innovation* `Δq_m` — lazy
    /// aggregation family (AQUILA, LAQ, LAdaQ). Server folds
    /// incrementally: `q̄ += Δq/M`.
    MidtreadDelta(QuantizedVec),
    /// Mid-tread-quantized *full* gradient (AdaQuantFL, DAdaQuant).
    MidtreadFull(QuantizedVec),
    /// QSGD stochastically-quantized full gradient.
    Qsgd(QsgdVec),
    /// Raw f32 gradient innovation (LENA trigger uploads, MARINA
    /// correction steps are quantized — see `algorithms::marina`).
    RawDelta(Vec<f32>),
    /// Raw f32 full gradient (FedAvg baseline, MARINA sync rounds).
    RawFull(Vec<f32>),
}

/// Payload kind, as carried by the wire tag byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// Mid-tread-quantized gradient innovation.
    MidtreadDelta,
    /// Mid-tread-quantized full gradient.
    MidtreadFull,
    /// QSGD stochastically-quantized full gradient.
    Qsgd,
    /// Raw f32 gradient innovation.
    RawDelta,
    /// Raw f32 full gradient.
    RawFull,
}

const TAG_MT_DELTA: u8 = 1;
const TAG_MT_FULL: u8 = 2;
const TAG_QSGD: u8 = 3;
const TAG_RAW_DELTA: u8 = 4;
const TAG_RAW_FULL: u8 = 5;

/// Error from [`decode`] / [`view`].
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    /// Message shorter than its header/body claims.
    #[error("message truncated: need {need} bytes, have {have}")]
    Truncated { need: usize, have: usize },
    /// Unrecognized payload kind tag.
    #[error("unknown payload tag {0}")]
    UnknownTag(u8),
    /// Bits field outside the representable range.
    #[error("invalid bits field {0}")]
    BadBits(u8),
}

impl Payload {
    /// Element count carried by this payload.
    pub fn len(&self) -> usize {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => q.dim(),
            Payload::Qsgd(q) => q.dim(),
            Payload::RawDelta(v) | Payload::RawFull(v) => v.len(),
        }
    }

    /// True for zero-element payloads.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantization level used, if any (for metrics).
    pub fn level(&self) -> Option<u8> {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => Some(q.bits),
            Payload::Qsgd(q) => Some(q.bits),
            _ => None,
        }
    }
}

/// Exact body size in bytes for a payload of `kind` with `n` elements
/// at `bits` bits.
const fn body_len(kind: PayloadKind, bits: u8, n: usize) -> usize {
    match kind {
        PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => packing::packed_len(n, bits),
        PayloadKind::Qsgd => n.div_ceil(8) + packing::packed_len(n, bits),
        PayloadKind::RawDelta | PayloadKind::RawFull => 4 * n,
    }
}

fn header_of(p: &Payload) -> (PayloadKind, u8, f32, usize) {
    match p {
        Payload::MidtreadDelta(q) => (PayloadKind::MidtreadDelta, q.bits, q.range, q.dim()),
        Payload::MidtreadFull(q) => (PayloadKind::MidtreadFull, q.bits, q.range, q.dim()),
        Payload::Qsgd(q) => (PayloadKind::Qsgd, q.bits, q.norm, q.dim()),
        Payload::RawDelta(v) => (PayloadKind::RawDelta, 0, 0.0, v.len()),
        Payload::RawFull(v) => (PayloadKind::RawFull, 0, 0.0, v.len()),
    }
}

impl PayloadKind {
    const fn tag(self) -> u8 {
        match self {
            PayloadKind::MidtreadDelta => TAG_MT_DELTA,
            PayloadKind::MidtreadFull => TAG_MT_FULL,
            PayloadKind::Qsgd => TAG_QSGD,
            PayloadKind::RawDelta => TAG_RAW_DELTA,
            PayloadKind::RawFull => TAG_RAW_FULL,
        }
    }
}

/// Serialize a payload to wire bytes.
pub fn encode(p: &Payload) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(p, &mut out);
    out
}

/// Serialize a payload into `out` (cleared first; capacity is kept so
/// per-device wire buffers stop allocating after the first round).
pub fn encode_into(p: &Payload, out: &mut Vec<u8>) {
    out.clear();
    let (kind, bits, scale, n) = header_of(p);
    out.reserve(HEADER_BYTES + body_len(kind, bits, n));
    out.push(kind.tag());
    out.push(bits);
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
            packing::pack_into(&q.psi, q.bits, out);
        }
        Payload::Qsgd(q) => {
            packing::pack_signs_into(&q.signs, out);
            packing::pack_into(&q.mags, q.bits, out);
        }
        Payload::RawDelta(v) | Payload::RawFull(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Borrowed zero-copy view of an encoded upload: header parsed, body
/// left packed in the wire buffer. See the module docs.
#[derive(Clone, Copy, Debug)]
pub struct PayloadView<'a> {
    /// Payload kind from the wire tag.
    pub kind: PayloadKind,
    /// Quantization level (0 for raw payloads).
    pub bits: u8,
    /// Range `R` (mid-tread) or `‖v‖₂` (QSGD); 0 for raw payloads.
    pub scale: f32,
    /// Element count.
    pub len: usize,
    /// Packed body, exactly `body_len` bytes.
    pub body: &'a [u8],
}

/// Parse the header of `bytes` and borrow the body — the zero-copy
/// counterpart of [`decode`]. Validates tag, bits, and body length.
pub fn view(bytes: &[u8]) -> Result<PayloadView<'_>, WireError> {
    if bytes.len() < HEADER_BYTES {
        return Err(WireError::Truncated {
            need: HEADER_BYTES,
            have: bytes.len(),
        });
    }
    let kind = match bytes[0] {
        TAG_MT_DELTA => PayloadKind::MidtreadDelta,
        TAG_MT_FULL => PayloadKind::MidtreadFull,
        TAG_QSGD => PayloadKind::Qsgd,
        TAG_RAW_DELTA => PayloadKind::RawDelta,
        TAG_RAW_FULL => PayloadKind::RawFull,
        t => return Err(WireError::UnknownTag(t)),
    };
    let bits = bytes[1];
    match kind {
        PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull if !(1..=32).contains(&bits) => {
            return Err(WireError::BadBits(bits));
        }
        PayloadKind::Qsgd if !(1..=31).contains(&bits) => {
            return Err(WireError::BadBits(bits));
        }
        _ => {}
    }
    let scale = f32::from_le_bytes(bytes[2..6].try_into().unwrap());
    let len = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
    let need = body_len(kind, bits, len);
    if bytes.len() < HEADER_BYTES + need {
        return Err(WireError::Truncated {
            need: HEADER_BYTES + need,
            have: bytes.len(),
        });
    }
    Ok(PayloadView {
        kind,
        bits,
        scale,
        len,
        body: &bytes[HEADER_BYTES..HEADER_BYTES + need],
    })
}

impl PayloadView<'_> {
    /// Quantization level used, if any (for metrics).
    pub fn level(&self) -> Option<u8> {
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull | PayloadKind::Qsgd => {
                Some(self.bits)
            }
            _ => None,
        }
    }

    /// Materialize an owned [`Payload`] (tests, legacy callers).
    pub fn to_owned(&self) -> Payload {
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => {
                let q = QuantizedVec {
                    bits: self.bits,
                    range: self.scale,
                    psi: packing::unpack(self.body, self.bits, self.len),
                };
                if self.kind == PayloadKind::MidtreadDelta {
                    Payload::MidtreadDelta(q)
                } else {
                    Payload::MidtreadFull(q)
                }
            }
            PayloadKind::Qsgd => {
                let sign_bytes = self.len.div_ceil(8);
                Payload::Qsgd(QsgdVec {
                    bits: self.bits,
                    norm: self.scale,
                    signs: packing::unpack_signs(&self.body[..sign_bytes], self.len),
                    mags: packing::unpack(&self.body[sign_bytes..], self.bits, self.len),
                })
            }
            PayloadKind::RawDelta | PayloadKind::RawFull => {
                let v: Vec<f32> = self
                    .body
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                if self.kind == PayloadKind::RawDelta {
                    Payload::RawDelta(v)
                } else {
                    Payload::RawFull(v)
                }
            }
        }
    }

    /// Fused fold step (§Perf): add this payload's contribution to one
    /// contiguous shard `out = direction[base .. base + out.len()]`,
    /// scaled by `scale`, going straight from the packed body — no ψ
    /// materialization, no dense scratch.
    ///
    /// `mask` is the uploading device's capacity mask (`len` must equal
    /// its support). Because mask indices are sorted, the support
    /// positions targeting the shard form one contiguous code range,
    /// located by binary search; per-element arithmetic is independent
    /// of shard boundaries, so any shard partition produces bit-identical
    /// results.
    pub fn scatter_add_shard(&self, mask: &CapacityMask, scale: f32, base: usize, out: &mut [f32]) {
        debug_assert_eq!(self.len, mask.support());
        let hi = base + out.len();
        let (codes, targets) = if mask.is_full() {
            (base.min(self.len)..hi.min(self.len), None)
        } else {
            let idx = mask.indices.as_slice();
            let p0 = idx.partition_point(|&i| (i as usize) < base);
            let p1 = idx.partition_point(|&i| (i as usize) < hi);
            (p0..p1, Some(idx))
        };
        if codes.is_empty() {
            return;
        }
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => {
                midtread::dequantize_scatter_add(
                    self.body, self.bits, self.scale, codes, targets, base, scale, out,
                );
            }
            PayloadKind::Qsgd => {
                let sign_bytes = self.len.div_ceil(8);
                qsgd::dequantize_scatter_add(
                    &self.body[..sign_bytes],
                    &self.body[sign_bytes..],
                    self.bits,
                    self.scale,
                    codes,
                    targets,
                    base,
                    scale,
                    out,
                );
            }
            PayloadKind::RawDelta | PayloadKind::RawFull => {
                raw_scatter_add(self.body, codes, targets, base, scale, out);
            }
        }
    }
}

/// Raw-f32 leg of the fused fold: read elements straight from the wire
/// body and scatter-add.
fn raw_scatter_add(
    body: &[u8],
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    for i in codes {
        let v = f32::from_le_bytes(body[4 * i..4 * i + 4].try_into().unwrap());
        let t = match targets {
            None => i - out_base,
            Some(idx) => idx[i] as usize - out_base,
        };
        out[t] += scale * v;
    }
}

/// One delivered upload as the server fold consumes it: originating
/// device + borrowed wire bytes (validated by the channel at receive
/// time).
#[derive(Clone, Copy, Debug)]
pub struct UploadRef<'a> {
    /// Originating device id.
    pub device: usize,
    /// The validated wire bytes (header + packed body).
    pub bytes: &'a [u8],
}

impl<'a> UploadRef<'a> {
    /// Zero-copy view of the payload (header re-parse only; the channel
    /// already validated the bytes).
    pub fn view(&self) -> PayloadView<'a> {
        view(self.bytes).expect("channel delivers only validated wire bytes")
    }
}

/// Owned wire bytes + device id — staging convenience for tests and
/// benches that construct server folds directly.
#[derive(Clone, Debug)]
pub struct EncodedUpload {
    /// Originating device id.
    pub device: usize,
    /// The encoded wire bytes.
    pub bytes: Vec<u8>,
}

impl EncodedUpload {
    /// Encode `p` as coming from `device`.
    pub fn encode(device: usize, p: &Payload) -> Self {
        Self {
            device,
            bytes: encode(p),
        }
    }

    /// Borrow as the fold-facing [`UploadRef`].
    pub fn as_upload(&self) -> UploadRef<'_> {
        UploadRef {
            device: self.device,
            bytes: &self.bytes,
        }
    }
}

/// Borrow a whole staged round (`EncodedUpload`s → `UploadRef`s).
pub fn upload_refs(staged: &[EncodedUpload]) -> Vec<UploadRef<'_>> {
    staged.iter().map(EncodedUpload::as_upload).collect()
}

/// Deserialize wire bytes back into an owned payload.
pub fn decode(bytes: &[u8]) -> Result<Payload, WireError> {
    Ok(view(bytes)?.to_owned())
}

/// Exact wire size in bits without encoding (used by size assertions and
/// fast-path accounting; must agree with `encode(p).len() * 8` — tested).
pub fn wire_bits(p: &Payload) -> u64 {
    let (kind, bits, _, n) = header_of(p);
    ((HEADER_BYTES + body_len(kind, bits, n)) * 8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;
    use crate::quant::qsgd as qsgd_quant;
    use crate::util::rng::Xoshiro256pp;

    fn sample_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn midtread_roundtrip() {
        let v = sample_vec(300, 1);
        for bits in [1u8, 3, 8, 13] {
            let q = quantize(&v, bits);
            for p in [
                Payload::MidtreadDelta(q.clone()),
                Payload::MidtreadFull(q.clone()),
            ] {
                let enc = encode(&p);
                assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
                assert_eq!(decode(&enc).unwrap(), p);
            }
        }
    }

    #[test]
    fn qsgd_roundtrip() {
        let v = sample_vec(127, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let q = qsgd_quant::quantize(&v, 4, &mut rng);
        let p = Payload::Qsgd(q);
        let enc = encode(&p);
        assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
        assert_eq!(decode(&enc).unwrap(), p);
    }

    #[test]
    fn raw_roundtrip() {
        let v = sample_vec(64, 4);
        for p in [Payload::RawDelta(v.clone()), Payload::RawFull(v.clone())] {
            let enc = encode(&p);
            assert_eq!(enc.len(), HEADER_BYTES + 256);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn view_borrows_packed_body() {
        let v = sample_vec(1000, 8);
        let q = quantize(&v, 4);
        let p = Payload::MidtreadFull(q.clone());
        let enc = encode(&p);
        let view = view(&enc).unwrap();
        assert_eq!(view.kind, PayloadKind::MidtreadFull);
        assert_eq!(view.bits, 4);
        assert_eq!(view.len, 1000);
        assert_eq!(view.scale, q.range);
        // Body stays packed: 1000 4-bit codes = 500 bytes, untouched.
        assert_eq!(view.body.len(), 500);
        assert_eq!(view.body, &enc[HEADER_BYTES..]);
        assert_eq!(view.to_owned(), p);
        assert_eq!(view.level(), Some(4));
    }

    #[test]
    fn view_scatter_matches_owned_fold() {
        use crate::hetero::CapacityMask;
        let d = 257;
        let v = sample_vec(d, 9);
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let payloads = vec![
            Payload::MidtreadDelta(quantize(&v, 4)),
            Payload::MidtreadFull(quantize(&v, 9)),
            Payload::Qsgd(qsgd_quant::quantize(&v, 5, &mut rng)),
            Payload::RawDelta(v.clone()),
            Payload::RawFull(v.clone()),
        ];
        let mask = CapacityMask::full(d);
        for p in &payloads {
            let enc = encode(p);
            let view = view(&enc).unwrap();
            // Whole-vector shard vs two uneven shards: bit-identical.
            let mut whole = vec![0.0f32; d];
            view.scatter_add_shard(&mask, 0.5, 0, &mut whole);
            let mut split = vec![0.0f32; d];
            let (a, b) = split.split_at_mut(100);
            view.scatter_add_shard(&mask, 0.5, 0, a);
            view.scatter_add_shard(&mask, 0.5, 100, b);
            for (x, y) in whole.iter().zip(&split) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn view_scatter_respects_masks() {
        use crate::hetero::CapacityMask;
        use crate::problems::ParamLayout;
        let layout = ParamLayout::contiguous(&[("w", vec![8, 8])]);
        let mask = CapacityMask::from_layout(&layout, 0.5);
        let support = mask.support();
        let v = sample_vec(support, 11);
        let p = Payload::MidtreadDelta(quantize(&v, 6));
        let enc = encode(&p);
        let view = view(&enc).unwrap();
        let mut out = vec![0.0f32; 64];
        // Shards of 16 coordinates each.
        for (s, chunk) in out.chunks_mut(16).enumerate() {
            view.scatter_add_shard(&mask, 1.0, s * 16, chunk);
        }
        for (i, &x) in out.iter().enumerate() {
            let in_mask = mask.indices.contains(&(i as u32));
            assert_eq!(x != 0.0, in_mask, "index {i}");
        }
    }

    #[test]
    fn quantized_is_smaller_than_raw() {
        let v = sample_vec(10_000, 5);
        let raw = encode(&Payload::RawFull(v.clone()));
        let q4 = encode(&Payload::MidtreadFull(quantize(&v, 4)));
        // 4-bit packing ⇒ ~8x smaller than f32.
        assert!(q4.len() * 7 < raw.len(), "{} vs {}", q4.len(), raw.len());
    }

    #[test]
    fn empty_payloads() {
        for p in [
            Payload::RawFull(vec![]),
            Payload::MidtreadDelta(quantize(&[], 4)),
        ] {
            let enc = encode(&p);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99; 16]).is_err()); // unknown tag
        let v = sample_vec(32, 6);
        let mut enc = encode(&Payload::RawFull(v));
        enc.truncate(20); // truncated body
        assert!(decode(&enc).is_err());
        assert!(view(&enc).is_err());
        // Bad bits for midtread.
        let mut enc2 = encode(&Payload::MidtreadFull(quantize(&[1.0, 2.0], 4)));
        enc2[1] = 0;
        assert!(decode(&enc2).is_err());
        assert!(view(&enc2).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let p = Payload::RawFull(sample_vec(16, 7));
        let mut buf = Vec::new();
        encode_into(&p, &mut buf);
        let first = buf.clone();
        let cap = buf.capacity();
        encode_into(&p, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn level_accessor() {
        let v = sample_vec(8, 7);
        assert_eq!(Payload::MidtreadFull(quantize(&v, 6)).level(), Some(6));
        assert_eq!(Payload::RawFull(v).level(), None);
    }
}
