//! Wire format for device→server uploads.
//!
//! Every table and figure reports *actual serialized bytes × 8*, so all
//! uploads round-trip through this encoding in the simulator: the client
//! encodes, the transport counts `bytes.len()`, the server decodes.
//!
//! Layout (little-endian). The v1 **global** encoding — one scale for
//! the whole payload — is unchanged byte-for-byte:
//!
//! ```text
//! [0]      tag: u8       payload kind
//! [1]      bits: u8      quantization level (0 for raw payloads)
//! [2..6]   scale: f32    range R (mid-tread) or ‖v‖₂ (QSGD); 0 for raw
//! [6..10]  len: u32      element count d (or |support| under HeteroFL)
//! [10..]   body          packed codes / sign bitmap + codes / raw f32
//! ```
//!
//! The v2 **sectioned** encoding (distinct tags) carries one scale per
//! quantization section (`crate::quant::sections`, DESIGN.md §Wire v2):
//!
//! ```text
//! [0]      tag: u8            sectioned payload kind
//! [1]      bits: u8           quantization level (shared by sections)
//! [2..4]   n_sections: u16    section count S ≥ 1
//! [4..4+8S] S × {scale: f32, len: u32}   per-section scale + length
//! [..]     body               packed codes (one continuous stream)
//! ```
//!
//! The body is a single continuous bit-packed stream across sections
//! (codes stay `O(1)`-addressable by global element index), so the
//! shard-parallel fold only has to intersect shard ranges with section
//! ranges to pick the right scale per sub-range.
//!
//! Two server-side representations exist:
//!
//! * [`Payload`] — owned, codes materialized (`Vec<u32>` ψ). Client-side
//!   staging and tests use this.
//! * [`PayloadView`] — borrowed, zero-copy: the header is parsed, the
//!   body stays *packed* in the received byte buffer. The aggregation
//!   pipeline folds straight from views via the fused
//!   dequantize–scatter kernels (`PayloadView::scatter_add_shard`), so
//!   a 4-bit upload is never inflated to `Vec<u32>` + dense f32 scratch
//!   on its way into `direction` (§Perf in DESIGN.md).

use crate::hetero::CapacityMask;
use crate::quant::midtread::{self, QuantizedVec};
use crate::quant::packing;
use crate::quant::qsgd::{self, QsgdVec};
use crate::quant::PackedVec;

/// v1 (global) header size in bytes (tag + bits + scale + len).
pub const HEADER_BYTES: usize = 10;

/// v2 (sectioned) fixed header size in bytes (tag + bits + n_sections),
/// before the section table.
pub const SECTION_HEADER_BYTES: usize = 4;

/// Bytes per section-table entry (scale f32 + len u32).
pub const SECTION_ENTRY_BYTES: usize = 8;

/// A device upload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Mid-tread-quantized gradient *innovation* `Δq_m` — lazy
    /// aggregation family (AQUILA, LAQ, LAdaQ). Server folds
    /// incrementally: `q̄ += Δq/M`.
    MidtreadDelta(QuantizedVec),
    /// Mid-tread-quantized *full* gradient (AdaQuantFL, DAdaQuant).
    MidtreadFull(QuantizedVec),
    /// QSGD stochastically-quantized full gradient.
    Qsgd(QsgdVec),
    /// Raw f32 gradient innovation (LENA trigger uploads, MARINA
    /// correction steps are quantized — see `algorithms::marina`).
    RawDelta(Vec<f32>),
    /// Raw f32 full gradient (FedAvg baseline, MARINA sync rounds).
    RawFull(Vec<f32>),
    /// Mid-tread innovation already in packed wire form — the output of
    /// the fused quantize→pack kernels (§Perf). Same wire tag and bytes
    /// as [`Payload::MidtreadDelta`]; [`decode`] always yields the
    /// unpacked form.
    MidtreadDeltaPacked(PackedVec),
    /// Mid-tread full gradient in packed wire form (see
    /// [`Payload::MidtreadDeltaPacked`]).
    MidtreadFullPacked(PackedVec),
    /// QSGD upload in packed wire form: sign bitmap + packed magnitudes
    /// (see [`Payload::MidtreadDeltaPacked`]).
    QsgdPacked(PackedVec),
}

/// Payload kind, as carried by the wire tag byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// Mid-tread-quantized gradient innovation.
    MidtreadDelta,
    /// Mid-tread-quantized full gradient.
    MidtreadFull,
    /// QSGD stochastically-quantized full gradient.
    Qsgd,
    /// Raw f32 gradient innovation.
    RawDelta,
    /// Raw f32 full gradient.
    RawFull,
}

const TAG_MT_DELTA: u8 = 1;
const TAG_MT_FULL: u8 = 2;
const TAG_QSGD: u8 = 3;
const TAG_RAW_DELTA: u8 = 4;
const TAG_RAW_FULL: u8 = 5;
// v2 sectioned variants (per-section scales; raw payloads carry no
// scale, so they have no sectioned form).
const TAG_MT_DELTA_S: u8 = 6;
const TAG_MT_FULL_S: u8 = 7;
const TAG_QSGD_S: u8 = 8;

/// Error from [`decode`] / [`view`].
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    /// Message shorter than its header/body claims.
    #[error("message truncated: need {need} bytes, have {have}")]
    Truncated { need: usize, have: usize },
    /// Unrecognized payload kind tag.
    #[error("unknown payload tag {0}")]
    UnknownTag(u8),
    /// Bits field outside the representable range.
    #[error("invalid bits field {0}")]
    BadBits(u8),
    /// Malformed v2 section table.
    #[error("invalid section table: {0}")]
    BadSections(&'static str),
}

impl Payload {
    /// Element count carried by this payload.
    pub fn len(&self) -> usize {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => q.dim(),
            Payload::Qsgd(q) => q.dim(),
            Payload::RawDelta(v) | Payload::RawFull(v) => v.len(),
            Payload::MidtreadDeltaPacked(p)
            | Payload::MidtreadFullPacked(p)
            | Payload::QsgdPacked(p) => p.dim(),
        }
    }

    /// True for zero-element payloads.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantization level used, if any (for metrics).
    pub fn level(&self) -> Option<u8> {
        match self {
            Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => Some(q.bits),
            Payload::Qsgd(q) => Some(q.bits),
            Payload::MidtreadDeltaPacked(p)
            | Payload::MidtreadFullPacked(p)
            | Payload::QsgdPacked(p) => Some(p.bits),
            _ => None,
        }
    }
}

/// Exact body size in bytes for a payload of `kind` with `n` elements
/// at `bits` bits.
const fn body_len(kind: PayloadKind, bits: u8, n: usize) -> usize {
    match kind {
        PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => packing::packed_len(n, bits),
        PayloadKind::Qsgd => n.div_ceil(8) + packing::packed_len(n, bits),
        PayloadKind::RawDelta | PayloadKind::RawFull => 4 * n,
    }
}

fn header_of(p: &Payload) -> (PayloadKind, u8, f32, usize) {
    match p {
        Payload::MidtreadDelta(q) => (PayloadKind::MidtreadDelta, q.bits, q.range, q.dim()),
        Payload::MidtreadFull(q) => (PayloadKind::MidtreadFull, q.bits, q.range, q.dim()),
        Payload::Qsgd(q) => (PayloadKind::Qsgd, q.bits, q.norm, q.dim()),
        Payload::RawDelta(v) => (PayloadKind::RawDelta, 0, 0.0, v.len()),
        Payload::RawFull(v) => (PayloadKind::RawFull, 0, 0.0, v.len()),
        Payload::MidtreadDeltaPacked(p) => (PayloadKind::MidtreadDelta, p.bits, p.scale, p.dim()),
        Payload::MidtreadFullPacked(p) => (PayloadKind::MidtreadFull, p.bits, p.scale, p.dim()),
        Payload::QsgdPacked(p) => (PayloadKind::Qsgd, p.bits, p.scale, p.dim()),
    }
}

/// The payload's per-section `(scale, len)` table; empty = v1 global.
fn section_scales_of(p: &Payload) -> &[(f32, u32)] {
    match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => &q.section_scales,
        Payload::Qsgd(q) => &q.section_scales,
        Payload::RawDelta(_) | Payload::RawFull(_) => &[],
        Payload::MidtreadDeltaPacked(p)
        | Payload::MidtreadFullPacked(p)
        | Payload::QsgdPacked(p) => &p.section_scales,
    }
}

impl PayloadKind {
    const fn tag(self) -> u8 {
        match self {
            PayloadKind::MidtreadDelta => TAG_MT_DELTA,
            PayloadKind::MidtreadFull => TAG_MT_FULL,
            PayloadKind::Qsgd => TAG_QSGD,
            PayloadKind::RawDelta => TAG_RAW_DELTA,
            PayloadKind::RawFull => TAG_RAW_FULL,
        }
    }

    /// The v2 sectioned tag for this kind (raw payloads have none).
    const fn sectioned_tag(self) -> u8 {
        match self {
            PayloadKind::MidtreadDelta => TAG_MT_DELTA_S,
            PayloadKind::MidtreadFull => TAG_MT_FULL_S,
            PayloadKind::Qsgd => TAG_QSGD_S,
            // Raw payloads carry no scale; encode asserts this is
            // unreachable.
            PayloadKind::RawDelta | PayloadKind::RawFull => 0,
        }
    }
}

/// Serialize a payload to wire bytes.
pub fn encode(p: &Payload) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(p, &mut out);
    out
}

/// Serialize a payload into `out` (cleared first; capacity is kept so
/// per-device wire buffers stop allocating after the first round).
/// Payloads without section scales use the v1 global layout —
/// byte-identical to the pre-sectioning format; payloads carrying
/// `section_scales` use the v2 sectioned layout.
pub fn encode_into(p: &Payload, out: &mut Vec<u8>) {
    out.clear();
    let (kind, bits, scale, n) = header_of(p);
    let sects = section_scales_of(p);
    if sects.is_empty() {
        out.reserve(HEADER_BYTES + body_len(kind, bits, n));
        out.push(kind.tag());
        out.push(bits);
        out.extend_from_slice(&scale.to_le_bytes());
        out.extend_from_slice(&(n as u32).to_le_bytes());
    } else {
        debug_assert_eq!(
            sects.iter().map(|&(_, l)| l as usize).sum::<usize>(),
            n,
            "section lengths must cover the payload"
        );
        assert!(
            sects.len() <= u16::MAX as usize,
            "section count exceeds the wire u16 field"
        );
        out.reserve(
            SECTION_HEADER_BYTES + SECTION_ENTRY_BYTES * sects.len() + body_len(kind, bits, n),
        );
        let tag = kind.sectioned_tag();
        assert!(tag != 0, "raw payloads cannot be sectioned");
        out.push(tag);
        out.push(bits);
        out.extend_from_slice(&(sects.len() as u16).to_le_bytes());
        for &(s, l) in sects {
            out.extend_from_slice(&s.to_le_bytes());
            out.extend_from_slice(&l.to_le_bytes());
        }
    }
    match p {
        Payload::MidtreadDelta(q) | Payload::MidtreadFull(q) => {
            packing::pack_into(&q.psi, q.bits, out);
        }
        Payload::Qsgd(q) => {
            packing::pack_signs_into(&q.signs, out);
            packing::pack_into(&q.mags, q.bits, out);
        }
        Payload::RawDelta(v) | Payload::RawFull(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Payload::MidtreadDeltaPacked(p)
        | Payload::MidtreadFullPacked(p)
        | Payload::QsgdPacked(p) => {
            debug_assert_eq!(
                p.body.len(),
                body_len(kind, p.bits, p.dim()),
                "packed body length disagrees with the wire layout"
            );
            out.extend_from_slice(&p.body);
        }
    }
}

/// Zero-copy view of a v2 section table: the raw `(scale, len)` entry
/// bytes stay in the received buffer; entries are decoded on access.
#[derive(Clone, Copy, Debug)]
pub struct SectionTable<'a> {
    /// Raw little-endian entry bytes, exactly `count × 8` long.
    entries: &'a [u8],
    /// Section count `S ≥ 1`.
    count: usize,
}

impl<'a> SectionTable<'a> {
    /// Number of sections.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Scale of section `i`.
    pub fn scale(&self, i: usize) -> f32 {
        let o = i * SECTION_ENTRY_BYTES;
        f32::from_le_bytes(self.entries[o..o + 4].try_into().unwrap())
    }

    /// Element count of section `i`.
    pub fn len(&self, i: usize) -> usize {
        let o = i * SECTION_ENTRY_BYTES + 4;
        u32::from_le_bytes(self.entries[o..o + 4].try_into().unwrap()) as usize
    }

    /// Whether the table is empty (never true for a valid v2 payload).
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Iterate `(scale, element_range)` per section, with a running
    /// offset over the payload's element index space.
    pub fn iter(&self) -> impl Iterator<Item = (f32, std::ops::Range<usize>)> + 'a {
        let table = *self;
        let mut off = 0usize;
        (0..table.count).map(move |i| {
            let r = off..off + table.len(i);
            off = r.end;
            (table.scale(i), r)
        })
    }

    /// Materialize the `(scale, len)` pairs (owned decode path).
    pub fn to_vec(&self) -> Vec<(f32, u32)> {
        (0..self.count)
            .map(|i| (self.scale(i), self.len(i) as u32))
            .collect()
    }
}

/// Borrowed zero-copy view of an encoded upload: header parsed, body
/// left packed in the wire buffer. See the module docs.
#[derive(Clone, Copy, Debug)]
pub struct PayloadView<'a> {
    /// Payload kind from the wire tag.
    pub kind: PayloadKind,
    /// Quantization level (0 for raw payloads).
    pub bits: u8,
    /// Range `R` (mid-tread) or `‖v‖₂` (QSGD); 0 for raw payloads. For
    /// sectioned payloads this is the max section scale (metrics only —
    /// the fold reads per-section scales from `sections`).
    pub scale: f32,
    /// Total element count.
    pub len: usize,
    /// v2 per-section scale table (`None` for v1 global payloads).
    pub sections: Option<SectionTable<'a>>,
    /// Packed body, exactly `body_len` bytes.
    pub body: &'a [u8],
}

/// Parse the header of `bytes` and borrow the body — the zero-copy
/// counterpart of [`decode`]. Validates tag, bits, the v2 section
/// table, and body length; never panics or over-reads on malformed
/// input (property-tested in `rust/tests/prop_wire.rs`).
pub fn view(bytes: &[u8]) -> Result<PayloadView<'_>, WireError> {
    if bytes.is_empty() {
        return Err(WireError::Truncated {
            need: SECTION_HEADER_BYTES.min(HEADER_BYTES),
            have: 0,
        });
    }
    let (kind, sectioned) = match bytes[0] {
        TAG_MT_DELTA => (PayloadKind::MidtreadDelta, false),
        TAG_MT_FULL => (PayloadKind::MidtreadFull, false),
        TAG_QSGD => (PayloadKind::Qsgd, false),
        TAG_RAW_DELTA => (PayloadKind::RawDelta, false),
        TAG_RAW_FULL => (PayloadKind::RawFull, false),
        TAG_MT_DELTA_S => (PayloadKind::MidtreadDelta, true),
        TAG_MT_FULL_S => (PayloadKind::MidtreadFull, true),
        TAG_QSGD_S => (PayloadKind::Qsgd, true),
        t => return Err(WireError::UnknownTag(t)),
    };
    let header = if sectioned { SECTION_HEADER_BYTES } else { HEADER_BYTES };
    if bytes.len() < header {
        return Err(WireError::Truncated {
            need: header,
            have: bytes.len(),
        });
    }
    let bits = bytes[1];
    match kind {
        PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull if !(1..=32).contains(&bits) => {
            return Err(WireError::BadBits(bits));
        }
        PayloadKind::Qsgd if !(1..=31).contains(&bits) => {
            return Err(WireError::BadBits(bits));
        }
        _ => {}
    }
    let (scale, len, sections, body_start) = if sectioned {
        let count = u16::from_le_bytes(bytes[2..4].try_into().unwrap()) as usize;
        if count == 0 {
            return Err(WireError::BadSections("zero sections"));
        }
        let table_end = SECTION_HEADER_BYTES + count * SECTION_ENTRY_BYTES;
        if bytes.len() < table_end {
            return Err(WireError::Truncated {
                need: table_end,
                have: bytes.len(),
            });
        }
        let table = SectionTable {
            entries: &bytes[SECTION_HEADER_BYTES..table_end],
            count,
        };
        let mut total = 0usize;
        let mut max_scale = 0.0f32;
        for i in 0..count {
            let l = table.len(i);
            if l == 0 && count > 1 {
                return Err(WireError::BadSections("zero-length section"));
            }
            total = total
                .checked_add(l)
                .ok_or(WireError::BadSections("length overflow"))?;
            let s = table.scale(i);
            if !s.is_finite() || s < 0.0 {
                return Err(WireError::BadSections("non-finite or negative scale"));
            }
            max_scale = max_scale.max(s);
        }
        if total > u32::MAX as usize {
            return Err(WireError::BadSections("length overflow"));
        }
        (max_scale, total, Some(table), table_end)
    } else {
        let scale = f32::from_le_bytes(bytes[2..6].try_into().unwrap());
        let len = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        (scale, len, None, HEADER_BYTES)
    };
    let need = body_len(kind, bits, len);
    let total_need = body_start
        .checked_add(need)
        .ok_or(WireError::BadSections("length overflow"))?;
    if bytes.len() < total_need {
        return Err(WireError::Truncated {
            need: total_need,
            have: bytes.len(),
        });
    }
    Ok(PayloadView {
        kind,
        bits,
        scale,
        len,
        sections,
        body: &bytes[body_start..total_need],
    })
}

impl PayloadView<'_> {
    /// Quantization level used, if any (for metrics).
    pub fn level(&self) -> Option<u8> {
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull | PayloadKind::Qsgd => {
                Some(self.bits)
            }
            _ => None,
        }
    }

    /// Materialize an owned [`Payload`] (tests, legacy callers).
    pub fn to_owned(&self) -> Payload {
        let section_scales = self
            .sections
            .map(|t| t.to_vec())
            .unwrap_or_default();
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => {
                let q = QuantizedVec {
                    bits: self.bits,
                    range: self.scale,
                    psi: packing::unpack(self.body, self.bits, self.len),
                    section_scales,
                };
                if self.kind == PayloadKind::MidtreadDelta {
                    Payload::MidtreadDelta(q)
                } else {
                    Payload::MidtreadFull(q)
                }
            }
            PayloadKind::Qsgd => {
                let sign_bytes = self.len.div_ceil(8);
                Payload::Qsgd(QsgdVec {
                    bits: self.bits,
                    norm: self.scale,
                    signs: packing::unpack_signs(&self.body[..sign_bytes], self.len),
                    mags: packing::unpack(&self.body[sign_bytes..], self.bits, self.len),
                    section_scales,
                })
            }
            PayloadKind::RawDelta | PayloadKind::RawFull => {
                let v: Vec<f32> = self
                    .body
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                if self.kind == PayloadKind::RawDelta {
                    Payload::RawDelta(v)
                } else {
                    Payload::RawFull(v)
                }
            }
        }
    }

    /// Fused fold step (§Perf): add this payload's contribution to one
    /// contiguous shard `out = direction[base .. base + out.len()]`,
    /// scaled by `scale`, going straight from the packed body — no ψ
    /// materialization, no dense scratch.
    ///
    /// `mask` is the uploading device's capacity mask (`len` must equal
    /// its support). Because mask indices are sorted, the support
    /// positions targeting the shard form one contiguous code range,
    /// located by binary search; per-element arithmetic is independent
    /// of shard boundaries, so any shard partition produces bit-identical
    /// results.
    pub fn scatter_add_shard(&self, mask: &CapacityMask, scale: f32, base: usize, out: &mut [f32]) {
        debug_assert_eq!(self.len, mask.support());
        let hi = base + out.len();
        let (codes, targets) = if mask.is_full() {
            (base.min(self.len)..hi.min(self.len), None)
        } else {
            let idx = mask.indices.as_slice();
            let p0 = idx.partition_point(|&i| (i as usize) < base);
            let p1 = idx.partition_point(|&i| (i as usize) < hi);
            (p0..p1, Some(idx))
        };
        if codes.is_empty() {
            return;
        }
        if let Some(table) = self.sections {
            // Sectioned payload: intersect the shard's code range with
            // each section's element range and fold that sub-range at
            // the section's own scale. Per-element arithmetic is
            // independent of both shard and section boundaries, so the
            // shard-parallel fold stays bit-identical to the serial one
            // (property-tested in `rust/tests/prop_sections.rs`).
            let sign_bytes = self.len.div_ceil(8);
            for (sect_scale, sect_range) in table.iter() {
                if sect_range.start >= codes.end {
                    break;
                }
                let lo = codes.start.max(sect_range.start);
                let hi = codes.end.min(sect_range.end);
                if lo >= hi {
                    continue;
                }
                match self.kind {
                    PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => {
                        midtread::dequantize_scatter_add(
                            self.body, self.bits, sect_scale, lo..hi, targets, base, scale, out,
                        );
                    }
                    PayloadKind::Qsgd => {
                        qsgd::dequantize_scatter_add(
                            &self.body[..sign_bytes],
                            &self.body[sign_bytes..],
                            self.bits,
                            sect_scale,
                            lo..hi,
                            targets,
                            base,
                            scale,
                            out,
                        );
                    }
                    // view() never yields a sectioned raw payload.
                    PayloadKind::RawDelta | PayloadKind::RawFull => unreachable!(),
                }
            }
            return;
        }
        match self.kind {
            PayloadKind::MidtreadDelta | PayloadKind::MidtreadFull => {
                midtread::dequantize_scatter_add(
                    self.body, self.bits, self.scale, codes, targets, base, scale, out,
                );
            }
            PayloadKind::Qsgd => {
                let sign_bytes = self.len.div_ceil(8);
                qsgd::dequantize_scatter_add(
                    &self.body[..sign_bytes],
                    &self.body[sign_bytes..],
                    self.bits,
                    self.scale,
                    codes,
                    targets,
                    base,
                    scale,
                    out,
                );
            }
            PayloadKind::RawDelta | PayloadKind::RawFull => {
                raw_scatter_add(self.body, codes, targets, base, scale, out);
            }
        }
    }
}

/// Raw-f32 leg of the fused fold: read elements straight from the wire
/// body and scatter-add.
fn raw_scatter_add(
    body: &[u8],
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    for i in codes {
        let v = f32::from_le_bytes(body[4 * i..4 * i + 4].try_into().unwrap());
        let t = match targets {
            None => i - out_base,
            Some(idx) => idx[i] as usize - out_base,
        };
        out[t] += scale * v;
    }
}

/// One delivered upload as the server fold consumes it: originating
/// device + borrowed wire bytes (validated by the channel at receive
/// time).
#[derive(Clone, Copy, Debug)]
pub struct UploadRef<'a> {
    /// Originating device id.
    pub device: usize,
    /// The validated wire bytes (header + packed body).
    pub bytes: &'a [u8],
}

impl<'a> UploadRef<'a> {
    /// Zero-copy view of the payload (header re-parse only; the channel
    /// already validated the bytes).
    pub fn view(&self) -> PayloadView<'a> {
        view(self.bytes).expect("channel delivers only validated wire bytes")
    }
}

/// Owned wire bytes + device id — staging convenience for tests and
/// benches that construct server folds directly.
#[derive(Clone, Debug)]
pub struct EncodedUpload {
    /// Originating device id.
    pub device: usize,
    /// The encoded wire bytes.
    pub bytes: Vec<u8>,
}

impl EncodedUpload {
    /// Encode `p` as coming from `device`.
    pub fn encode(device: usize, p: &Payload) -> Self {
        Self {
            device,
            bytes: encode(p),
        }
    }

    /// Borrow as the fold-facing [`UploadRef`].
    pub fn as_upload(&self) -> UploadRef<'_> {
        UploadRef {
            device: self.device,
            bytes: &self.bytes,
        }
    }
}

/// Borrow a whole staged round (`EncodedUpload`s → `UploadRef`s).
pub fn upload_refs(staged: &[EncodedUpload]) -> Vec<UploadRef<'_>> {
    staged.iter().map(EncodedUpload::as_upload).collect()
}

/// Deserialize wire bytes back into an owned payload.
pub fn decode(bytes: &[u8]) -> Result<Payload, WireError> {
    Ok(view(bytes)?.to_owned())
}

/// Exact wire size in bits without encoding (used by size assertions and
/// fast-path accounting; must agree with `encode(p).len() * 8` — tested).
pub fn wire_bits(p: &Payload) -> u64 {
    let (kind, bits, _, n) = header_of(p);
    let sects = section_scales_of(p);
    let header = if sects.is_empty() {
        HEADER_BYTES
    } else {
        SECTION_HEADER_BYTES + SECTION_ENTRY_BYTES * sects.len()
    };
    ((header + body_len(kind, bits, n)) * 8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;
    use crate::quant::qsgd as qsgd_quant;
    use crate::util::rng::Xoshiro256pp;

    fn sample_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn midtread_roundtrip() {
        let v = sample_vec(300, 1);
        for bits in [1u8, 3, 8, 13] {
            let q = quantize(&v, bits);
            for p in [
                Payload::MidtreadDelta(q.clone()),
                Payload::MidtreadFull(q.clone()),
            ] {
                let enc = encode(&p);
                assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
                assert_eq!(decode(&enc).unwrap(), p);
            }
        }
    }

    #[test]
    fn qsgd_roundtrip() {
        let v = sample_vec(127, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let q = qsgd_quant::quantize(&v, 4, &mut rng);
        let p = Payload::Qsgd(q);
        let enc = encode(&p);
        assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
        assert_eq!(decode(&enc).unwrap(), p);
    }

    #[test]
    fn raw_roundtrip() {
        let v = sample_vec(64, 4);
        for p in [Payload::RawDelta(v.clone()), Payload::RawFull(v.clone())] {
            let enc = encode(&p);
            assert_eq!(enc.len(), HEADER_BYTES + 256);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn view_borrows_packed_body() {
        let v = sample_vec(1000, 8);
        let q = quantize(&v, 4);
        let p = Payload::MidtreadFull(q.clone());
        let enc = encode(&p);
        let view = view(&enc).unwrap();
        assert_eq!(view.kind, PayloadKind::MidtreadFull);
        assert_eq!(view.bits, 4);
        assert_eq!(view.len, 1000);
        assert_eq!(view.scale, q.range);
        // Body stays packed: 1000 4-bit codes = 500 bytes, untouched.
        assert_eq!(view.body.len(), 500);
        assert_eq!(view.body, &enc[HEADER_BYTES..]);
        assert_eq!(view.to_owned(), p);
        assert_eq!(view.level(), Some(4));
    }

    #[test]
    fn view_scatter_matches_owned_fold() {
        use crate::hetero::CapacityMask;
        let d = 257;
        let v = sample_vec(d, 9);
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let payloads = vec![
            Payload::MidtreadDelta(quantize(&v, 4)),
            Payload::MidtreadFull(quantize(&v, 9)),
            Payload::Qsgd(qsgd_quant::quantize(&v, 5, &mut rng)),
            Payload::RawDelta(v.clone()),
            Payload::RawFull(v.clone()),
        ];
        let mask = CapacityMask::full(d);
        for p in &payloads {
            let enc = encode(p);
            let view = view(&enc).unwrap();
            // Whole-vector shard vs two uneven shards: bit-identical.
            let mut whole = vec![0.0f32; d];
            view.scatter_add_shard(&mask, 0.5, 0, &mut whole);
            let mut split = vec![0.0f32; d];
            let (a, b) = split.split_at_mut(100);
            view.scatter_add_shard(&mask, 0.5, 0, a);
            view.scatter_add_shard(&mask, 0.5, 100, b);
            for (x, y) in whole.iter().zip(&split) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn view_scatter_respects_masks() {
        use crate::hetero::CapacityMask;
        use crate::problems::ParamLayout;
        let layout = ParamLayout::contiguous(&[("w", vec![8, 8])]);
        let mask = CapacityMask::from_layout(&layout, 0.5);
        let support = mask.support();
        let v = sample_vec(support, 11);
        let p = Payload::MidtreadDelta(quantize(&v, 6));
        let enc = encode(&p);
        let view = view(&enc).unwrap();
        let mut out = vec![0.0f32; 64];
        // Shards of 16 coordinates each.
        for (s, chunk) in out.chunks_mut(16).enumerate() {
            view.scatter_add_shard(&mask, 1.0, s * 16, chunk);
        }
        for (i, &x) in out.iter().enumerate() {
            let in_mask = mask.indices.contains(&(i as u32));
            assert_eq!(x != 0.0, in_mask, "index {i}");
        }
    }

    #[test]
    fn sectioned_roundtrip_and_header_size() {
        use crate::quant::midtread::quantize_sections;
        use crate::quant::qsgd::quantize_sections as qsgd_quantize_sections;
        use crate::quant::Sections;
        let v = sample_vec(300, 21);
        let sections = Sections::from_lens([100usize, 80, 120]);
        let q = quantize_sections(&v, 5, &sections);
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let qs = qsgd_quantize_sections(&v, 5, &sections, &mut rng);
        for p in [
            Payload::MidtreadDelta(q.clone()),
            Payload::MidtreadFull(q.clone()),
            Payload::Qsgd(qs.clone()),
        ] {
            let enc = encode(&p);
            assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
            assert_eq!(decode(&enc).unwrap(), p);
            let view = view(&enc).unwrap();
            assert_eq!(view.len, 300);
            let table = view.sections.expect("sectioned payload has a table");
            assert_eq!(table.count(), 3);
            assert!(!table.is_empty());
            assert_eq!(table.len(0), 100);
            assert_eq!(table.len(2), 120);
            let ranges: Vec<_> = table.iter().map(|(_, r)| r).collect();
            assert_eq!(ranges, vec![0..100, 100..180, 180..300]);
            // v2 header = 4 + 8·S bytes (v1 is 10).
            let body = crate::quant::packing::packed_len(300, 5)
                + if matches!(p, Payload::Qsgd(_)) { 300usize.div_ceil(8) } else { 0 };
            assert_eq!(enc.len(), 4 + 8 * 3 + body);
        }
    }

    #[test]
    fn single_section_quantize_is_byte_identical_to_global() {
        use crate::quant::midtread::quantize_sections;
        use crate::quant::Sections;
        let v = sample_vec(257, 23);
        let global = encode(&Payload::MidtreadFull(quantize(&v, 7)));
        let single = encode(&Payload::MidtreadFull(quantize_sections(
            &v,
            7,
            &Sections::global(v.len()),
        )));
        assert_eq!(global, single);
        assert_eq!(global[0], 2); // v1 tag, not a sectioned one
    }

    #[test]
    fn sectioned_scatter_matches_dense_dequantize() {
        use crate::hetero::CapacityMask;
        use crate::quant::midtread::{dequantize_into as mt_deq, quantize_sections};
        use crate::quant::Sections;
        let d = 513;
        let v = sample_vec(d, 24);
        let sections = Sections::from_lens([200usize, 13, 300]);
        let p = Payload::MidtreadDelta(quantize_sections(&v, 4, &sections));
        let enc = encode(&p);
        let view = view(&enc).unwrap();
        // Dense reference.
        let q = match &p {
            Payload::MidtreadDelta(q) => q,
            _ => unreachable!(),
        };
        let mut dense = vec![0.0f32; d];
        mt_deq(q, &mut dense);
        let mut expect = vec![0.0f32; d];
        for (e, x) in expect.iter_mut().zip(&dense) {
            *e += 0.5 * x;
        }
        // Fused over three uneven shards (boundaries straddle
        // sections): bit-identical.
        let mask = CapacityMask::full(d);
        let mut out = vec![0.0f32; d];
        let (a, rest) = out.split_at_mut(150);
        let (b, c) = rest.split_at_mut(100);
        view.scatter_add_shard(&mask, 0.5, 0, a);
        view.scatter_add_shard(&mask, 0.5, 150, b);
        view.scatter_add_shard(&mask, 0.5, 250, c);
        for (i, (x, y)) in out.iter().zip(&expect).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "i={i}");
        }
    }

    #[test]
    fn sectioned_rejects_malformed_tables() {
        use crate::quant::midtread::quantize_sections;
        use crate::quant::Sections;
        let v = sample_vec(64, 25);
        let sections = Sections::from_lens([32usize, 32]);
        let enc = encode(&Payload::MidtreadFull(quantize_sections(&v, 6, &sections)));
        // Zero section count.
        let mut bad = enc.clone();
        bad[2] = 0;
        bad[3] = 0;
        assert!(matches!(decode(&bad), Err(WireError::BadSections(_))));
        // Oversized count → table truncated.
        let mut bad = enc.clone();
        bad[2] = 0xFF;
        bad[3] = 0xFF;
        assert!(matches!(decode(&bad), Err(WireError::Truncated { .. })));
        // Oversized section len → body truncated.
        let mut bad = enc.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&bad).is_err());
        // Truncated body.
        let mut bad = enc.clone();
        bad.truncate(enc.len() - 1);
        assert!(matches!(decode(&bad), Err(WireError::Truncated { .. })));
        // Non-finite scale.
        let mut bad = enc;
        bad[4..8].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(matches!(decode(&bad), Err(WireError::BadSections(_))));
    }

    #[test]
    fn packed_payloads_encode_byte_identical_and_decode_unpacked() {
        use crate::quant::Sections;
        let v = sample_vec(300, 30);
        let sections = Sections::from_lens([100usize, 80, 120]);
        // Mid-tread, global: full and delta wrappers over one PackedVec.
        let q = quantize(&v, 5);
        let qp = midtread::quantize_packed_buf(&v, 5, Vec::new());
        for (packed, plain) in [
            (
                Payload::MidtreadFullPacked(qp.clone()),
                Payload::MidtreadFull(q.clone()),
            ),
            (
                Payload::MidtreadDeltaPacked(qp.clone()),
                Payload::MidtreadDelta(q.clone()),
            ),
        ] {
            let enc = encode(&packed);
            assert_eq!(enc, encode(&plain));
            assert_eq!(enc.len() as u64 * 8, wire_bits(&packed));
            assert_eq!(packed.level(), plain.level());
            assert_eq!(packed.len(), plain.len());
            // Decode always yields the unpacked form.
            assert_eq!(decode(&enc).unwrap(), plain);
        }
        // Mid-tread, sectioned.
        let qs = midtread::quantize_sections(&v, 5, &sections);
        let qsp = midtread::quantize_sections_packed_buf(&v, 5, &sections, Vec::new());
        let enc = encode(&Payload::MidtreadFullPacked(qsp));
        assert_eq!(enc, encode(&Payload::MidtreadFull(qs)));
        // QSGD, global and sectioned (same seed → same stochastic draw).
        let mut r1 = Xoshiro256pp::seed_from_u64(31);
        let mut r2 = Xoshiro256pp::seed_from_u64(31);
        let g = qsgd_quant::quantize(&v, 4, &mut r1);
        let gp = qsgd_quant::quantize_packed(&v, 4, &mut r2);
        let p = Payload::QsgdPacked(gp);
        let enc = encode(&p);
        assert_eq!(enc, encode(&Payload::Qsgd(g.clone())));
        assert_eq!(enc.len() as u64 * 8, wire_bits(&p));
        assert_eq!(decode(&enc).unwrap(), Payload::Qsgd(g));
        let mut r1 = Xoshiro256pp::seed_from_u64(32);
        let mut r2 = Xoshiro256pp::seed_from_u64(32);
        let gs = qsgd_quant::quantize_sections(&v, 4, &sections, &mut r1);
        let gsp = qsgd_quant::quantize_sections_packed_buf(&v, 4, &sections, &mut r2, Vec::new());
        let enc = encode(&Payload::QsgdPacked(gsp));
        assert_eq!(enc, encode(&Payload::Qsgd(gs)));
    }

    #[test]
    fn quantized_is_smaller_than_raw() {
        let v = sample_vec(10_000, 5);
        let raw = encode(&Payload::RawFull(v.clone()));
        let q4 = encode(&Payload::MidtreadFull(quantize(&v, 4)));
        // 4-bit packing ⇒ ~8x smaller than f32.
        assert!(q4.len() * 7 < raw.len(), "{} vs {}", q4.len(), raw.len());
    }

    #[test]
    fn empty_payloads() {
        for p in [
            Payload::RawFull(vec![]),
            Payload::MidtreadDelta(quantize(&[], 4)),
        ] {
            let enc = encode(&p);
            assert_eq!(decode(&enc).unwrap(), p);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99; 16]).is_err()); // unknown tag
        let v = sample_vec(32, 6);
        let mut enc = encode(&Payload::RawFull(v));
        enc.truncate(20); // truncated body
        assert!(decode(&enc).is_err());
        assert!(view(&enc).is_err());
        // Bad bits for midtread.
        let mut enc2 = encode(&Payload::MidtreadFull(quantize(&[1.0, 2.0], 4)));
        enc2[1] = 0;
        assert!(decode(&enc2).is_err());
        assert!(view(&enc2).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let p = Payload::RawFull(sample_vec(16, 7));
        let mut buf = Vec::new();
        encode_into(&p, &mut buf);
        let first = buf.clone();
        let cap = buf.capacity();
        encode_into(&p, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn level_accessor() {
        let v = sample_vec(8, 7);
        assert_eq!(Payload::MidtreadFull(quantize(&v, 6)).level(), Some(6));
        assert_eq!(Payload::RawFull(v).level(), None);
    }
}
