//! Simulated network scenarios: per-device link models, round
//! deadlines, and straggler semantics.
//!
//! The plain [`super::FaultSpec`] models the network as one uniform
//! drop probability — every device looks the same, so selection
//! strategies are never stressed by the bandwidth-heterogeneous,
//! straggler-prone conditions the FL quantization literature evaluates
//! under. This module adds that axis:
//!
//! * **Per-device links** — every device gets a [`Link`] (uplink /
//!   downlink bandwidth + latency) drawn deterministically from a
//!   [`LinkPreset`] population (`lan`, `wan`, `cellular`, `edge-mix`,
//!   or the `ideal` zero-cost default).
//! * **Round deadlines** — an upload whose simulated transfer time
//!   exceeds [`NetworkSpec::deadline_s`] is a *straggler*: dropped or
//!   admitted late per [`StragglerPolicy`].
//! * **Availability traces** — an optional periodic up/down schedule,
//!   expressed with the same [`AvailabilitySchedule`] type the
//!   selection layer uses, so the one schedule can drive *proactive*
//!   cohort choice (`--select availability:...`) and *reactive*
//!   transport loss (a down device's upload never arrives).
//! * **Simulated wall-clock** — each round's duration (broadcast +
//!   deadline-capped upload window) accumulates into the
//!   `sim_time` column of `RoundRecord`, making time-to-accuracy a
//!   first-class metric next to communication bits
//!   (`RunTrace::time_to_loss`).
//!
//! Determinism contract: link draws are keyed by `(seed, device)`
//! position in one stream at build time; per-round randomness (transfer
//! jitter) is drawn from a stream keyed by `(seed, round)`, exactly
//! like the round-keyed selection and fault streams — so a
//! checkpoint-resumed run replays the identical network weather, and
//! traces are bit-reproducible across thread counts (the whole
//! transport phase is serial). See DESIGN.md §Network.

use crate::selection::AvailabilitySchedule;
use crate::util::rng::Xoshiro256pp;

/// What happens to an upload whose simulated transfer would finish
/// after the round deadline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// The server closes the round at the deadline: late uploads are
    /// counted as stragglers and lost (bits were still spent).
    #[default]
    Drop,
    /// The server waits: late uploads still fold into the round (and
    /// are counted as stragglers), extending the round's simulated
    /// duration past the deadline.
    AdmitLate,
}

impl StragglerPolicy {
    /// Parse a policy keyword: `drop` or `late` (aka `admit-late`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "drop" => Some(Self::Drop),
            "late" | "admit-late" | "admitlate" => Some(Self::AdmitLate),
            _ => None,
        }
    }

    /// The keyword [`StragglerPolicy::parse`] accepts for this policy.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Drop => "drop",
            Self::AdmitLate => "late",
        }
    }
}

/// One device's simulated network link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    /// Uplink bandwidth in bits/second.
    pub up_bps: f64,
    /// Downlink (broadcast) bandwidth in bits/second.
    pub down_bps: f64,
    /// One-way propagation latency in seconds (applied to both
    /// directions).
    pub latency_s: f64,
}

impl Link {
    /// The zero-cost link: infinite bandwidth, zero latency. Every
    /// transfer completes instantly, so `sim_time` stays 0 — the
    /// pre-scenario behaviour.
    pub const IDEAL: Link = Link {
        up_bps: f64::INFINITY,
        down_bps: f64::INFINITY,
        latency_s: 0.0,
    };

    /// Seconds to upload `bits` over this link.
    pub fn uplink_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.up_bps
    }

    /// Seconds to receive a `bits`-sized broadcast over this link.
    pub fn downlink_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.down_bps
    }
}

/// Named link-population presets: each draws a device's [`Link`] from a
/// distribution characteristic of that deployment class.
///
/// | preset | uplink | latency | downlink |
/// |---|---|---|---|
/// | `ideal` | ∞ | 0 | ∞ |
/// | `lan` | 50–200 Mbps uniform | 1–5 ms | symmetric |
/// | `wan` | 10–50 Mbps uniform | 20–80 ms | 2× uplink |
/// | `cellular` | 1–20 Mbps log-uniform | 50–300 ms | 4× uplink |
/// | `edge-mix` | 20% lan / 30% wan / 50% cellular | per class | per class |
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LinkPreset {
    /// Infinite bandwidth, zero latency (the pre-scenario behaviour).
    #[default]
    Ideal,
    /// Cross-silo datacenter links: fast, symmetric, low latency.
    Lan,
    /// Wide-area links: moderate bandwidth, tens of ms latency.
    Wan,
    /// Mobile uplinks: slow, asymmetric, high latency — the classic
    /// cross-device FL straggler regime.
    Cellular,
    /// Mixed edge population (20% lan, 30% wan, 50% cellular) — the
    /// heterogeneous fleet most selection papers evaluate on.
    EdgeMix,
}

impl LinkPreset {
    /// Parse a preset name (`ideal`/`uniform`, `lan`, `wan`,
    /// `cellular`, `edge-mix`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ideal" | "uniform" | "none" => Some(Self::Ideal),
            "lan" => Some(Self::Lan),
            "wan" => Some(Self::Wan),
            "cellular" | "cell" | "mobile" => Some(Self::Cellular),
            "edge-mix" | "edgemix" | "edge" | "mix" => Some(Self::EdgeMix),
            _ => None,
        }
    }

    /// The canonical name [`LinkPreset::parse`] accepts.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Ideal => "ideal",
            Self::Lan => "lan",
            Self::Wan => "wan",
            Self::Cellular => "cellular",
            Self::EdgeMix => "edge-mix",
        }
    }

    /// Draw one device's link from this preset's population.
    fn sample(&self, rng: &mut Xoshiro256pp) -> Link {
        const MBPS: f64 = 1e6;
        match self {
            Self::Ideal => Link::IDEAL,
            Self::Lan => {
                let up = rng.uniform(50.0, 200.0) * MBPS;
                Link {
                    up_bps: up,
                    down_bps: up,
                    latency_s: rng.uniform(0.001, 0.005),
                }
            }
            Self::Wan => {
                let up = rng.uniform(10.0, 50.0) * MBPS;
                Link {
                    up_bps: up,
                    down_bps: 2.0 * up,
                    latency_s: rng.uniform(0.020, 0.080),
                }
            }
            Self::Cellular => {
                // Log-uniform: bandwidth spans an order of magnitude,
                // so the slowest devices straggle hard.
                let up = rng.uniform(1.0f64.ln(), 20.0f64.ln()).exp() * MBPS;
                Link {
                    up_bps: up,
                    down_bps: 4.0 * up,
                    latency_s: rng.uniform(0.050, 0.300),
                }
            }
            Self::EdgeMix => {
                let class = rng.next_f64();
                let pick = if class < 0.2 {
                    Self::Lan
                } else if class < 0.5 {
                    Self::Wan
                } else {
                    Self::Cellular
                };
                pick.sample(rng)
            }
        }
    }
}

/// Config-parseable description of a network scenario — the
/// `--network` CLI flag and the `network = "..."` TOML key. Build the
/// runtime form with [`NetworkSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    /// Link-population preset devices draw from.
    pub preset: LinkPreset,
    /// Round deadline in simulated seconds; `f64::INFINITY` (the
    /// default) disables straggler semantics entirely.
    pub deadline_s: f64,
    /// What happens to uploads that miss the deadline.
    pub policy: StragglerPolicy,
    /// Fractional per-upload transfer-time jitter in `[0, 1)`: each
    /// upload's transfer time is scaled by a factor uniform in
    /// `[1−j, 1+j]`, drawn from a round-keyed stream. 0 = no jitter.
    pub jitter: f64,
    /// Optional periodic availability trace `(period, duty)` shared
    /// with the selection layer's [`AvailabilitySchedule`]: a device
    /// that is down in a round is unreachable — its upload is lost.
    pub availability: Option<(usize, usize)>,
}

impl Default for NetworkSpec {
    fn default() -> Self {
        Self {
            preset: LinkPreset::Ideal,
            deadline_s: f64::INFINITY,
            policy: StragglerPolicy::Drop,
            jitter: 0.0,
            availability: None,
        }
    }
}

impl NetworkSpec {
    /// Accepted spec syntax, for error messages and help text.
    pub const SYNTAX: &'static str = "ideal | lan | wan | cellular | edge-mix \
         [:deadline=SECS,policy=drop|late,jitter=J,avail=PERIOD/DUTY]";

    /// The ideal (zero-cost, no-deadline) scenario — the default.
    pub fn ideal() -> Self {
        Self::default()
    }

    /// Parse a spec string: a preset name optionally followed by
    /// `:key=value,...` modifiers, e.g. `cellular`,
    /// `wan:deadline=0.5`, `edge-mix:deadline=2,policy=late,jitter=0.1`,
    /// `cellular:avail=8/5`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        let (head, tail) = match s.split_once(':') {
            Some((h, t)) => (h, Some(t)),
            None => (s, None),
        };
        let mut spec = NetworkSpec {
            preset: LinkPreset::parse(head)?,
            ..NetworkSpec::default()
        };
        if let Some(tail) = tail {
            for kv in tail.split(',') {
                let (k, v) = kv.split_once('=')?;
                let v = v.trim();
                match k.trim().to_ascii_lowercase().as_str() {
                    "deadline" => {
                        let d = v.parse::<f64>().ok()?;
                        if d.is_nan() || d <= 0.0 {
                            return None;
                        }
                        spec.deadline_s = d;
                    }
                    "policy" => spec.policy = StragglerPolicy::parse(v)?,
                    "jitter" => {
                        let j = v.parse::<f64>().ok()?;
                        if !(0.0..1.0).contains(&j) {
                            return None;
                        }
                        spec.jitter = j;
                    }
                    "avail" => {
                        let (p, d) = v.split_once('/')?;
                        let p = p.trim().parse::<usize>().ok().filter(|&x| x >= 1)?;
                        let d = d
                            .trim()
                            .parse::<usize>()
                            .ok()
                            .filter(|&x| x >= 1 && x <= p)?;
                        spec.availability = Some((p, d));
                    }
                    _ => return None,
                }
            }
        }
        Some(spec)
    }

    /// Instantiate the scenario for `num_devices` devices, drawing
    /// per-device links deterministically from `seed`.
    pub fn build(&self, num_devices: usize, seed: u64) -> NetworkScenario {
        let mut rng = Xoshiro256pp::stream(seed, 0x11E7_C0DE);
        // The ideal preset draws nothing (`sample` consumes no RNG) and
        // every link is `Link::IDEAL` — which is also what `link()`
        // returns past the end of the vector. Storing no links is
        // therefore trace-neutral and keeps the default scenario O(1)
        // for million-device populations.
        let links = if self.preset == LinkPreset::Ideal {
            Vec::new()
        } else {
            (0..num_devices).map(|_| self.preset.sample(&mut rng)).collect()
        };
        let availability = self
            .availability
            .map(|(period, duty)| AvailabilitySchedule::periodic(period, duty, num_devices, seed));
        NetworkScenario {
            links,
            deadline_s: self.deadline_s,
            policy: self.policy,
            jitter: self.jitter,
            availability,
            seed,
        }
    }

    /// True when this spec is the zero-cost default (no simulation
    /// effects beyond byte counting).
    pub fn is_ideal(&self) -> bool {
        self.preset == LinkPreset::Ideal
            && self.deadline_s.is_infinite()
            && self.availability.is_none()
    }
}

impl std::fmt::Display for NetworkSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.preset.name())?;
        let mut mods: Vec<String> = Vec::new();
        if self.deadline_s.is_finite() {
            mods.push(format!("deadline={}", self.deadline_s));
        }
        if self.policy != StragglerPolicy::Drop {
            mods.push(format!("policy={}", self.policy.name()));
        }
        if self.jitter > 0.0 {
            mods.push(format!("jitter={}", self.jitter));
        }
        if let Some((p, d)) = self.availability {
            mods.push(format!("avail={p}/{d}"));
        }
        if !mods.is_empty() {
            write!(f, ":{}", mods.join(","))?;
        }
        Ok(())
    }
}

/// A built network scenario: per-device links plus the round semantics
/// ([`NetworkSpec::build`]). Consumed by [`super::Channel`].
#[derive(Clone, Debug)]
pub struct NetworkScenario {
    links: Vec<Link>,
    deadline_s: f64,
    policy: StragglerPolicy,
    jitter: f64,
    availability: Option<AvailabilitySchedule>,
    seed: u64,
}

impl NetworkScenario {
    /// The ideal scenario for any device count: every link is
    /// [`Link::IDEAL`], no deadline, no availability trace.
    pub fn ideal() -> Self {
        NetworkSpec::default().build(0, 0)
    }

    /// The link of `device` (out-of-range devices — e.g. in tests
    /// driving a bare channel — get the ideal link).
    pub fn link(&self, device: usize) -> Link {
        self.links.get(device).copied().unwrap_or(Link::IDEAL)
    }

    /// All per-device links, indexed by device id.
    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Round deadline in simulated seconds (∞ = none).
    pub fn deadline(&self) -> f64 {
        self.deadline_s
    }

    /// Straggler handling at the deadline.
    pub fn policy(&self) -> StragglerPolicy {
        self.policy
    }

    /// The availability trace, if any — the same
    /// [`AvailabilitySchedule`] type the selection layer consumes, so
    /// an availability-aware strategy can be built over the identical
    /// schedule the transport enforces.
    pub fn availability(&self) -> Option<&AvailabilitySchedule> {
        self.availability.as_ref()
    }

    /// Is `device` reachable in `round`? (Always true without an
    /// availability trace.)
    pub fn is_up(&self, device: usize, round: usize) -> bool {
        match &self.availability {
            Some(a) => a.is_up(device, round),
            None => true,
        }
    }

    /// The round-keyed jitter stream: like selection and fault streams,
    /// keyed by `(seed, round)` rather than free-running, so resumed
    /// runs replay identical network weather.
    pub fn round_jitter_stream(&self, round: usize) -> Xoshiro256pp {
        Xoshiro256pp::stream(
            self.seed,
            0x7E17_7E12 ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
    }

    /// Simulated seconds for `device` to upload `bits`, with this
    /// scenario's jitter applied from `jitter_rng` (one draw per call
    /// when jitter is enabled; none otherwise).
    pub fn uplink_time(&self, device: usize, bits: u64, jitter_rng: &mut Xoshiro256pp) -> f64 {
        let base = self.link(device).uplink_time(bits);
        if self.jitter > 0.0 {
            base * (1.0 + self.jitter * (2.0 * jitter_rng.next_f64() - 1.0))
        } else {
            base
        }
    }

    /// Simulated seconds to broadcast `bits` to every listed
    /// participant (the slowest participant's downlink bounds it; 0
    /// with no participants).
    pub fn broadcast_time(&self, participants: &[usize], bits: u64) -> f64 {
        participants
            .iter()
            .map(|&d| self.link(d).downlink_time(bits))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrip() {
        for (text, want) in [
            ("ideal", NetworkSpec::default()),
            (
                "cellular",
                NetworkSpec {
                    preset: LinkPreset::Cellular,
                    ..NetworkSpec::default()
                },
            ),
            (
                "wan:deadline=0.5",
                NetworkSpec {
                    preset: LinkPreset::Wan,
                    deadline_s: 0.5,
                    ..NetworkSpec::default()
                },
            ),
            (
                "edge-mix:deadline=2,policy=late,jitter=0.1",
                NetworkSpec {
                    preset: LinkPreset::EdgeMix,
                    deadline_s: 2.0,
                    policy: StragglerPolicy::AdmitLate,
                    jitter: 0.1,
                    ..NetworkSpec::default()
                },
            ),
            (
                "lan:avail=8/5",
                NetworkSpec {
                    preset: LinkPreset::Lan,
                    availability: Some((8, 5)),
                    ..NetworkSpec::default()
                },
            ),
        ] {
            assert_eq!(NetworkSpec::parse(text), Some(want.clone()), "{text}");
            // Display output parses back to the same spec.
            assert_eq!(NetworkSpec::parse(&want.to_string()), Some(want), "{text}");
        }
        for bad in [
            "martian",
            "lan:deadline=0",
            "lan:deadline=-1",
            "lan:jitter=1.5",
            "lan:avail=4/9",
            "lan:avail=0/0",
            "lan:frobnicate=1",
            "lan:deadline",
        ] {
            assert_eq!(NetworkSpec::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn ideal_links_cost_nothing() {
        let sc = NetworkScenario::ideal();
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        assert_eq!(sc.uplink_time(0, 1 << 30, &mut rng), 0.0);
        assert_eq!(sc.broadcast_time(&[0, 1, 2], 1 << 30), 0.0);
        assert!(sc.is_up(7, 123));
    }

    #[test]
    fn preset_populations_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..200 {
            let l = LinkPreset::Lan.sample(&mut rng);
            assert!((50e6..=200e6).contains(&l.up_bps));
            assert_eq!(l.down_bps, l.up_bps);
            let w = LinkPreset::Wan.sample(&mut rng);
            assert!((10e6..=50e6).contains(&w.up_bps));
            let c = LinkPreset::Cellular.sample(&mut rng);
            assert!((1e6 * 0.999..=20e6 * 1.001).contains(&c.up_bps));
            assert!(c.down_bps > c.up_bps);
            assert!((0.050..=0.300).contains(&c.latency_s));
        }
    }

    #[test]
    fn build_is_deterministic_and_per_device() {
        let spec = NetworkSpec::parse("cellular:deadline=1").unwrap();
        let a = spec.build(16, 42);
        let b = spec.build(16, 42);
        assert_eq!(a.links(), b.links());
        assert_eq!(a.deadline(), 1.0);
        // Heterogeneous: not all devices share a link.
        let first = a.link(0);
        assert!(a.links().iter().any(|l| l.up_bps != first.up_bps));
        // A different seed draws a different fleet.
        let c = spec.build(16, 43);
        assert_ne!(a.links(), c.links());
    }

    #[test]
    fn uplink_time_scales_with_bits_and_bandwidth() {
        let spec = NetworkSpec::parse("wan").unwrap();
        let sc = spec.build(4, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let t_small = sc.uplink_time(0, 1_000_000, &mut rng);
        let t_big = sc.uplink_time(0, 10_000_000, &mut rng);
        assert!(t_big > t_small);
        let l = sc.link(0);
        let expect = l.latency_s + 1_000_000.0 / l.up_bps;
        assert!((t_small - expect).abs() < 1e-12);
    }

    #[test]
    fn jitter_draws_are_round_keyed() {
        let spec = NetworkSpec::parse("cellular:jitter=0.2").unwrap();
        let sc = spec.build(4, 9);
        let mut r5a = sc.round_jitter_stream(5);
        let mut r5b = sc.round_jitter_stream(5);
        let mut r6 = sc.round_jitter_stream(6);
        let a = sc.uplink_time(1, 8_000_000, &mut r5a);
        let b = sc.uplink_time(1, 8_000_000, &mut r5b);
        let c = sc.uplink_time(1, 8_000_000, &mut r6);
        assert_eq!(a.to_bits(), b.to_bits(), "same round, same weather");
        assert_ne!(a.to_bits(), c.to_bits(), "different round, fresh weather");
        // Jitter stays within the ±20% envelope.
        let base = sc.link(1).uplink_time(8_000_000);
        assert!(a >= base * 0.8 - 1e-12 && a <= base * 1.2 + 1e-12);
    }

    #[test]
    fn availability_trace_gates_reachability() {
        let spec = NetworkSpec::parse("ideal:avail=4/2").unwrap();
        let sc = spec.build(8, 3);
        let sched = sc.availability().expect("schedule built");
        for dev in 0..8 {
            let ups = (0..8).filter(|&r| sc.is_up(dev, r)).count();
            assert_eq!(ups, 4, "duty 2/4 over 8 rounds");
            for r in 0..8 {
                assert_eq!(sc.is_up(dev, r), sched.is_up(dev, r));
            }
        }
    }
}
