//! Simulated network transport with honest byte accounting.
//!
//! Every device upload is actually serialized ([`wire`]), its length
//! counted — the bit totals in Tables II/III are sums of real
//! `bytes.len() × 8`, not analytic estimates. Since the zero-copy
//! aggregation redesign (§Perf in DESIGN.md) the server side no longer
//! eagerly decodes: the channel validates each upload's wire framing
//! and hands the *bytes* through; the fold reads them via
//! [`wire::PayloadView`] without materializing ψ vectors. The channel
//! also supports failure injection (random device dropout) used by the
//! robustness tests.

pub mod wire;

use crate::util::rng::Xoshiro256pp;
use wire::UploadRef;

/// Per-round transport statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStats {
    /// Uplink payload bits actually transferred this round.
    pub uplink_bits: u64,
    /// Number of device uploads delivered.
    pub messages: u64,
    /// Messages lost to injected failures.
    pub dropped: u64,
}

/// Failure-injection model.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Probability an upload is lost in transit.
    pub drop_prob: f64,
    pub seed: u64,
}

impl FaultSpec {
    pub fn none() -> Self {
        Self {
            drop_prob: 0.0,
            seed: 0,
        }
    }
}

/// The simulated uplink channel: counts real wire bytes, optionally
/// drops, and validates framing on behalf of the receiver.
pub struct Channel {
    faults: FaultSpec,
    rng: Xoshiro256pp,
    /// Cumulative uplink bits since construction.
    pub total_bits: u64,
    /// Cumulative delivered messages.
    pub total_messages: u64,
    /// Cumulative drops.
    pub total_dropped: u64,
}

impl Channel {
    pub fn new(faults: FaultSpec) -> Self {
        let rng = Xoshiro256pp::stream(faults.seed, 0xC4A7);
        Self {
            faults,
            rng,
            total_bits: 0,
            total_messages: 0,
            total_dropped: 0,
        }
    }

    pub fn reliable() -> Self {
        Self::new(FaultSpec::none())
    }

    /// Transmit one round of encoded uploads: returns the delivered
    /// subset (same borrowed bytes — the server folds zero-copy) and
    /// the round's stats. Framing is validated here so every delivered
    /// upload can be viewed infallibly downstream.
    ///
    /// Dropped uploads still consumed uplink bandwidth (the bytes were
    /// sent; the loss is on the path) — consistent with how the paper
    /// counts transmitted bits.
    pub fn transmit<'a>(&mut self, uploads: Vec<UploadRef<'a>>) -> (Vec<UploadRef<'a>>, LinkStats) {
        let mut stats = LinkStats::default();
        let mut delivered = Vec::with_capacity(uploads.len());
        for up in uploads {
            wire::view(up.bytes).expect("self-encoded payload must be viewable");
            stats.uplink_bits += up.bytes.len() as u64 * 8;
            if self.faults.drop_prob > 0.0 && self.rng.bernoulli(self.faults.drop_prob) {
                stats.dropped += 1;
                continue;
            }
            stats.messages += 1;
            delivered.push(up);
        }
        self.total_bits += stats.uplink_bits;
        self.total_messages += stats.messages;
        self.total_dropped += stats.dropped;
        (delivered, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;
    use wire::{encode, upload_refs, EncodedUpload, Payload};

    #[test]
    fn counts_real_bytes() {
        let mut ch = Channel::reliable();
        let v: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let p = Payload::MidtreadFull(quantize(&v, 4));
        let expected_bits = encode(&p).len() as u64 * 8;
        let staged = vec![EncodedUpload::encode(0, &p)];
        let (delivered, stats) = ch.transmit(upload_refs(&staged));
        assert_eq!(stats.uplink_bits, expected_bits);
        assert_eq!(delivered.len(), 1);
        assert_eq!(delivered[0].view().to_owned(), p);
        assert_eq!(ch.total_bits, expected_bits);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let mut ch = Channel::reliable();
        let (delivered, stats) = ch.transmit(Vec::new());
        assert!(delivered.is_empty());
        assert_eq!(stats, LinkStats::default());
    }

    #[test]
    fn drops_are_counted_and_billed() {
        let mut ch = Channel::new(FaultSpec {
            drop_prob: 1.0,
            seed: 1,
        });
        let p = Payload::RawFull(vec![1.0; 10]);
        let bits = encode(&p).len() as u64 * 8;
        let staged = vec![EncodedUpload::encode(0, &p)];
        let (delivered, stats) = ch.transmit(upload_refs(&staged));
        assert!(delivered.is_empty());
        assert_eq!(stats.dropped, 1);
        // Bits were still spent.
        assert_eq!(stats.uplink_bits, bits);
    }

    #[test]
    fn partial_drop_rate() {
        let mut ch = Channel::new(FaultSpec {
            drop_prob: 0.5,
            seed: 7,
        });
        let mut delivered_total = 0;
        for _ in 0..100 {
            let staged: Vec<EncodedUpload> = (0..10)
                .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 4])))
                .collect();
            let (del, _) = ch.transmit(upload_refs(&staged));
            delivered_total += del.len();
        }
        // ~500 of 1000 delivered.
        assert!((350..650).contains(&delivered_total), "{delivered_total}");
        assert_eq!(ch.total_dropped + delivered_total as u64, 1000);
    }
}
