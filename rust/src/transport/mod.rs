//! Simulated network transport with honest byte accounting.
//!
//! Every device upload is actually serialized ([`wire`]), its length
//! counted — the bit totals in Tables II/III are sums of real
//! `bytes.len() × 8`, not analytic estimates (including the v2
//! sectioned encoding's per-section scale table, so layout-aware
//! quantization pays for its header honestly — DESIGN.md §Wire v2).
//! Since the zero-copy aggregation redesign (§Perf in DESIGN.md) the
//! server side no longer eagerly decodes: the channel validates each
//! upload's wire framing and hands the *bytes* through; the fold reads
//! them via [`wire::PayloadView`] without materializing ψ vectors.
//!
//! On top of byte counting the channel simulates the network itself
//! ([`scenario`]): per-device link models, round deadlines with
//! straggler semantics, availability traces, downlink (broadcast)
//! accounting, and failure injection (random device dropout). All
//! per-round randomness — fault coin flips and transfer jitter — is
//! drawn from streams keyed by `(seed, round)`, so a checkpoint-resumed
//! run replays exactly the drops and weather the uninterrupted run
//! would have seen (see DESIGN.md §Network).

pub mod scenario;
pub mod wire;

use crate::util::rng::Xoshiro256pp;
use scenario::{NetworkScenario, StragglerPolicy};
use wire::{EncodedUpload, UploadRef};

/// Per-round transport statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStats {
    /// Uplink payload bits actually transferred this round.
    pub uplink_bits: u64,
    /// Downlink bits broadcast this round (model bits × participants).
    pub downlink_bits: u64,
    /// Number of device uploads delivered.
    pub messages: u64,
    /// Messages lost in transit: injected failures, unavailability
    /// windows, and deadline-dropped stragglers.
    pub dropped: u64,
    /// Uploads whose simulated transfer exceeded the round deadline
    /// (dropped or admitted late per [`StragglerPolicy`]).
    pub stragglers: u64,
    /// Simulated duration of this round in seconds: broadcast time plus
    /// the (deadline-capped) upload window.
    pub round_time: f64,
}

/// Failure-injection model: a uniform per-upload drop probability.
/// Per-device heterogeneity lives in [`scenario::NetworkSpec`].
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Probability an upload is lost in transit.
    pub drop_prob: f64,
    /// Seed of the (round-keyed) fault RNG stream.
    pub seed: u64,
}

impl FaultSpec {
    /// No injected failures.
    pub fn none() -> Self {
        Self {
            drop_prob: 0.0,
            seed: 0,
        }
    }
}

/// The round-keyed fault stream: like the selection streams, a fresh
/// generator per `(seed, round)` rather than one free-running stream —
/// the free-running version replayed *different* drops after a
/// checkpoint resume (the same bug round-keying fixed for stochastic
/// selection). Round 0 matches the old stream's start exactly.
fn fault_stream(seed: u64, round: usize) -> Xoshiro256pp {
    Xoshiro256pp::stream(
        seed,
        0xC4A7 ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// The simulated channel: counts real wire bytes both directions,
/// applies the network scenario (links, deadline, availability),
/// optionally drops, and validates framing on behalf of the receiver.
pub struct Channel {
    faults: FaultSpec,
    scenario: NetworkScenario,
    /// Cumulative uplink bits since construction.
    pub total_bits: u64,
    /// Cumulative downlink (broadcast) bits since construction.
    pub total_bits_down: u64,
    /// Cumulative delivered messages.
    pub total_messages: u64,
    /// Cumulative drops (faults + unavailability + dropped stragglers).
    pub total_dropped: u64,
    /// Cumulative stragglers.
    pub total_stragglers: u64,
    /// Cumulative simulated seconds.
    pub sim_time: f64,
}

impl Channel {
    /// Channel with fault injection over the ideal (zero-cost) network.
    pub fn new(faults: FaultSpec) -> Self {
        Self::with_scenario(faults, NetworkScenario::ideal())
    }

    /// Channel with fault injection and a simulated network scenario.
    pub fn with_scenario(faults: FaultSpec, scenario: NetworkScenario) -> Self {
        Self {
            faults,
            scenario,
            total_bits: 0,
            total_bits_down: 0,
            total_messages: 0,
            total_dropped: 0,
            total_stragglers: 0,
            sim_time: 0.0,
        }
    }

    /// Fault-free channel over the ideal network.
    pub fn reliable() -> Self {
        Self::new(FaultSpec::none())
    }

    /// The active network scenario.
    pub fn scenario(&self) -> &NetworkScenario {
        &self.scenario
    }

    /// Transmit one round: broadcast accounting for `participants`
    /// (each receives `model_bits` downlink), then the uploads. Returns
    /// the delivered subset (same borrowed bytes — the server folds
    /// zero-copy) and the round's stats. Framing is validated here so
    /// every delivered upload can be viewed infallibly downstream.
    ///
    /// Dropped uploads still consumed uplink bandwidth (the bytes were
    /// sent; the loss is on the path) — consistent with how the paper
    /// counts transmitted bits. With a finite deadline, a *straggler*
    /// dropped at the deadline makes the server wait out the full
    /// deadline (it stopped listening only when the clock ran out);
    /// every other loss — injected faults and unavailability windows —
    /// is known to the link layer, so the round window closes at the
    /// last actual arrival, not the deadline. (Devices that
    /// intentionally skip — lazy-aggregation rules — likewise announce
    /// it with a zero-cost beacon and never block the window.) In
    /// particular a huge-but-finite deadline no longer stretches
    /// `round_time` when a fault eats an upload that would have
    /// arrived promptly.
    pub fn transmit<'a>(
        &mut self,
        round: usize,
        participants: &[usize],
        model_bits: u64,
        uploads: Vec<UploadRef<'a>>,
    ) -> (Vec<UploadRef<'a>>, LinkStats) {
        let mut stats = LinkStats {
            downlink_bits: model_bits * participants.len() as u64,
            ..LinkStats::default()
        };
        let t_bcast = self.scenario.broadcast_time(participants, model_bits);
        let deadline = self.scenario.deadline();
        let mut fault_rng = fault_stream(self.faults.seed, round);
        let mut jitter_rng = self.scenario.round_jitter_stream(round);
        let mut window = 0.0f64;
        let mut straggled_out = false;
        let mut delivered = Vec::with_capacity(uploads.len());
        for up in uploads {
            wire::view(up.bytes).expect("self-encoded payload must be viewable");
            stats.uplink_bits += up.bytes.len() as u64 * 8;
            // Fault coin first (stream parity with the pre-scenario
            // path: one draw per staged upload when drop_prob > 0).
            let fault_dropped =
                self.faults.drop_prob > 0.0 && fault_rng.bernoulli(self.faults.drop_prob);
            if fault_dropped || !self.scenario.is_up(up.device, round) {
                stats.dropped += 1;
                continue;
            }
            let arrival = self
                .scenario
                .uplink_time(up.device, up.bytes.len() as u64 * 8, &mut jitter_rng);
            if arrival > deadline {
                stats.stragglers += 1;
                if self.scenario.policy() == StragglerPolicy::Drop {
                    stats.dropped += 1;
                    straggled_out = true;
                    continue;
                }
            }
            window = window.max(arrival);
            stats.messages += 1;
            delivered.push(up);
        }
        if straggled_out && deadline.is_finite() {
            // A deadline-dropped straggler means the server listened
            // until the clock ran out.
            window = window.max(deadline);
        }
        stats.round_time = t_bcast + window;
        self.total_bits += stats.uplink_bits;
        self.total_bits_down += stats.downlink_bits;
        self.total_messages += stats.messages;
        self.total_dropped += stats.dropped;
        self.total_stragglers += stats.stragglers;
        self.sim_time += stats.round_time;
        (delivered, stats)
    }

    /// Schedule one cohort dispatch on the buffered-async path
    /// (DESIGN.md §Async): instead of closing a deadline-capped round
    /// window, each surviving upload becomes an [`UploadEvent`] whose
    /// `offset` is its link-derived completion time relative to the
    /// dispatch instant. The event-loop engine owns the simulated
    /// clock, so this call advances *no* time: `stats.round_time`
    /// carries only the dispatch's broadcast-completion offset (the
    /// floor below which no commit fed by this cohort can land) and
    /// the channel's cumulative `sim_time` is untouched.
    ///
    /// Randomness parity: the fault coin and jitter draws are keyed by
    /// `dispatch` and consumed in exactly [`Channel::transmit`]'s
    /// order (one coin per staged upload when `drop_prob > 0`, one
    /// jitter draw per non-dropped upload) — with dispatch index =
    /// round index the two paths see identical weather, which is what
    /// makes the degenerate buffered configuration bit-identical to
    /// sync. A straggler past a finite deadline is dropped or admitted
    /// (flagged) per the scenario policy, but never waited for: the
    /// buffered server has no barrier to hold open.
    pub fn transmit_async(
        &mut self,
        dispatch: usize,
        participants: &[usize],
        model_bits: u64,
        uploads: Vec<EncodedUpload>,
    ) -> (Vec<UploadEvent>, LinkStats) {
        let mut stats = LinkStats {
            downlink_bits: model_bits * participants.len() as u64,
            ..LinkStats::default()
        };
        let t_bcast = self.scenario.broadcast_time(participants, model_bits);
        let deadline = self.scenario.deadline();
        let mut fault_rng = fault_stream(self.faults.seed, dispatch);
        let mut jitter_rng = self.scenario.round_jitter_stream(dispatch);
        let mut events = Vec::with_capacity(uploads.len());
        for up in uploads {
            wire::view(&up.bytes).expect("self-encoded payload must be viewable");
            stats.uplink_bits += up.bytes.len() as u64 * 8;
            let fault_dropped =
                self.faults.drop_prob > 0.0 && fault_rng.bernoulli(self.faults.drop_prob);
            if fault_dropped || !self.scenario.is_up(up.device, dispatch) {
                stats.dropped += 1;
                continue;
            }
            let arrival = self
                .scenario
                .uplink_time(up.device, up.bytes.len() as u64 * 8, &mut jitter_rng);
            let straggler = arrival > deadline;
            if straggler {
                stats.stragglers += 1;
                if self.scenario.policy() == StragglerPolicy::Drop {
                    stats.dropped += 1;
                    continue;
                }
            }
            stats.messages += 1;
            events.push(UploadEvent {
                device: up.device,
                offset: t_bcast + arrival,
                straggler,
                bytes: up.bytes,
            });
        }
        stats.round_time = t_bcast;
        self.total_bits += stats.uplink_bits;
        self.total_bits_down += stats.downlink_bits;
        self.total_messages += stats.messages;
        self.total_dropped += stats.dropped;
        self.total_stragglers += stats.stragglers;
        (events, stats)
    }
}

/// One upload's scheduled completion on the buffered-async path,
/// produced by [`Channel::transmit_async`].
#[derive(Clone, Debug)]
pub struct UploadEvent {
    /// The uploading device.
    pub device: usize,
    /// Completion time in seconds relative to the dispatch instant
    /// (broadcast completion + uplink transfer, jitter included).
    pub offset: f64,
    /// Whether the transfer overran the scenario deadline (admitted
    /// late under [`StragglerPolicy::AdmitLate`]; a dropped straggler
    /// never becomes an event).
    pub straggler: bool,
    /// The validated wire bytes, owned: the upload outlives its device
    /// slot, which may be re-selected and re-dispatched while this one
    /// is still in flight.
    pub bytes: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::scenario::NetworkSpec;
    use super::*;
    use crate::quant::midtread::quantize;
    use wire::{encode, upload_refs, EncodedUpload, Payload};

    #[test]
    fn counts_real_bytes() {
        let mut ch = Channel::reliable();
        let v: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let p = Payload::MidtreadFull(quantize(&v, 4));
        let expected_bits = encode(&p).len() as u64 * 8;
        let staged = vec![EncodedUpload::encode(0, &p)];
        let (delivered, stats) = ch.transmit(0, &[0], 0, upload_refs(&staged));
        assert_eq!(stats.uplink_bits, expected_bits);
        assert_eq!(delivered.len(), 1);
        assert_eq!(delivered[0].view().to_owned(), p);
        assert_eq!(ch.total_bits, expected_bits);
        // Ideal network: no simulated time elapses.
        assert_eq!(stats.round_time, 0.0);
        assert_eq!(stats.stragglers, 0);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let mut ch = Channel::reliable();
        let (delivered, stats) = ch.transmit(0, &[], 0, Vec::new());
        assert!(delivered.is_empty());
        assert_eq!(stats, LinkStats::default());
    }

    #[test]
    fn downlink_billed_per_participant() {
        let mut ch = Channel::reliable();
        let (_, stats) = ch.transmit(0, &[0, 1, 2], 1000, Vec::new());
        assert_eq!(stats.downlink_bits, 3000);
        assert_eq!(ch.total_bits_down, 3000);
    }

    #[test]
    fn drops_are_counted_and_billed() {
        let mut ch = Channel::new(FaultSpec {
            drop_prob: 1.0,
            seed: 1,
        });
        let p = Payload::RawFull(vec![1.0; 10]);
        let bits = encode(&p).len() as u64 * 8;
        let staged = vec![EncodedUpload::encode(0, &p)];
        let (delivered, stats) = ch.transmit(0, &[0], 0, upload_refs(&staged));
        assert!(delivered.is_empty());
        assert_eq!(stats.dropped, 1);
        // Bits were still spent.
        assert_eq!(stats.uplink_bits, bits);
    }

    #[test]
    fn partial_drop_rate() {
        let mut ch = Channel::new(FaultSpec {
            drop_prob: 0.5,
            seed: 7,
        });
        let mut delivered_total = 0;
        for round in 0..100 {
            let staged: Vec<EncodedUpload> = (0..10)
                .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 4])))
                .collect();
            let (del, _) = ch.transmit(round, &[], 0, upload_refs(&staged));
            delivered_total += del.len();
        }
        // ~500 of 1000 delivered.
        assert!((350..650).contains(&delivered_total), "{delivered_total}");
        assert_eq!(ch.total_dropped + delivered_total as u64, 1000);
    }

    #[test]
    fn fault_draws_are_round_keyed() {
        // Two channels, one replaying only round 7: identical verdicts.
        let spec = FaultSpec {
            drop_prob: 0.5,
            seed: 11,
        };
        let staged: Vec<EncodedUpload> = (0..32)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 4])))
            .collect();
        let mut a = Channel::new(spec.clone());
        let mut survivors_a = Vec::new();
        for round in 0..8 {
            let (del, _) = a.transmit(round, &[], 0, upload_refs(&staged));
            if round == 7 {
                survivors_a = del.iter().map(|u| u.device).collect();
            }
        }
        // Fresh channel going straight to round 7 (as a resumed run
        // does) sees the same drops.
        let mut b = Channel::new(spec);
        let (del, _) = b.transmit(7, &[], 0, upload_refs(&staged));
        let survivors_b: Vec<usize> = del.iter().map(|u| u.device).collect();
        assert_eq!(survivors_a, survivors_b);
    }

    #[test]
    fn deadline_drops_stragglers_and_waits() {
        // 1 Mbps uplink at best (cellular) and a deadline far below any
        // feasible transfer of ~4 MB: everything straggles.
        let spec = NetworkSpec::parse("cellular:deadline=0.001").unwrap();
        let mut ch = Channel::with_scenario(FaultSpec::none(), spec.build(4, 3));
        let staged: Vec<EncodedUpload> = (0..4)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 1_000_000])))
            .collect();
        // No broadcast this round (empty participant list), so the
        // round window is exactly the waited-out deadline.
        let (delivered, stats) = ch.transmit(0, &[], 0, upload_refs(&staged));
        assert!(delivered.is_empty());
        assert_eq!(stats.stragglers, 4);
        assert_eq!(stats.dropped, 4);
        // The server waited out the deadline.
        assert!((stats.round_time - 0.001).abs() < 1e-9);
    }

    #[test]
    fn admit_late_keeps_stragglers() {
        let spec = NetworkSpec::parse("cellular:deadline=0.001,policy=late").unwrap();
        let mut ch = Channel::with_scenario(FaultSpec::none(), spec.build(4, 3));
        let staged: Vec<EncodedUpload> = (0..4)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 1_000_000])))
            .collect();
        let (delivered, stats) = ch.transmit(0, &[0, 1, 2, 3], 0, upload_refs(&staged));
        assert_eq!(delivered.len(), 4);
        assert_eq!(stats.stragglers, 4);
        assert_eq!(stats.dropped, 0);
        // The round ran past the deadline to the slowest arrival.
        assert!(stats.round_time > 0.001);
    }

    #[test]
    fn availability_trace_loses_down_devices() {
        let spec = NetworkSpec::parse("ideal:avail=2/1").unwrap();
        let sc = spec.build(8, 5);
        let sched = sc.availability().unwrap().clone();
        let mut ch = Channel::with_scenario(FaultSpec::none(), sc);
        let staged: Vec<EncodedUpload> = (0..8)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 4])))
            .collect();
        for round in 0..4 {
            let (del, stats) = ch.transmit(round, &[], 0, upload_refs(&staged));
            let up_now: Vec<usize> = (0..8).filter(|&d| sched.is_up(d, round)).collect();
            let got: Vec<usize> = del.iter().map(|u| u.device).collect();
            assert_eq!(got, up_now, "round {round}");
            assert_eq!(stats.dropped as usize, 8 - up_now.len());
        }
    }

    #[test]
    fn fault_drop_does_not_wait_out_huge_deadline() {
        // Satellite fix: a lost upload is known to the link layer, so
        // with policy=drop and a huge finite deadline the round closes
        // at the last actual arrival — bitwise what the same run sees
        // under an infinite deadline — instead of stretching to the
        // deadline.
        let faults = FaultSpec {
            drop_prob: 0.5,
            seed: 13,
        };
        let staged: Vec<EncodedUpload> = (0..8)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 10_000])))
            .collect();
        let spec_huge = NetworkSpec::parse("cellular:deadline=1000000").unwrap();
        let mut ch_huge = Channel::with_scenario(faults.clone(), spec_huge.build(8, 3));
        let spec_inf = NetworkSpec::parse("cellular").unwrap();
        let mut ch_inf = Channel::with_scenario(faults, spec_inf.build(8, 3));
        for round in 0..6 {
            let (del_h, st_h) = ch_huge.transmit(round, &[0], 1000, upload_refs(&staged));
            let (del_i, st_i) = ch_inf.transmit(round, &[0], 1000, upload_refs(&staged));
            assert!(st_h.dropped > 0 || st_h.messages == 8, "round {round}");
            assert_eq!(del_h.len(), del_i.len(), "round {round}");
            assert_eq!(
                st_h.round_time.to_bits(),
                st_i.round_time.to_bits(),
                "round {round}: huge-deadline window {} != max(arrival) {}",
                st_h.round_time,
                st_i.round_time
            );
        }
        assert_eq!(ch_huge.sim_time.to_bits(), ch_inf.sim_time.to_bits());
    }

    #[test]
    fn async_events_mirror_sync_arrivals() {
        // transmit_async with dispatch = round must replay transmit's
        // exact weather: same survivors, same per-upload timing (the
        // sync window is the max event offset), same billing — only
        // the clock ownership moves to the event loop.
        let faults = FaultSpec {
            drop_prob: 0.3,
            seed: 21,
        };
        let spec = NetworkSpec::parse("edge-mix:jitter=0.2").unwrap();
        let mut sync_ch = Channel::with_scenario(faults.clone(), spec.build(8, 5));
        let mut async_ch = Channel::with_scenario(faults, spec.build(8, 5));
        let staged: Vec<EncodedUpload> = (0..8)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.5; 5_000])))
            .collect();
        for round in 0..5 {
            let (delivered, st) = sync_ch.transmit(round, &[0, 1], 1000, upload_refs(&staged));
            let (events, ast) = async_ch.transmit_async(round, &[0, 1], 1000, staged.clone());
            let got: Vec<usize> = events.iter().map(|e| e.device).collect();
            let want: Vec<usize> = delivered.iter().map(|u| u.device).collect();
            assert_eq!(got, want, "round {round}");
            assert_eq!(ast.uplink_bits, st.uplink_bits);
            assert_eq!(ast.downlink_bits, st.downlink_bits);
            assert_eq!((ast.messages, ast.dropped, ast.stragglers), (
                st.messages,
                st.dropped,
                st.stragglers
            ));
            // Sync's round window is exactly the slowest event.
            let max_offset = events.iter().fold(0.0f64, |w, e| w.max(e.offset));
            if !events.is_empty() {
                assert_eq!(st.round_time.to_bits(), max_offset.to_bits(), "round {round}");
            }
            // The async path advances no simulated time itself.
            assert_eq!(async_ch.sim_time, 0.0);
        }
    }

    #[test]
    fn infinite_deadline_never_straggles() {
        let spec = NetworkSpec::parse("cellular").unwrap();
        let mut ch = Channel::with_scenario(FaultSpec::none(), spec.build(4, 3));
        let staged: Vec<EncodedUpload> = (0..4)
            .map(|d| EncodedUpload::encode(d, &Payload::RawFull(vec![0.0; 100_000])))
            .collect();
        let (delivered, stats) = ch.transmit(0, &[0, 1, 2, 3], 32_000, upload_refs(&staged));
        assert_eq!(delivered.len(), 4);
        assert_eq!(stats.stragglers, 0);
        // Time still elapses (slow links), monotone across rounds.
        assert!(stats.round_time > 0.0);
        let t0 = ch.sim_time;
        ch.transmit(1, &[0], 32_000, Vec::new());
        assert!(ch.sim_time >= t0);
    }
}
