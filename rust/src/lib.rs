//! # AQUILA — communication-efficient federated learning
//!
//! Reproduction of *"AQUILA: Communication Efficient Federated Learning
//! with Adaptive Quantization in Device Selection Strategy"* (Zhao, Mao,
//! Shi, Liu, Lan, Ding, Zhang; 2023) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L3 (this crate)** — the federated coordinator: server/device
//!   state, the AQUILA round protocol (adaptive level selection, eq. 19;
//!   lazy device selection, eq. 8), seven baseline algorithms, honest
//!   byte-accounted transport, datasets, partitioners, metrics, theory
//!   calculators and the table/figure reproduction harness.
//! * **L2** — JAX neural models (`python/compile/model.py`) lowered AOT
//!   to HLO text artifacts executed through PJRT (`runtime`).
//! * **L1** — the fused Pallas quantization kernel
//!   (`python/compile/kernels/aquila_quant.py`), mirrored bit-exactly by
//!   [`quant::midtread`] on the Rust hot path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod algorithms;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hetero;
pub mod metrics;
pub mod problems;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod theory;
pub mod transport;
pub mod util;
