//! # AQUILA — communication-efficient federated learning
//!
//! Reproduction of *"AQUILA: Communication Efficient Federated Learning
//! with Adaptive Quantization in Device Selection Strategy"* (Zhao, Mao,
//! Shi, Liu, Lan, Ding, Zhang; 2023) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L3 (this crate)** — the federated coordinator: an owned,
//!   builder-constructed [`coordinator::Session`] composing a
//!   [`problems::GradientSource`], an [`algorithms::Algorithm`], a
//!   pluggable [`selection::SelectionStrategy`] (the paper's eq. 8
//!   context made an injectable policy), and streaming
//!   [`metrics::observer::RoundObserver`] sinks; seven baseline
//!   algorithms, honest byte-accounted transport, datasets,
//!   partitioners, metrics, theory calculators and the table/figure
//!   reproduction harness; the [`protocol`] module serves a session to
//!   remote device clients over TCP or an in-process loopback.
//! * **L2** — JAX neural models (`python/compile/model.py`) lowered AOT
//!   to HLO text artifacts executed through PJRT (`runtime`).
//! * **L1** — the fused Pallas quantization kernel
//!   (`python/compile/kernels/aquila_quant.py`), mirrored bit-exactly by
//!   [`quant::midtread`] on the Rust hot path.
//!
//! See `DESIGN.md` for the architecture (Session/SelectionStrategy/
//! RoundObserver layering in §2, the network scenario model in
//! §Network) and `EXPERIMENTS.md` for the paper-vs-measured record.

#![warn(missing_docs)]

pub mod algorithms;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hetero;
pub mod metrics;
pub mod problems;
pub mod protocol;
pub mod quant;
pub mod repro;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod selection;
pub mod theory;
pub mod transport;
pub mod util;
