//! The artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`, describing every AOT-lowered HLO module —
//! model entries (grad/eval), their shapes, and the flat parameter
//! layout (used for HeteroFL masks) — plus the L1 quantization kernel
//! artifacts.

use crate::problems::{LayerSpec, ParamLayout};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled model (a `variant` in `python/compile/model.py`).
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Model variant name (e.g. `txf_small`).
    pub name: String,
    /// Flat parameter dimension `d`.
    pub dim: usize,
    /// HLO text file computing `(loss, grad)` from `(θ, x, y)`.
    pub grad_file: PathBuf,
    /// HLO text file computing `(loss,)` from `(θ, x, y)`.
    pub eval_file: PathBuf,
    /// Optional fused device step `(θ, q_prev, x, y) -> (loss, dq,
    /// range, bits, ‖Δq‖², ‖ε‖²)` — model grad + L1 Pallas quantizer in
    /// one module.
    pub step_file: Option<PathBuf>,
    /// Batch size the module was lowered at.
    pub batch: usize,
    /// Sequence length the module was lowered at.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Flat-parameter layout of the model's tensors.
    pub layout: ParamLayout,
}

/// One AOT-compiled L1 kernel entry (the fused AQUILA quantizer at a
/// fixed dimension).
#[derive(Clone, Debug)]
pub struct KernelEntry {
    /// Kernel name (e.g. `aquila_quant_d65536`).
    pub name: String,
    /// Fixed input dimension the kernel was lowered at.
    pub dim: usize,
    /// HLO text file of the kernel.
    pub file: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub root: PathBuf,
    /// AOT-compiled models.
    pub models: Vec<ModelEntry>,
    /// AOT-compiled L1 kernels.
    pub kernels: Vec<KernelEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let mut models = Vec::new();
        for m in j.get("models").as_arr().unwrap_or(&[]) {
            let name = m
                .get("name")
                .as_str()
                .context("model entry missing name")?
                .to_string();
            let dim = m.get("dim").as_usize().context("model missing dim")?;
            let mut entries = Vec::new();
            for l in m.get("layout").as_arr().unwrap_or(&[]) {
                entries.push(LayerSpec {
                    name: l.get("name").as_str().unwrap_or("?").to_string(),
                    shape: l
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect(),
                    offset: l.get("offset").as_usize().unwrap_or(0),
                });
            }
            let layout = ParamLayout { entries };
            if layout.dim() != dim {
                bail!(
                    "model {name}: layout covers {} params but dim = {dim}",
                    layout.dim()
                );
            }
            models.push(ModelEntry {
                grad_file: dir.join(
                    m.get("grad")
                        .as_str()
                        .context("model missing grad file")?,
                ),
                eval_file: dir.join(
                    m.get("eval")
                        .as_str()
                        .context("model missing eval file")?,
                ),
                step_file: m.get("step").as_str().map(|s| dir.join(s)),
                batch: m.get("batch").as_usize().unwrap_or(1),
                seq: m.get("seq").as_usize().unwrap_or(1),
                vocab: m.get("vocab").as_usize().unwrap_or(0),
                name,
                dim,
                layout,
            });
        }
        let mut kernels = Vec::new();
        for k in j.get("kernels").as_arr().unwrap_or(&[]) {
            kernels.push(KernelEntry {
                name: k
                    .get("name")
                    .as_str()
                    .context("kernel missing name")?
                    .to_string(),
                dim: k.get("dim").as_usize().context("kernel missing dim")?,
                file: dir.join(k.get("file").as_str().context("kernel missing file")?),
            });
        }
        Ok(Self {
            root: dir.to_path_buf(),
            models,
            kernels,
        })
    }

    /// Look up a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                format!(
                    "model '{name}' not in manifest (have: {:?})",
                    self.models.iter().map(|m| &m.name).collect::<Vec<_>>()
                )
            })
    }

    /// Look up a kernel by name.
    pub fn kernel(&self, name: &str) -> Result<&KernelEntry> {
        self.kernels
            .iter()
            .find(|k| k.name == name)
            .with_context(|| format!("kernel '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "models": [{
        "name": "txf_tiny", "dim": 10,
        "grad": "grad_txf_tiny.hlo.txt", "eval": "eval_txf_tiny.hlo.txt",
        "batch": 4, "seq": 8, "vocab": 16,
        "layout": [
          {"name": "embed", "shape": [2, 3], "offset": 0},
          {"name": "bias", "shape": [4], "offset": 6}
        ]
      }],
      "kernels": [{"name": "aquila_quant", "dim": 10, "file": "quant_10.hlo.txt"}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.models.len(), 1);
        let model = m.model("txf_tiny").unwrap();
        assert_eq!(model.dim, 10);
        assert_eq!(model.batch, 4);
        assert_eq!(model.layout.entries.len(), 2);
        assert_eq!(model.layout.entries[1].offset, 6);
        assert!(model.grad_file.ends_with("grad_txf_tiny.hlo.txt"));
        assert_eq!(m.kernel("aquila_quant").unwrap().dim, 10);
    }

    #[test]
    fn rejects_dim_layout_mismatch() {
        let bad = SAMPLE.replace("\"dim\": 10", "\"dim\": 11");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
    }
}
