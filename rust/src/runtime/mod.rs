//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! from the Rust hot path — the L2/L3 bridge.
//!
//! Python runs **once** (`make artifacts`: JAX lowers the model and the
//! Pallas kernel to HLO text, see `python/compile/aot.py`); this module
//! loads the text through the `xla` crate
//! (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile`) and executes compiled modules with zero Python
//! involvement per round.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;

pub use manifest::{KernelEntry, Manifest, ModelEntry};

use crate::data::TokenDataset;
use crate::problems::{EvalMetrics, GradScratch, GradientSource, ParamLayout};
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// A compiled HLO module plus the serialized-execution lock.
///
/// SAFETY note: the underlying TFRT CPU PJRT client is thread-safe, but
/// the `xla` crate's wrappers are raw-pointer newtypes without
/// `Send`/`Sync` markers. We (a) serialize every `execute` behind a
/// `Mutex` and (b) never move the client across threads after
/// construction, so declaring the wrapper `Send + Sync` is sound for the
/// CPU client used here.
pub struct HloExecutable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    /// Human-readable identifier (artifact path).
    pub tag: String,
}

unsafe impl Send for HloExecutable {}
unsafe impl Sync for HloExecutable {}

impl HloExecutable {
    /// Run with the given inputs; returns the flattened output tuple.
    ///
    /// `aot.py` lowers every entry with `return_tuple=True`, so the
    /// single output literal is a tuple we decompose.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe.lock().expect("executable lock poisoned");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.tag))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.tag))?;
        lit.to_tuple().with_context(|| format!("untupling {}", self.tag))
    }
}

/// The PJRT CPU runtime: client + compile cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Construct a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<HloExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(HloExecutable {
            exe: Mutex::new(exe),
            tag: path.display().to_string(),
        })
    }
}

/// Token batches for one device: `x[b, s]` inputs and `y[b, s]`
/// next-token targets, flattened row-major.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    /// Input tokens, `batch × seq` row-major.
    pub x: Vec<i32>,
    /// Next-token targets, `batch × seq` row-major.
    pub y: Vec<i32>,
    /// Batch size.
    pub batch: usize,
    /// Sequence length.
    pub seq: usize,
}

impl TokenBatch {
    /// Deterministically carve `batch` sequences of length `seq` from a
    /// token shard (full-batch local data in the paper's sense).
    pub fn from_shard(shard: &TokenDataset, batch: usize, seq: usize) -> Result<Self> {
        let need = batch * seq + 1;
        if shard.len() < need {
            anyhow::bail!(
                "shard too short: {} tokens < batch {batch} × seq {seq} + 1",
                shard.len()
            );
        }
        let stride = (shard.len() - seq - 1) / batch.max(1);
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let start = b * stride;
            for s in 0..seq {
                x.push(shard.tokens[start + s] as i32);
                y.push(shard.tokens[start + s + 1] as i32);
            }
        }
        Ok(Self { x, y, batch, seq })
    }

    fn literals(&self) -> Result<(xla::Literal, xla::Literal)> {
        let dims = [self.batch as i64, self.seq as i64];
        let x = xla::Literal::vec1(&self.x).reshape(&dims)?;
        let y = xla::Literal::vec1(&self.y).reshape(&dims)?;
        Ok((x, y))
    }
}

/// A [`GradientSource`] backed by AOT-compiled JAX models executed via
/// PJRT — the neural-model path of the three-layer architecture.
pub struct HloGradientSource {
    grad_exe: HloExecutable,
    eval_exe: HloExecutable,
    dim: usize,
    layout: ParamLayout,
    shards: Vec<TokenBatch>,
    eval_batch: TokenBatch,
    init_scale: f32,
    /// Report perplexity (LM) vs plain loss.
    lm_metrics: bool,
}

impl HloGradientSource {
    /// Build from a manifest model entry + per-device token shards +
    /// held-out tokens.
    pub fn new(
        runtime: &PjrtRuntime,
        model: &ModelEntry,
        device_shards: &[TokenDataset],
        heldout: &TokenDataset,
    ) -> Result<Self> {
        let grad_exe = runtime.load_hlo(&model.grad_file)?;
        let eval_exe = runtime.load_hlo(&model.eval_file)?;
        let shards = device_shards
            .iter()
            .map(|s| TokenBatch::from_shard(s, model.batch, model.seq))
            .collect::<Result<Vec<_>>>()?;
        let eval_batch = TokenBatch::from_shard(heldout, model.batch, model.seq)?;
        Ok(Self {
            grad_exe,
            eval_exe,
            dim: model.dim,
            layout: model.layout.clone(),
            shards,
            eval_batch,
            init_scale: 0.02,
            lm_metrics: true,
        })
    }

    fn run_grad(&self, theta: &[f32], batch: &TokenBatch) -> Result<(f64, Vec<f32>)> {
        let t = xla::Literal::vec1(theta);
        let (x, y) = batch.literals()?;
        let outs = self.grad_exe.run(&[t, x, y])?;
        anyhow::ensure!(outs.len() == 2, "grad entry must return (loss, grad)");
        let loss = outs[0].to_vec::<f32>()?[0] as f64;
        let grad = outs[1].to_vec::<f32>()?;
        Ok((loss, grad))
    }
}

impl GradientSource for HloGradientSource {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        _scratch: &mut GradScratch,
    ) -> f64 {
        // The workspace is unused: PJRT owns the intermediate buffers
        // on its side of the FFI boundary.
        let (loss, g) = self
            .run_grad(theta, &self.shards[device])
            .expect("HLO gradient execution failed");
        grad.copy_from_slice(&g);
        loss
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let t = xla::Literal::vec1(theta);
        let (x, y) = self.eval_batch.literals().expect("eval batch literals");
        let outs = self
            .eval_exe
            .run(&[t, x, y])
            .expect("HLO eval execution failed");
        let loss = outs[0].to_vec::<f32>().expect("eval loss")[0] as f64;
        EvalMetrics {
            loss,
            accuracy: None,
            perplexity: if self.lm_metrics { Some(loss.exp()) } else { None },
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256pp::stream(seed, 0x7F0);
        (0..self.dim)
            .map(|_| rng.gaussian_f32(0.0, self.init_scale))
            .collect()
    }

    fn layout(&self) -> ParamLayout {
        self.layout.clone()
    }
}

/// The L1 kernel loaded as an HLO artifact: the fused AQUILA device
/// step (innovation norms → eq. 19 level → mid-tread quantize →
/// dequantized Δq + skip-rule norms), used for Rust↔Pallas parity tests
/// and the `pjrt` quantization backend.
pub struct HloQuantKernel {
    exe: HloExecutable,
    /// Fixed input dimension of the kernel.
    pub dim: usize,
}

/// Output of the fused HLO device step (mirrors
/// `quant::midtread::QuantizeOutcome` + the level decision).
#[derive(Clone, Debug)]
pub struct HloQuantResult {
    /// Dequantized innovation `Δq`.
    pub dq: Vec<f32>,
    /// Quantization range `R`.
    pub range: f32,
    /// Selected level `b` (eq. 19).
    pub bits: u8,
    /// `‖Δq‖²` (skip-rule numerator).
    pub dq_norm_sq: f64,
    /// `‖ε‖²` quantization error norm.
    pub err_norm_sq: f64,
}

impl HloQuantKernel {
    /// Compile the kernel's HLO artifact on `runtime`.
    pub fn load(runtime: &PjrtRuntime, entry: &KernelEntry) -> Result<Self> {
        Ok(Self {
            exe: runtime.load_hlo(&entry.file)?,
            dim: entry.dim,
        })
    }

    /// Execute the fused step for `(g, q_prev)`.
    pub fn run(&self, grad: &[f32], q_prev: &[f32]) -> Result<HloQuantResult> {
        anyhow::ensure!(grad.len() == self.dim && q_prev.len() == self.dim);
        let g = xla::Literal::vec1(grad);
        let q = xla::Literal::vec1(q_prev);
        let outs = self.exe.run(&[g, q])?;
        anyhow::ensure!(
            outs.len() == 5,
            "quant kernel must return (dq, range, bits, dq_norm_sq, err_norm_sq)"
        );
        Ok(HloQuantResult {
            dq: outs[0].to_vec::<f32>()?,
            range: outs[1].to_vec::<f32>()?[0],
            bits: outs[2].to_vec::<i32>()?[0] as u8,
            dq_norm_sq: outs[3].to_vec::<f32>()?[0] as f64,
            err_norm_sq: outs[4].to_vec::<f32>()?[0] as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::{markov_corpus, CorpusSpec};

    #[test]
    fn token_batch_shapes() {
        let ds = markov_corpus(&CorpusSpec::wikitext2_like(1000, 1));
        let b = TokenBatch::from_shard(&ds, 4, 16).unwrap();
        assert_eq!(b.x.len(), 64);
        assert_eq!(b.y.len(), 64);
        // y is x shifted by one.
        assert_eq!(b.x[1], b.y[0]);
    }

    #[test]
    fn token_batch_rejects_short_shard() {
        let ds = markov_corpus(&CorpusSpec::wikitext2_like(20, 2));
        assert!(TokenBatch::from_shard(&ds, 8, 16).is_err());
    }
}
