//! `repro` — the AQUILA reproduction CLI (leader entrypoint).
//!
//! ```text
//! repro table2 [--scale S] [--rounds N] [--out DIR]   Table II (homogeneous)
//! repro table3 [--scale S] [--rounds N] [--out DIR]   Table III (heterogeneous)
//! repro fig2   [--out DIR]                            Figure 2 series (CSV)
//! repro fig3   [--out DIR]                            Figure 3 series (CSV)
//! repro ablation-beta [--dataset D]                   Figures 4–5 β sweep
//! repro run --config FILE [--algo NAME] [--select SPEC] [--network SPEC]
//!           [--quant-sections SPEC] [--dadaquant-b0 B] [--dadaquant-patience P]
//!           [--dadaquant-cap C] [--out FILE.csv] [--jsonl FILE.jsonl]
//!           [--serve [ADDR] | --connect ADDR] [--chaos SPEC]
//!           [--checkpoint FILE [--checkpoint-every N]] [--resume FILE]
//!           [--population N] [--slot-cache C]
//!           [--aggregation SPEC]                       single configured run
//! repro theory                                        Corollary-1/Theorem-3 numbers
//! repro list                                          presets + algorithms + strategies
//! ```

use aquila::algorithms::{self, Algorithm};
use aquila::config::{table2_rows, table3_rows, DatasetKind, ExperimentSpec, SplitKind};
use aquila::coordinator::checkpoint::Checkpoint;
use aquila::metrics::bits_display;
use aquila::metrics::observer::{CsvStream, JsonLines};
use aquila::problems::GradientSource;
use aquila::protocol::{
    ChaosSpec, CoordinatorService, DeviceClient, Dial, TcpDialer, TcpTransport, Transport,
};
use aquila::quant::SectionSpec;
use aquila::repro;
use aquila::selection::SelectionSpec;
use aquila::transport::scenario::NetworkSpec;
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args() -> Args {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = std::collections::BTreeMap::new();
    let mut key: Option<String> = None;
    for a in it {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some(k) = key.take() {
                flags.insert(k, "true".to_string());
            }
            key = Some(stripped.to_string());
        } else if let Some(k) = key.take() {
            flags.insert(k, a);
        }
    }
    if let Some(k) = key.take() {
        flags.insert(k, "true".to_string());
    }
    Args { cmd, flags }
}

fn apply_common_flags(rows: &mut [ExperimentSpec], args: &Args) {
    if let Some(s) = args.flags.get("scale").and_then(|v| v.parse::<f64>().ok()) {
        for r in rows.iter_mut() {
            r.data_scale = s;
        }
    }
    if let Some(n) = args.flags.get("rounds").and_then(|v| v.parse::<usize>().ok()) {
        for r in rows.iter_mut() {
            r.rounds = n;
        }
    }
    if let Some(seed) = args.flags.get("seed").and_then(|v| v.parse::<u64>().ok()) {
        for r in rows.iter_mut() {
            r.seed = seed;
        }
    }
}

fn out_dir(args: &Args, default: &str) -> PathBuf {
    PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| default.to_string()),
    )
}

fn algo_by_name(name: &str, beta: f32) -> Option<Arc<dyn Algorithm>> {
    match name.to_ascii_lowercase().as_str() {
        "aquila" => Some(Arc::new(algorithms::aquila::Aquila::new(beta))),
        "qsgd" => Some(Arc::new(algorithms::qsgd::QsgdAlgo::new(8))),
        "adaquantfl" | "adaq" => Some(Arc::new(algorithms::adaquantfl::AdaQuantFl::new(4, 32))),
        "laq" => Some(Arc::new(algorithms::laq::Laq::new(8, 0.8, 10))),
        "ladaq" => Some(Arc::new(algorithms::ladaq::LAdaQ::new(4, 32, 0.8, 10))),
        "lena" => Some(Arc::new(algorithms::lena::Lena::new(0.8, 10))),
        "marina" => Some(Arc::new(algorithms::marina::Marina::new(8, 0.1))),
        "fedavg" => Some(Arc::new(algorithms::fedavg::FedAvg)),
        "dadaquant" => Some(Arc::new(algorithms::dadaquant::DAdaQuant::uniform(16))),
        _ => None,
    }
}

fn cmd_table(which: u8, args: &Args) {
    let mut rows = if which == 2 { table2_rows() } else { table3_rows() };
    apply_common_flags(&mut rows, args);
    let dir = out_dir(args, if which == 2 { "results/table2" } else { "results/table3" });
    let title = if which == 2 {
        "Table II — total communication bits, homogeneous"
    } else {
        "Table III — total communication bits, heterogeneous (100%-50%)"
    };
    repro::run_table(title, &rows, Some(&dir));
    println!("\ntraces written to {}", dir.display());
}

fn cmd_fig(which: u8, args: &Args) {
    // Figures 2/3 plot the M = 10 rows; the CSV traces (loss vs
    // cumulative bits; bits per epoch vs epoch) are the series.
    let mut rows: Vec<ExperimentSpec> = if which == 2 {
        table2_rows()
            .into_iter()
            .filter(|r| r.split != SplitKind::IidLarge)
            .collect()
    } else {
        table3_rows()
    };
    apply_common_flags(&mut rows, args);
    let dir = out_dir(args, if which == 2 { "results/fig2" } else { "results/fig3" });
    let title = if which == 2 {
        "Figure 2 series — homogeneous"
    } else {
        "Figure 3 series — heterogeneous"
    };
    repro::run_table(title, &rows, Some(&dir));
    println!(
        "\nper-round series (loss vs bits, bits vs epoch) in {}",
        dir.display()
    );
}

fn cmd_ablation(args: &Args) {
    let betas: Vec<f32> = args
        .flags
        .get("betas")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| vec![0.0, 0.1, 0.25, 0.5, 1.25, 2.5, 5.0]);
    let datasets: Vec<DatasetKind> = match args.flags.get("dataset").map(|s| s.as_str()) {
        Some(d) => vec![DatasetKind::parse(d).expect("unknown dataset")],
        None => vec![DatasetKind::Cf10, DatasetKind::Cf100, DatasetKind::Wt2],
    };
    let dir = out_dir(args, "results/ablation");
    for ds in datasets {
        let mut spec = ExperimentSpec::new(ds, SplitKind::Iid, false);
        if let Some(s) = args.flags.get("scale").and_then(|v| v.parse().ok()) {
            spec.data_scale = s;
        }
        if let Some(n) = args.flags.get("rounds").and_then(|v| v.parse().ok()) {
            spec.rounds = n;
        }
        println!("\n=== Figure 4/5 — β ablation on {} ===", ds.name());
        println!(
            "{:>8} {:>12} {:>12} {:>10} {:>8}",
            "beta", "final", "bits(Gb)", "uploads", "skip%"
        );
        for (beta, trace) in repro::ablation_beta(&spec, &betas) {
            let total = trace.total_uploads() + trace.total_skips();
            let skip_pct = 100.0 * trace.total_skips() as f64 / total.max(1) as f64;
            println!(
                "{beta:>8.2} {:>12} {:>12} {:>10} {skip_pct:>7.1}%",
                repro::metric_display(&trace),
                bits_display(trace.total_bits()),
                trace.total_uploads(),
            );
            let fname = format!(
                "{}_beta{beta}.csv",
                ds.name().to_lowercase().replace('-', "")
            );
            trace.write_csv(&dir.join(fname)).expect("write csv");
        }
    }
    println!("\nseries written to {}", dir.display());
}

fn cmd_run(args: &Args) -> ExitCode {
    let Some(cfg_path) = args.flags.get("config") else {
        eprintln!("repro run requires --config FILE");
        return ExitCode::FAILURE;
    };
    let mut spec = match ExperimentSpec::from_file(std::path::Path::new(cfg_path)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("config error: {e}");
            return ExitCode::FAILURE;
        }
    };
    if let Some(s) = args.flags.get("select") {
        match SelectionSpec::parse(s) {
            Some(sel) => spec.selection = sel,
            None => {
                eprintln!(
                    "unknown selection spec '{s}' (try: {})",
                    SelectionSpec::SYNTAX
                );
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(s) = args.flags.get("network") {
        match NetworkSpec::parse(s) {
            Some(net) => spec.network = net,
            None => {
                eprintln!("unknown network spec '{s}' (try: {})", NetworkSpec::SYNTAX);
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(s) = args.flags.get("quant-sections") {
        match SectionSpec::parse(s) {
            Some(q) => spec.quant_sections = q,
            None => {
                eprintln!(
                    "unknown quant-sections spec '{s}' (try: {})",
                    SectionSpec::SYNTAX
                );
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(s) = args.flags.get("chaos") {
        match ChaosSpec::parse(s) {
            Some(c) => spec.chaos = c,
            None => {
                eprintln!("bad chaos spec '{s}' (try: {})", ChaosSpec::SYNTAX);
                return ExitCode::FAILURE;
            }
        }
    }
    // DAdaQuant schedule overrides (`dadaquant_*` TOML keys have the
    // same effect; the CLI wins).
    if let Some(v) = args.flags.get("dadaquant-b0") {
        match v.parse::<u8>() {
            Ok(b) if (1..=32).contains(&b) => spec.dadaquant_b0 = b,
            _ => {
                eprintln!("--dadaquant-b0 must be an integer in 1..=32, got '{v}'");
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(v) = args.flags.get("dadaquant-patience") {
        match v.parse::<u32>() {
            Ok(p) if p >= 1 => spec.dadaquant_patience = p,
            _ => {
                eprintln!("--dadaquant-patience must be a positive integer, got '{v}'");
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(v) = args.flags.get("dadaquant-cap") {
        match v.parse::<u8>() {
            Ok(c) if (1..=32).contains(&c) => spec.dadaquant_cap = c,
            _ => {
                eprintln!("--dadaquant-cap must be an integer in 1..=32, got '{v}'");
                return ExitCode::FAILURE;
            }
        }
    }
    // Population virtualization: `--population N` swaps in the streamed
    // N-device quadratic with a lazy slot store; `--slot-cache C`
    // bounds (or, with 0, unbounds) the live-slot cache.
    if let Some(v) = args.flags.get("population") {
        match v.parse::<usize>() {
            Ok(n) if n >= 1 => spec.population = Some(n),
            _ => {
                eprintln!("--population must be a positive integer, got '{v}'");
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(v) = args.flags.get("slot-cache") {
        match v.parse::<usize>() {
            Ok(c) => spec.slot_cache = Some(c),
            _ => {
                eprintln!("--slot-cache must be a non-negative integer, got '{v}'");
                return ExitCode::FAILURE;
            }
        }
    }
    // Aggregation mode: the default sync barrier or the buffered-async
    // event engine (`[aggregation]` TOML table has the same effect;
    // the CLI wins).
    if let Some(v) = args.flags.get("aggregation") {
        match aquila::coordinator::AggregationMode::parse(v) {
            Some(mode) => spec.aggregation = mode,
            None => {
                eprintln!(
                    "unknown aggregation spec '{v}' (try: {})",
                    aquila::coordinator::AggregationMode::SYNTAX
                );
                return ExitCode::FAILURE;
            }
        }
    }
    let algo_name = args
        .flags
        .get("algo")
        .map(|s| s.as_str())
        .unwrap_or("aquila");
    let Some(algo) = algo_by_name(algo_name, spec.beta) else {
        eprintln!("unknown algorithm '{algo_name}'");
        return ExitCode::FAILURE;
    };
    // Protocol roles: `--connect ADDR` turns this process into a device
    // client of a remote coordinator; `--serve [ADDR]` serves the run
    // over TCP instead of executing the device phase in-process. The
    // buffered-async engine is in-process only: the wire protocol has
    // no per-upload arrival events yet.
    if !spec.aggregation.is_sync()
        && (args.flags.contains_key("serve") || args.flags.contains_key("connect"))
    {
        eprintln!("buffered aggregation is not supported with --serve/--connect (in-process only)");
        return ExitCode::FAILURE;
    }
    if let Some(addr) = args.flags.get("connect") {
        return cmd_connect(&spec, algo, addr);
    }
    if let Some(v) = args.flags.get("serve") {
        // Bare `--serve` listens on the config's serve.addr.
        if v != "true" {
            spec.serve.addr = v.clone();
        }
    }
    // Crash-recovery flags: periodic checkpoints out, a restored
    // snapshot in. Both work for in-process and served runs.
    let checkpoint = args.flags.get("checkpoint").map(PathBuf::from);
    let ckpt_every = match args.flags.get("checkpoint-every") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("--checkpoint-every must be a positive integer, got '{v}'");
                return ExitCode::FAILURE;
            }
        },
        None => 1,
    };
    let resume = match args.flags.get("resume") {
        Some(p) => match Checkpoint::load(std::path::Path::new(p)) {
            Ok(c) => Some(c),
            Err(e) => {
                eprintln!("cannot load --resume {p}: {e}");
                return ExitCode::FAILURE;
            }
        },
        None => None,
    };
    println!(
        "running {} on {} ({} devices, {} rounds, α={}, β={}, select={}, network={}, sections={}, aggregation={})",
        algo.name(),
        if spec.population.is_some() {
            "virtualized population".to_string()
        } else {
            spec.row_label()
        },
        spec.effective_devices(),
        spec.rounds,
        spec.alpha,
        spec.beta,
        spec.selection,
        spec.network,
        spec.quant_sections,
        spec.aggregation,
    );
    // Streaming sinks: rounds hit the files as they complete.
    let mut builder = repro::session_for(&spec, algo);
    if let Some(out) = args.flags.get("out") {
        match CsvStream::create(std::path::Path::new(out)) {
            Ok(obs) => builder = builder.observer(Box::new(obs)),
            Err(e) => {
                eprintln!("cannot open --out {out}: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    if let Some(path) = args.flags.get("jsonl") {
        match JsonLines::create(std::path::Path::new(path)) {
            Ok(obs) => builder = builder.observer(Box::new(obs)),
            Err(e) => {
                eprintln!("cannot open --jsonl {path}: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    let trace = if args.flags.contains_key("serve") {
        let mut service = CoordinatorService::new(builder.build(), spec.serve.clone());
        if let Some(path) = &checkpoint {
            service = service.checkpoint_to(path.clone(), ckpt_every);
        }
        if let Some(ckpt) = &resume {
            match service.resume_from(ckpt) {
                Ok(next) => println!("resumed from checkpoint, continuing at round {next}"),
                Err(e) => {
                    eprintln!("cannot resume: {e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        let tcp = match TcpTransport::bind(&spec.serve.addr) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot bind {}: {e}", spec.serve.addr);
                return ExitCode::FAILURE;
            }
        };
        if let Ok(addr) = tcp.local_addr() {
            println!(
                "serving on {addr}, waiting for {} client(s)",
                service.serve_spec().clients
            );
        }
        let mut transport: Box<dyn Transport> = Box::new(tcp);
        if spec.chaos.is_enabled() {
            println!("chaos enabled on the coordinator transport: {}", spec.chaos);
            transport = Box::new(spec.chaos.clone().wrap_transport(transport));
        }
        match service.run(transport.as_mut()) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("serve failed: {e}");
                return ExitCode::FAILURE;
            }
        }
    } else {
        let mut session = builder.build();
        let start = match &resume {
            Some(ckpt) => match session.restore(ckpt) {
                Ok(next) => {
                    println!("resumed from checkpoint, continuing at round {next}");
                    next
                }
                Err(e) => {
                    eprintln!("cannot resume: {e}");
                    return ExitCode::FAILURE;
                }
            },
            None => 0,
        };
        if let Some(path) = checkpoint.clone() {
            session.checkpoint_to(path, ckpt_every);
        }
        session.run_from(start)
    };
    println!("{}", trace.summary_json());
    if let Some(out) = args.flags.get("out") {
        println!("trace streamed to {out}");
    }
    if let Some(path) = args.flags.get("jsonl") {
        println!("json-lines streamed to {path}");
    }
    ExitCode::SUCCESS
}

/// `repro run --connect ADDR`: serve a device range for a remote
/// coordinator, constructing the identical problem/masks/config from
/// the shared experiment file.
fn cmd_connect(spec: &ExperimentSpec, algo: Arc<dyn Algorithm>, addr: &str) -> ExitCode {
    println!("connecting to coordinator at {addr} as a device client");
    let problem: Arc<dyn GradientSource> = spec.build_problem().into();
    let masks = repro::mask_table_for(spec, problem.as_ref());
    let client = DeviceClient::with_mask_table(problem, algo, spec.run_config(), masks)
        .heartbeat_ms(spec.serve.heartbeat_ms)
        .reconnect(10, 50, 2_000)
        .idle_timeout_ms(spec.serve.round_timeout_ms.saturating_mul(2).max(1_000));
    let tcp = TcpDialer::new(addr, std::time::Duration::from_secs(10));
    let dialer: Box<dyn Dial> = if spec.chaos.is_enabled() {
        println!("chaos enabled on client dials: {}", spec.chaos);
        Box::new(spec.chaos.clone().wrap_dial(Box::new(tcp), 1))
    } else {
        Box::new(tcp)
    };
    match client.run_with(dialer.as_ref()) {
        Ok(rep) => {
            println!(
                "client {} served devices {}..{} for {} round(s)",
                rep.client_id, rep.devices.start, rep.devices.end, rep.rounds_served
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("client failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_theory() {
    use aquila::theory;
    // The paper's worked hyperparameter example (after Corollary 2).
    let (l, alpha, beta, gamma, mu) = (2.5, 0.1, 0.25, 2.0, 0.5);
    println!(
        "Corollary 1 condition L/2 - 1/(2α) + βγ/α ≤ 0 with (L={l}, α={alpha}, β={beta}, γ={gamma}):"
    );
    println!(
        "  satisfied = {}",
        theory::corollary1_condition(l, alpha, beta, gamma)
    );
    println!(
        "  max feasible β = {:.4}",
        theory::max_feasible_beta(l, alpha, gamma)
    );
    let k_nc = theory::corollary1_rounds(1.0, 0.0, 0.01, alpha, beta, gamma, 1e-3);
    println!("Corollary 1 rounds to ‖∇f‖² ≤ 1e-3 (f(θ¹)−f* = 1): K = {k_nc:.0}");
    let k_pl = theory::theorem3_rounds(1.0, 0.0, 0.01, alpha, l, mu, 1e-6);
    let omega1 = 1.0 + (1.0 / (2.0 * alpha) - l / 2.0) * 0.01;
    let k_lag = theory::lag_rounds(omega1, alpha, mu, 10.0, 0.05, 1e-6);
    println!("Theorem 3 (PL μ={mu}) rounds to ε=1e-6: K_AQUILA = {k_pl:.0}, K_LAG = {k_lag:.0}");
}

fn cmd_list() {
    println!("Table II rows:");
    for r in table2_rows() {
        println!(
            "  {:<18} M={:<4} rounds={:<5} α={:<5} β={}",
            r.row_label(),
            r.devices,
            r.rounds,
            r.alpha,
            r.beta
        );
    }
    println!("Table III rows (heterogeneous):");
    for r in table3_rows() {
        println!("  {:<18} M={:<4}", r.row_label(), r.devices);
    }
    println!("algorithms: qsgd adaquantfl laq ladaq lena marina aquila fedavg dadaquant");
    println!(
        "selection strategies (--select / selection = \"...\"): {}",
        SelectionSpec::SYNTAX
    );
    println!(
        "network scenarios (--network / network = \"...\"): {}",
        NetworkSpec::SYNTAX
    );
    println!(
        "quantization sections (--quant-sections / quant_sections = \"...\"): {}",
        SectionSpec::SYNTAX
    );
    println!(
        "serve config ([serve] TOML table): addr clients heartbeat_ms heartbeat_timeout_ms \
         round_timeout_ms accept_timeout_ms"
    );
    println!(
        "chaos injection ([chaos] TOML table / --chaos): {}",
        ChaosSpec::SYNTAX
    );
    println!(
        "aggregation modes (--aggregation / aggregation = \"...\"): {}",
        aquila::coordinator::AggregationMode::SYNTAX
    );
    println!("flags per command:");
    println!("  table2 | table3 | fig2 | fig3   --scale S --rounds N --seed K --out DIR");
    println!("  ablation-beta                   --betas B1,B2,.. --dataset D --scale S");
    println!("                                  --rounds N --out DIR");
    // The `run` rows come from the canonical flag table so this
    // listing cannot drift from what the parser accepts.
    println!("  run");
    for (flag, toml_key, help) in aquila::config::RUN_FLAG_SURFACE {
        let toml = match toml_key {
            Some(k) => format!("  [toml: {k}]"),
            None => String::new(),
        };
        println!("    --{flag:<19} {help}{toml}");
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    /// Flags consumed by the table/figure/ablation commands — they
    /// have no TOML counterpart and are listed on their own `repro
    /// list` rows, not in the `run` table.
    const COMMON_FLAGS: &[&str] = &["scale", "rounds", "seed", "betas", "dataset", "out"];

    /// Every flag the binary parses out of `args.flags`, scraped from
    /// this very source file. Escaped quotes inside string literals
    /// (e.g. in `format!` arguments) do not match the patterns, so the
    /// scrape sees exactly the `flags.get("…")` call sites.
    fn parsed_flags() -> BTreeSet<&'static str> {
        let src = include_str!("main.rs");
        let mut flags = BTreeSet::new();
        for pat in ["flags.get(\"", "flags.contains_key(\""] {
            for part in src.split(pat).skip(1) {
                if let Some(flag) = part.split('"').next() {
                    flags.insert(flag);
                }
            }
        }
        flags
    }

    #[test]
    fn every_parsed_flag_is_in_the_canonical_surface() {
        let surface: BTreeSet<&str> = aquila::config::RUN_FLAG_SURFACE
            .iter()
            .map(|(flag, _, _)| *flag)
            .collect();
        let parsed = parsed_flags();
        assert!(parsed.len() > 15, "flag scrape found too few sites — pattern rot?");
        for flag in &parsed {
            assert!(
                surface.contains(flag) || COMMON_FLAGS.contains(flag),
                "main.rs parses --{flag} but RUN_FLAG_SURFACE has no row for it \
                 (so `repro list` would not print it)"
            );
        }
        for (flag, _, _) in aquila::config::RUN_FLAG_SURFACE {
            assert!(
                parsed.contains(flag),
                "RUN_FLAG_SURFACE lists --{flag} but main.rs never parses it"
            );
        }
    }

    #[test]
    fn readme_documents_every_run_flag() {
        let readme = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"))
            .expect("README.md at the repo root");
        for (flag, _, _) in aquila::config::RUN_FLAG_SURFACE {
            assert!(
                readme.contains(&format!("--{flag}")),
                "README.md does not document --{flag}"
            );
        }
    }
}

fn main() -> ExitCode {
    let args = parse_args();
    match args.cmd.as_str() {
        "table2" => cmd_table(2, &args),
        "table3" => cmd_table(3, &args),
        "fig2" => cmd_fig(2, &args),
        "fig3" => cmd_fig(3, &args),
        "ablation-beta" => cmd_ablation(&args),
        "run" => return cmd_run(&args),
        "theory" => cmd_theory(),
        "list" => cmd_list(),
        _ => {
            println!("AQUILA reproduction CLI — commands:");
            println!("  table2 | table3 | fig2 | fig3 | ablation-beta | run | theory | list");
            println!("  common flags: --scale S --rounds N --seed K --out DIR");
            println!("  run flags: --config FILE --algo NAME --select SPEC --network SPEC");
            println!("             --quant-sections SPEC --jsonl FILE --dadaquant-b0 B");
            println!("             --dadaquant-patience P --dadaquant-cap C");
            println!("             --serve [ADDR] (coordinator) | --connect ADDR (client)");
            println!("             --chaos SPEC --checkpoint FILE [--checkpoint-every N]");
            println!("             --resume FILE --population N --slot-cache C");
            println!("             --aggregation SPEC (sync | buffered async)");
            println!("  `repro list` prints the full flag surface and spec syntaxes");
        }
    }
    ExitCode::SUCCESS
}
