//! Small convolutional network with manual backprop — the closest
//! native-Rust analogue of the paper's ResNet-18 / MobileNet-v2
//! workloads (the conv-net gradient structure — shared weights, spatial
//! pooling — produces different innovation statistics than the MLP,
//! exercised by the Table II/III CF-10 rows when configured with
//! `cnn = true`).
//!
//! Architecture over `H×W` single-channel images:
//!
//! ```text
//! x (H×W) → conv C filters k×k (same pad) → ReLU → 2×2 avg-pool
//!         → flatten → dense K → softmax
//! ```
//!
//! Layout: `conv_w [C×k×k] | conv_b [C] | fc_w [K×(C·H/2·W/2)] | fc_b [K]`.
//!
//! The convolution runs as a GEMM over **im2col patch matrices**
//! precomputed per shard at construction (inputs never change between
//! rounds): forward `conv[n·S²×C] = P·W_convᵀ`, backward
//! `∂W_conv[C×k²] = δ_convᵀ·P` — with the dense head batched the same
//! way as the MLP. The per-sample pre-batching path is retained as
//! [`CnnProblem::local_grad_naive`].

use super::{
    add_l2, stage_output_deltas, zeroed, EvalMetrics, GradScratch, GradientSource, ParamLayout,
};
use crate::data::ClassificationDataset;
use crate::util::gemm::{col_sum_add, gemm_nn, gemm_nt, gemm_tn};
use crate::util::rng::Xoshiro256pp;

/// See module docs.
pub struct CnnProblem {
    shards: Vec<ClassificationDataset>,
    test: ClassificationDataset,
    /// Per-shard im2col matrices (`n·S² × k²`, zero-padded borders).
    shard_patches: Vec<Vec<f32>>,
    /// im2col of the held-out set.
    test_patches: Vec<f32>,
    /// Image side (input dim must be `side²`).
    side: usize,
    /// Conv filters.
    channels: usize,
    /// Kernel size (odd).
    ksize: usize,
    classes: usize,
    l2: f32,
}

/// Build the im2col patch matrix: one `k²` row per (sample, pixel),
/// zero where the window leaves the image — so `P·Wᵀ` reproduces the
/// same-padded convolution exactly.
fn im2col(data: &ClassificationDataset, side: usize, ksize: usize) -> Vec<f32> {
    let half = ksize / 2;
    let k2 = ksize * ksize;
    let n = data.len();
    let mut out = vec![0.0f32; n * side * side * k2];
    for i in 0..n {
        let x = data.row(i);
        for r in 0..side {
            for q in 0..side {
                let base = ((i * side + r) * side + q) * k2;
                let patch = &mut out[base..base + k2];
                for dr in 0..ksize {
                    let rr = r as isize + dr as isize - half as isize;
                    if rr < 0 || rr >= side as isize {
                        continue;
                    }
                    for dq in 0..ksize {
                        let qq = q as isize + dq as isize - half as isize;
                        if qq < 0 || qq >= side as isize {
                            continue;
                        }
                        patch[dr * ksize + dq] = x[rr as usize * side + qq as usize];
                    }
                }
            }
        }
    }
    out
}

impl CnnProblem {
    /// CNN over square inputs: `channels` conv filters of odd `ksize`,
    /// 2×2 pooling, linear head; `l2` weight decay.
    pub fn new(
        shards: Vec<ClassificationDataset>,
        test: ClassificationDataset,
        channels: usize,
        ksize: usize,
        l2: f32,
    ) -> Self {
        assert!(!shards.is_empty());
        let dim_in = shards[0].dim;
        let side = (dim_in as f64).sqrt() as usize;
        assert_eq!(side * side, dim_in, "input dim must be a square");
        assert!(side % 2 == 0, "side must be even for 2×2 pooling");
        assert!(ksize % 2 == 1, "kernel must be odd");
        let classes = shards[0].num_classes;
        for s in &shards {
            assert_eq!(s.dim, dim_in);
            assert!(!s.is_empty());
        }
        let shard_patches = shards.iter().map(|s| im2col(s, side, ksize)).collect();
        let test_patches = im2col(&test, side, ksize);
        Self {
            shards,
            test,
            shard_patches,
            test_patches,
            side,
            channels,
            ksize,
            classes,
            l2,
        }
    }

    fn pooled(&self) -> usize {
        (self.side / 2) * (self.side / 2)
    }

    fn offsets(&self) -> (usize, usize, usize, usize) {
        let (c, k2, k) = (self.channels, self.ksize * self.ksize, self.classes);
        let conv_w = 0;
        let conv_b = conv_w + c * k2;
        let fc_w = conv_b + c;
        let fc_b = fc_w + k * c * self.pooled();
        (conv_w, conv_b, fc_w, fc_b)
    }

    /// Batched forward + optional backward over one dataset (`patches`
    /// must be its im2col matrix); returns `(mean loss, correct)`.
    fn loss_grad_on(
        &self,
        data: &ClassificationDataset,
        patches: &[f32],
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
        scratch: &mut GradScratch,
    ) -> (f64, usize) {
        let (s, c, kk) = (self.side, self.channels, self.ksize);
        let k2 = kk * kk;
        let ps = s / 2;
        let pooled = ps * ps;
        let feat = c * pooled;
        let k_out = self.classes;
        let (o_cw, o_cb, o_fw, o_fb) = self.offsets();
        let n = data.len();
        let rows = n * s * s;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let conv_w = &theta[o_cw..o_cw + c * k2];
        let conv_b = &theta[o_cb..o_cb + c];
        let fc_w = &theta[o_fw..o_fw + k_out * feat];
        let fc_b = &theta[o_fb..o_fb + k_out];

        // Conv as GEMM: conv[rows×C] = P·W_convᵀ + bias (pre-ReLU;
        // spatial-major, channel-minor layout).
        let conv = zeroed(&mut scratch.conv, rows * c);
        for row in conv.chunks_exact_mut(c) {
            row.copy_from_slice(conv_b);
        }
        gemm_nt(patches, conv_w, conv, rows, c, k2);

        // 2×2 average pool over ReLU(conv), into the fc feature layout
        // pool[i, ch·pooled + r·ps + q].
        let pool = zeroed(&mut scratch.hidden, n * feat);
        for (conv_i, pool_i) in conv.chunks_exact(s * s * c).zip(pool.chunks_exact_mut(feat)) {
            for ch in 0..c {
                for r in 0..ps {
                    for q in 0..ps {
                        let mut acc = 0.0f32;
                        for dr in 0..2 {
                            for dq in 0..2 {
                                acc += conv_i[((2 * r + dr) * s + 2 * q + dq) * c + ch].max(0.0);
                            }
                        }
                        pool_i[ch * pooled + r * ps + q] = acc * 0.25;
                    }
                }
            }
        }

        // Dense head: logits[n×K] = pool·W_fcᵀ + 1·bᵀ.
        let logits = zeroed(&mut scratch.logits, n * k_out);
        for row in logits.chunks_exact_mut(k_out) {
            row.copy_from_slice(fc_b);
        }
        gemm_nt(pool, fc_w, logits, n, k_out, feat);

        // Softmax + CE per row; δ_out staged in place (× 1/n).
        scratch.probs.clear();
        scratch.probs.resize(k_out, 0.0);
        let probs = &mut scratch.probs[..];
        let want_grad = grad.is_some();
        let inv_n = 1.0 / n as f64;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for (row, &y) in logits.chunks_exact_mut(k_out).zip(&data.labels) {
            loss += super::logistic::softmax_row(row, y, probs, &mut correct);
            if want_grad {
                stage_output_deltas(row, probs, y, inv_n);
            }
        }
        loss *= inv_n;

        if let Some(g) = grad.as_deref_mut() {
            // Dense head: ∂W_fc += δ_outᵀ·pool, ∂b_fc = colsum(δ_out).
            gemm_tn(logits, pool, &mut g[o_fw..o_fw + k_out * feat], k_out, feat, n);
            col_sum_add(logits, &mut g[o_fb..o_fb + k_out], k_out);
            // δ_pool[n×feat] = δ_out·W_fc.
            let dpool = zeroed(&mut scratch.dhidden, n * feat);
            gemm_nn(logits, fc_w, dpool, n, feat, k_out);
            // Unpool through the 2×2 average and the ReLU gate into
            // δ_conv[rows×C] (every conv cell belongs to one pool cell).
            let dconv = zeroed(&mut scratch.dconv, rows * c);
            for ((conv_i, dconv_i), dpool_i) in conv
                .chunks_exact(s * s * c)
                .zip(dconv.chunks_exact_mut(s * s * c))
                .zip(dpool.chunks_exact(feat))
            {
                for ch in 0..c {
                    for r in 0..ps {
                        for q in 0..ps {
                            let dp = dpool_i[ch * pooled + r * ps + q] * 0.25;
                            for dr in 0..2 {
                                for dq in 0..2 {
                                    let cell = ((2 * r + dr) * s + 2 * q + dq) * c + ch;
                                    if conv_i[cell] > 0.0 {
                                        dconv_i[cell] = dp;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Conv weights: ∂W_conv[C×k²] += δ_convᵀ·P, ∂b_conv =
            // colsum(δ_conv).
            gemm_tn(dconv, patches, &mut g[o_cw..o_cw + c * k2], c, k2, rows);
            col_sum_add(dconv, &mut g[o_cb..o_cb + c], c);
        }
        add_l2(self.l2, theta, &mut loss, grad);
        (loss, correct)
    }

    /// Retained per-sample reference implementation (the pre-batching
    /// path): ground truth for `tests/prop_grad.rs` and the baseline
    /// the `grad` bench measures the GEMM path against.
    pub fn local_grad_naive(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let data = &self.shards[device];
        let (s, c, kk) = (self.side, self.channels, self.ksize);
        let half = kk / 2;
        let ps = s / 2;
        let pooled = ps * ps;
        let k_out = self.classes;
        let (o_cw, o_cb, o_fw, o_fb) = self.offsets();
        let n = data.len();
        grad.fill(0.0);
        let inv_n = 1.0 / n as f64;
        let mut conv = vec![0.0f32; c * s * s]; // pre-ReLU activations
        let mut pool = vec![0.0f32; c * pooled];
        let mut probs = vec![0.0f64; k_out];
        let mut dpool = vec![0.0f32; c * pooled];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..n {
            let x = data.row(i);
            let y = data.labels[i];
            // ---- conv + 2×2 average pool over ReLU ---------------------
            for ch in 0..c {
                let w = &theta[o_cw + ch * kk * kk..o_cw + (ch + 1) * kk * kk];
                let b = theta[o_cb + ch];
                for r in 0..s {
                    for q in 0..s {
                        let mut acc = b;
                        for dr in 0..kk {
                            let rr = r as isize + dr as isize - half as isize;
                            if rr < 0 || rr >= s as isize {
                                continue;
                            }
                            for dq in 0..kk {
                                let qq = q as isize + dq as isize - half as isize;
                                if qq < 0 || qq >= s as isize {
                                    continue;
                                }
                                acc += w[dr * kk + dq] * x[rr as usize * s + qq as usize];
                            }
                        }
                        conv[ch * s * s + r * s + q] = acc;
                    }
                }
            }
            for ch in 0..c {
                for r in 0..ps {
                    for q in 0..ps {
                        let mut acc = 0.0f32;
                        for dr in 0..2 {
                            for dq in 0..2 {
                                acc += conv[ch * s * s + (2 * r + dr) * s + (2 * q + dq)]
                                    .max(0.0);
                            }
                        }
                        pool[ch * pooled + r * ps + q] = acc * 0.25;
                    }
                }
            }
            // ---- dense + softmax ---------------------------------------
            for (o, p) in probs.iter_mut().enumerate() {
                let row = &theta[o_fw + o * c * pooled..o_fw + (o + 1) * c * pooled];
                let mut acc = theta[o_fb + o] as f64;
                for (&wj, &pj) in row.iter().zip(&pool) {
                    acc += wj as f64 * pj as f64;
                }
                *p = acc;
            }
            loss += super::logistic::softmax_f64_row(&mut probs, y, &mut correct);
            // ---- backward ----------------------------------------------
            dpool.fill(0.0);
            for o in 0..k_out {
                let coef = ((probs[o] - if o == y { 1.0 } else { 0.0 }) * inv_n) as f32;
                let row_w = &theta[o_fw + o * c * pooled..o_fw + (o + 1) * c * pooled];
                let grow = &mut grad[o_fw + o * c * pooled..o_fw + (o + 1) * c * pooled];
                for j in 0..c * pooled {
                    grow[j] += coef * pool[j];
                    dpool[j] += coef * row_w[j];
                }
                grad[o_fb + o] += coef;
            }
            // Through avg-pool and ReLU into conv weights.
            for ch in 0..c {
                let mut gb = 0.0f32;
                for r in 0..ps {
                    for q in 0..ps {
                        let dp = dpool[ch * pooled + r * ps + q] * 0.25;
                        if dp == 0.0 {
                            continue;
                        }
                        for dr in 0..2 {
                            for dq in 0..2 {
                                let rr = 2 * r + dr;
                                let qq = 2 * q + dq;
                                // ReLU gate.
                                if conv[ch * s * s + rr * s + qq] <= 0.0 {
                                    continue;
                                }
                                gb += dp;
                                let gw = &mut grad[o_cw + ch * kk * kk..o_cw + (ch + 1) * kk * kk];
                                for kr in 0..kk {
                                    let ir = rr as isize + kr as isize - half as isize;
                                    if ir < 0 || ir >= s as isize {
                                        continue;
                                    }
                                    for kq in 0..kk {
                                        let iq = qq as isize + kq as isize - half as isize;
                                        if iq < 0 || iq >= s as isize {
                                            continue;
                                        }
                                        gw[kr * kk + kq] += dp * x[ir as usize * s + iq as usize];
                                    }
                                }
                            }
                        }
                    }
                }
                grad[o_cb + ch] += gb;
            }
        }
        loss *= inv_n;
        add_l2(self.l2, theta, &mut loss, Some(grad));
        loss
    }
}

impl GradientSource for CnnProblem {
    fn dim(&self) -> usize {
        let (c, k2, k) = (self.channels, self.ksize * self.ksize, self.classes);
        c * k2 + c + k * c * self.pooled() + k
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn make_scratch(&self) -> GradScratch {
        let n_max = self.shards.iter().map(|s| s.len()).max().unwrap_or(0);
        let (s, c) = (self.side, self.channels);
        let feat = c * self.pooled();
        let mut ws = GradScratch::default();
        ws.conv.reserve(n_max * s * s * c);
        ws.dconv.reserve(n_max * s * s * c);
        ws.hidden.reserve(n_max * feat);
        ws.dhidden.reserve(n_max * feat);
        ws.logits.reserve(n_max * self.classes);
        ws.probs.reserve(self.classes);
        ws
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        scratch: &mut GradScratch,
    ) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let patches = &self.shard_patches[device];
        self.loss_grad_on(&self.shards[device], patches, theta, Some(grad), scratch).0
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let mut scratch = self.make_scratch();
        let (loss, correct) =
            self.loss_grad_on(&self.test, &self.test_patches, theta, None, &mut scratch);
        EvalMetrics {
            loss,
            accuracy: Some(correct as f64 / self.test.len() as f64),
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0xC33);
        let (o_cw, _o_cb, o_fw, o_fb) = self.offsets();
        let mut theta = vec![0.0f32; self.dim()];
        let s_conv = 1.0 / (self.ksize as f32);
        for t in theta[o_cw..o_cw + self.channels * self.ksize * self.ksize].iter_mut() {
            *t = rng.gaussian_f32(0.0, s_conv);
        }
        let fan_in = (self.channels * self.pooled()) as f32;
        let s_fc = 1.0 / fan_in.sqrt();
        for t in theta[o_fw..o_fb].iter_mut() {
            *t = rng.gaussian_f32(0.0, s_fc);
        }
        theta
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[
            ("conv_w", vec![self.channels, self.ksize, self.ksize]),
            ("conv_b", vec![self.channels]),
            ("fc_w", vec![self.classes, self.channels * self.pooled()]),
            ("fc_b", vec![self.classes]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synth::{train_test_split, MixtureSpec};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> CnnProblem {
        let spec = MixtureSpec {
            num_classes: 3,
            dim: 36, // 6×6 images
            num_samples: 240,
            separation: 1.0,
            noise: 0.8,
            seed: 99,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let parts = iid_partition(train.len(), 3, &mut rng);
        let shards = parts.iter().map(|p| train.subset(p)).collect();
        CnnProblem::new(shards, test, 4, 3, 1e-4)
    }

    #[test]
    fn dims_and_layout() {
        let p = small_problem();
        // conv: 4·9 + 4 = 40; fc: 3·(4·9) + 3 = 111. total 151.
        assert_eq!(p.dim(), 151);
        assert_eq!(p.layout().dim(), 151);
        assert_eq!(p.layout().entries.len(), 4);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let theta = p.init_theta(5);
        // Coordinates across all four blocks.
        check_gradient(&p, 0, &theta, &[0, 17, 39, 41, 70, 150], 5e-2);
    }

    #[test]
    fn batched_matches_naive_reference() {
        let p = small_problem();
        let theta = p.init_theta(13);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut g_ref = vec![0.0f32; p.dim()];
        for dev in 0..p.num_devices() {
            let loss = p.local_grad(dev, &theta, &mut g, &mut ws);
            let loss_ref = p.local_grad_naive(dev, &theta, &mut g_ref);
            assert!((loss - loss_ref).abs() < 1e-5 * loss_ref.abs().max(1.0));
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn training_improves_accuracy() {
        let p = small_problem();
        let mut theta = p.init_theta(6);
        let acc0 = p.eval(&theta).accuracy.unwrap();
        let m = p.num_devices();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..150 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-0.5, &step, &mut theta);
        }
        let acc = p.eval(&theta).accuracy.unwrap();
        assert!(acc > acc0.max(0.5), "CNN failed to train: {acc0} -> {acc}");
    }

    #[test]
    fn relu_gate_blocks_gradient() {
        // A conv channel whose bias is very negative never activates,
        // so its weight gradient is exactly the L2 term.
        let p = small_problem();
        let mut theta = p.init_theta(7);
        let (_o_cw, o_cb, _, _) = p.offsets();
        theta[o_cb] = -1e6; // channel 0 dead
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        p.local_grad(0, &theta, &mut g, &mut ws);
        for j in 0..p.ksize * p.ksize {
            let expect = p.l2 * theta[j];
            assert!(
                (g[j] - expect).abs() < 1e-9,
                "dead channel leaked gradient at {j}"
            );
        }
    }

    #[test]
    fn hetero_mask_on_cnn_layout() {
        use crate::hetero::CapacityMask;
        let p = small_problem();
        let mask = CapacityMask::from_layout(&p.layout(), 0.5);
        // conv_w leading 2 of 4 channels (rank-3 → leading dim), conv_b
        // 2 of 4, fc rows 2 of 3 × cols 18 of 36, fc_b 2 of 3.
        assert_eq!(mask.support(), 2 * 9 + 2 + 2 * 18 + 2);
    }
}
