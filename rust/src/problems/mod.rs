//! Local objectives `f_m` computed natively in Rust.
//!
//! The coordinator is generic over a [`GradientSource`]: anything that
//! can produce per-device full-batch gradients of a flat parameter
//! vector. Two families implement it:
//!
//! * the pure-Rust problems in this module (quadratic with known
//!   PL/L constants, multinomial logistic regression, a one-hidden-layer
//!   MLP, and a bigram softmax language model) — fast enough to run the
//!   M = 100-device, many-round sweeps behind every table and figure;
//! * [`crate::runtime::HloGradientSource`] — neural models (MLP / CNN /
//!   transformer) authored in JAX (L2), AOT-lowered to HLO and executed
//!   through PJRT from the Rust hot path.
//!
//! The paper's FL setting (Section II) uses *full local gradients* per
//! round — `∇f_m(θᵏ)` over the device's whole shard — which all of these
//! implement (deterministic, so runs are bit-reproducible).
//!
//! **Compute layer.** The native problems compute forward/backward
//! passes as batched matrix products over the whole device shard
//! (`util::gemm`, fixed accumulation order ⇒ bit-reproducible at any
//! thread count) into a caller-owned [`GradScratch`] workspace, so
//! steady-state rounds allocate nothing. Each problem retains a
//! `local_grad_naive` per-sample reference implementation that the
//! property tests (`tests/prop_grad.rs`) and the `grad` bench validate
//! and measure the batched path against. See DESIGN.md §Compute.

pub mod cnn;
pub mod logistic;
pub mod mlp;
pub mod quadratic;
pub mod softmax_lm;

/// Flat-parameter layout metadata: where each named tensor lives inside
/// the flat `θ` vector. The HeteroFL capacity masks (`crate::hetero`) are
/// computed from this.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamLayout {
    /// Tensors in flat-vector order.
    pub entries: Vec<LayerSpec>,
}

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    /// Tensor name (e.g. `w1`, `b1`).
    pub name: String,
    /// Tensor shape; `[rows, cols]` for matrices, `[n]` for vectors.
    pub shape: Vec<usize>,
    /// Offset into the flat vector.
    pub offset: usize,
}

impl LayerSpec {
    /// Element count of this tensor.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

impl ParamLayout {
    /// Total parameter count; equals `GradientSource::dim()`.
    pub fn dim(&self) -> usize {
        self.entries
            .last()
            .map(|e| e.offset + e.numel())
            .unwrap_or(0)
    }

    /// Build a layout from `(name, shape)` pairs laid out contiguously.
    pub fn contiguous(specs: &[(&str, Vec<usize>)]) -> Self {
        let mut entries = Vec::with_capacity(specs.len());
        let mut offset = 0;
        for (name, shape) in specs {
            let numel: usize = shape.iter().product();
            entries.push(LayerSpec {
                name: name.to_string(),
                shape: shape.clone(),
                offset,
            });
            offset += numel;
        }
        ParamLayout { entries }
    }
}

/// Evaluation metrics on held-out data.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalMetrics {
    /// Held-out mean loss.
    pub loss: f64,
    /// Classification accuracy in `[0, 1]` (classification tasks).
    pub accuracy: Option<f64>,
    /// `exp(loss)` (language-modelling tasks).
    pub perplexity: Option<f64>,
}

/// Reusable per-device workspace for [`GradientSource::local_grad`].
///
/// Problems size the buffers they need on first use (capacity is
/// retained across calls, so steady-state rounds allocate nothing) and
/// may pre-reserve in [`GradientSource::make_scratch`]. Buffer roles
/// are by convention — a problem may repurpose any field — but the
/// names match the batched passes in this module.
#[derive(Clone, Debug, Default)]
pub struct GradScratch {
    /// Output-layer batch matrix (`n × K`): logits on the forward pass,
    /// then `∂loss/∂logits` in place on the backward pass.
    pub logits: Vec<f32>,
    /// Hidden/feature activations (`n × H`; pooled features for the
    /// CNN).
    pub hidden: Vec<f32>,
    /// Backpropagated hidden deltas (`n × H`; pooling deltas for the
    /// CNN).
    pub dhidden: Vec<f32>,
    /// Pre-activation convolution feature map (`n·S² × C`, CNN only).
    pub conv: Vec<f32>,
    /// Convolution deltas (`n·S² × C`, CNN only).
    pub dconv: Vec<f32>,
    /// Per-row f64 staging (softmax probabilities).
    pub probs: Vec<f64>,
}

/// Size `buf` to exactly `len` zeroed elements, reusing its capacity.
#[inline]
pub fn zeroed(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(len, 0.0);
    &mut buf[..]
}

/// Add the `λ/2 ‖θ‖²` regularization term to `loss` (f64 accumulation)
/// and `λθ` to `grad` — shared tail of every regularized problem.
pub(crate) fn add_l2(l2: f32, theta: &[f32], loss: &mut f64, grad: Option<&mut [f32]>) {
    if l2 <= 0.0 {
        return;
    }
    let reg: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
    *loss += 0.5 * l2 as f64 * reg;
    if let Some(g) = grad {
        for (gi, &ti) in g.iter_mut().zip(theta) {
            *gi += l2 * ti;
        }
    }
}

/// Overwrite one logit row with the staged output deltas
/// `(softmax − onehot(y)) / n` — the f32 operand of the backward
/// weight-gradient GEMMs.
#[inline]
pub(crate) fn stage_output_deltas(row: &mut [f32], probs: &[f64], y: usize, inv_n: f64) {
    for (c, (slot, &p)) in row.iter_mut().zip(probs).enumerate() {
        let t = if c == y { 1.0 } else { 0.0 };
        *slot = ((p - t) * inv_n) as f32;
    }
}

/// A federated optimization problem: per-device local objectives over a
/// shared flat parameter vector.
pub trait GradientSource: Send + Sync {
    /// Model dimension `d`.
    fn dim(&self) -> usize;

    /// Number of devices `M`.
    fn num_devices(&self) -> usize;

    /// Build a gradient workspace for this problem, pre-reserved for
    /// its largest device shard where the problem knows the sizes.
    /// Callers keep one per worker/device and pass it to every
    /// [`GradientSource::local_grad`] call.
    fn make_scratch(&self) -> GradScratch {
        GradScratch::default()
    }

    /// Full-batch local gradient `∇f_m(θ)` written into `grad`
    /// (len `d`); returns the local loss `f_m(θ)`. `scratch` provides
    /// the intermediate buffers (any [`GradScratch`] works; reuse one
    /// to keep steady-state rounds allocation-free). The result is a
    /// pure function of `(device, θ)` — bit-identical across repeated
    /// calls, scratch instances, and engine thread counts.
    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        scratch: &mut GradScratch,
    ) -> f64;

    /// Global objective `f(θ) = (1/M) Σ_m f_m(θ)`.
    ///
    /// Default: averages `local_grad` losses (O(M·d); problems with a
    /// cheaper closed form override this).
    fn global_loss(&self, theta: &[f32]) -> f64 {
        let mut grad = vec![0.0f32; self.dim()];
        let mut scratch = self.make_scratch();
        let m = self.num_devices();
        let mut acc = 0.0;
        for dev in 0..m {
            acc += self.local_grad(dev, theta, &mut grad, &mut scratch);
        }
        acc / m as f64
    }

    /// Held-out evaluation.
    fn eval(&self, theta: &[f32]) -> EvalMetrics;

    /// Initial parameter vector.
    fn init_theta(&self, seed: u64) -> Vec<f32>;

    /// Flat layout (for HeteroFL masks). Default: one anonymous blob.
    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("theta", vec![self.dim()])])
    }
}

/// Numerical gradient check helper used by the problems' own tests:
/// central differences on a few random coordinates.
#[cfg(test)]
pub(crate) fn check_gradient<S: GradientSource>(
    src: &S,
    device: usize,
    theta: &[f32],
    coords: &[usize],
    tol: f64,
) {
    let d = src.dim();
    let mut grad = vec![0.0f32; d];
    let mut ws = src.make_scratch();
    src.local_grad(device, theta, &mut grad, &mut ws);
    let eps = 1e-3f32;
    let mut th = theta.to_vec();
    let mut gbuf = vec![0.0f32; d];
    for &i in coords {
        let orig = th[i];
        th[i] = orig + eps;
        let fp = src.local_grad(device, &th, &mut gbuf, &mut ws);
        th[i] = orig - eps;
        let fm = src.local_grad(device, &th, &mut gbuf, &mut ws);
        th[i] = orig;
        let fd = (fp - fm) / (2.0 * eps as f64);
        let g = grad[i] as f64;
        let denom = fd.abs().max(g.abs()).max(1e-4);
        assert!(
            (fd - g).abs() / denom < tol,
            "coord {i}: analytic {g} vs numeric {fd}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_contiguous_offsets() {
        let l = ParamLayout::contiguous(&[
            ("w1", vec![4, 3]),
            ("b1", vec![4]),
            ("w2", vec![2, 4]),
        ]);
        assert_eq!(l.entries[0].offset, 0);
        assert_eq!(l.entries[1].offset, 12);
        assert_eq!(l.entries[2].offset, 16);
        assert_eq!(l.dim(), 24);
    }

    #[test]
    fn empty_layout() {
        let l = ParamLayout { entries: vec![] };
        assert_eq!(l.dim(), 0);
    }
}
