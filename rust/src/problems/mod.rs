//! Local objectives `f_m` computed natively in Rust.
//!
//! The coordinator is generic over a [`GradientSource`]: anything that
//! can produce per-device full-batch gradients of a flat parameter
//! vector. Two families implement it:
//!
//! * the pure-Rust problems in this module (quadratic with known
//!   PL/L constants, multinomial logistic regression, a one-hidden-layer
//!   MLP, and a bigram softmax language model) — fast enough to run the
//!   M = 100-device, many-round sweeps behind every table and figure;
//! * [`crate::runtime::HloGradientSource`] — neural models (MLP / CNN /
//!   transformer) authored in JAX (L2), AOT-lowered to HLO and executed
//!   through PJRT from the Rust hot path.
//!
//! The paper's FL setting (Section II) uses *full local gradients* per
//! round — `∇f_m(θᵏ)` over the device's whole shard — which all of these
//! implement (deterministic, so runs are bit-reproducible).

pub mod cnn;
pub mod logistic;
pub mod mlp;
pub mod quadratic;
pub mod softmax_lm;

/// Flat-parameter layout metadata: where each named tensor lives inside
/// the flat `θ` vector. The HeteroFL capacity masks (`crate::hetero`) are
/// computed from this.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamLayout {
    pub entries: Vec<LayerSpec>,
}

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    /// Tensor shape; `[rows, cols]` for matrices, `[n]` for vectors.
    pub shape: Vec<usize>,
    /// Offset into the flat vector.
    pub offset: usize,
}

impl LayerSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

impl ParamLayout {
    /// Total parameter count; equals `GradientSource::dim()`.
    pub fn dim(&self) -> usize {
        self.entries
            .last()
            .map(|e| e.offset + e.numel())
            .unwrap_or(0)
    }

    /// Build a layout from `(name, shape)` pairs laid out contiguously.
    pub fn contiguous(specs: &[(&str, Vec<usize>)]) -> Self {
        let mut entries = Vec::with_capacity(specs.len());
        let mut offset = 0;
        for (name, shape) in specs {
            let numel: usize = shape.iter().product();
            entries.push(LayerSpec {
                name: name.to_string(),
                shape: shape.clone(),
                offset,
            });
            offset += numel;
        }
        ParamLayout { entries }
    }
}

/// Evaluation metrics on held-out data.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalMetrics {
    /// Held-out mean loss.
    pub loss: f64,
    /// Classification accuracy in `[0, 1]` (classification tasks).
    pub accuracy: Option<f64>,
    /// `exp(loss)` (language-modelling tasks).
    pub perplexity: Option<f64>,
}

/// A federated optimization problem: per-device local objectives over a
/// shared flat parameter vector.
pub trait GradientSource: Send + Sync {
    /// Model dimension `d`.
    fn dim(&self) -> usize;

    /// Number of devices `M`.
    fn num_devices(&self) -> usize;

    /// Full-batch local gradient `∇f_m(θ)` written into `grad`
    /// (len `d`); returns the local loss `f_m(θ)`.
    fn local_grad(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64;

    /// Global objective `f(θ) = (1/M) Σ_m f_m(θ)`.
    ///
    /// Default: averages `local_grad` losses (O(M·d); problems with a
    /// cheaper closed form override this).
    fn global_loss(&self, theta: &[f32]) -> f64 {
        let mut grad = vec![0.0f32; self.dim()];
        let m = self.num_devices();
        let mut acc = 0.0;
        for dev in 0..m {
            acc += self.local_grad(dev, theta, &mut grad);
        }
        acc / m as f64
    }

    /// Held-out evaluation.
    fn eval(&self, theta: &[f32]) -> EvalMetrics;

    /// Initial parameter vector.
    fn init_theta(&self, seed: u64) -> Vec<f32>;

    /// Flat layout (for HeteroFL masks). Default: one anonymous blob.
    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("theta", vec![self.dim()])])
    }
}

/// Numerical gradient check helper used by the problems' own tests:
/// central differences on a few random coordinates.
#[cfg(test)]
pub(crate) fn check_gradient<S: GradientSource>(
    src: &S,
    device: usize,
    theta: &[f32],
    coords: &[usize],
    tol: f64,
) {
    let d = src.dim();
    let mut grad = vec![0.0f32; d];
    src.local_grad(device, theta, &mut grad);
    let eps = 1e-3f32;
    let mut th = theta.to_vec();
    let mut scratch = vec![0.0f32; d];
    for &i in coords {
        let orig = th[i];
        th[i] = orig + eps;
        let fp = src.local_grad(device, &th, &mut scratch);
        th[i] = orig - eps;
        let fm = src.local_grad(device, &th, &mut scratch);
        th[i] = orig;
        let fd = (fp - fm) / (2.0 * eps as f64);
        let g = grad[i] as f64;
        let denom = fd.abs().max(g.abs()).max(1e-4);
        assert!(
            (fd - g).abs() / denom < tol,
            "coord {i}: analytic {g} vs numeric {fd}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_contiguous_offsets() {
        let l = ParamLayout::contiguous(&[
            ("w1", vec![4, 3]),
            ("b1", vec![4]),
            ("w2", vec![2, 4]),
        ]);
        assert_eq!(l.entries[0].offset, 0);
        assert_eq!(l.entries[1].offset, 12);
        assert_eq!(l.entries[2].offset, 16);
        assert_eq!(l.dim(), 24);
    }

    #[test]
    fn empty_layout() {
        let l = ParamLayout { entries: vec![] };
        assert_eq!(l.dim(), 0);
    }
}
