//! Federated multinomial logistic regression on a partitioned
//! classification dataset (the CIFAR-stand-in convex workload).
//!
//! Parameters: `W ∈ ℝ^{K×D}` then `b ∈ ℝ^K`, flattened row-major;
//! `d = K(D+1)`. Loss: mean softmax cross-entropy over the device shard
//! plus `λ/2 ‖θ‖²` L2 regularization (making the problem strongly convex
//! — useful for convergence tests).
//!
//! The gradient is computed batched over the whole shard: one
//! `logits[n×K] = X·Wᵀ + 1bᵀ` GEMM forward, per-row f64 softmax, and
//! one `∂W[K×D] = δᵀ·X` GEMM backward ([`crate::util::gemm`]). The
//! pre-batching per-sample path is retained as
//! [`LogisticProblem::local_grad_naive`] for property tests and the
//! `grad` bench.

use super::{
    add_l2, stage_output_deltas, zeroed, EvalMetrics, GradScratch, GradientSource, ParamLayout,
};
use crate::data::ClassificationDataset;
use crate::util::gemm::{col_sum_add, gemm_nt, gemm_tn};
use crate::util::rng::Xoshiro256pp;

/// See module docs.
pub struct LogisticProblem {
    /// Per-device training shards.
    shards: Vec<ClassificationDataset>,
    /// Held-out evaluation data.
    test: ClassificationDataset,
    dim_in: usize,
    classes: usize,
    l2: f32,
}

impl LogisticProblem {
    /// Multinomial logistic regression over the shards' feature space
    /// with `l2` weight decay.
    pub fn new(
        shards: Vec<ClassificationDataset>,
        test: ClassificationDataset,
        l2: f32,
    ) -> Self {
        assert!(!shards.is_empty());
        let dim_in = shards[0].dim;
        let classes = shards[0].num_classes;
        for s in &shards {
            assert_eq!(s.dim, dim_in);
            assert_eq!(s.num_classes, classes);
            assert!(!s.is_empty(), "empty device shard");
        }
        assert_eq!(test.dim, dim_in);
        Self {
            shards,
            test,
            dim_in,
            classes,
            l2,
        }
    }

    #[inline]
    fn w_len(&self) -> usize {
        self.classes * self.dim_in
    }

    /// Batched loss/gradient over one dataset; returns
    /// `(mean loss, correct predictions)`.
    fn loss_grad_on(
        &self,
        data: &ClassificationDataset,
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
        scratch: &mut GradScratch,
    ) -> (f64, usize) {
        let (k, dm) = (self.classes, self.dim_in);
        let n = data.len();
        let w = &theta[..k * dm];
        let b = &theta[k * dm..];
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }

        // Forward: logits[n×K] = X·Wᵀ + 1·bᵀ, one GEMM over the shard.
        let logits = zeroed(&mut scratch.logits, n * k);
        for row in logits.chunks_exact_mut(k) {
            row.copy_from_slice(b);
        }
        gemm_nt(&data.features, w, logits, n, k, dm);

        // Per-row f64 softmax: loss, accuracy, and (in place) the
        // backward staging δ = (softmax − onehot)/n.
        scratch.probs.clear();
        scratch.probs.resize(k, 0.0);
        let probs = &mut scratch.probs[..];
        let want_grad = grad.is_some();
        let inv_n = 1.0 / n as f64;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for (row, &y) in logits.chunks_exact_mut(k).zip(&data.labels) {
            loss += softmax_row(row, y, probs, &mut correct);
            if want_grad {
                stage_output_deltas(row, probs, y, inv_n);
            }
        }
        loss *= inv_n;

        // Backward: ∂W[K×D] += δᵀ·X, ∂b = column sums of δ.
        if let Some(g) = grad.as_deref_mut() {
            let (gw, gb) = g.split_at_mut(k * dm);
            gemm_tn(logits, &data.features, gw, k, dm, n);
            col_sum_add(logits, gb, k);
        }
        add_l2(self.l2, theta, &mut loss, grad);
        (loss, correct)
    }

    /// Retained per-sample reference implementation (the pre-batching
    /// path): ground truth for `tests/prop_grad.rs` and the baseline
    /// the `grad` bench measures the GEMM path against.
    pub fn local_grad_naive(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let data = &self.shards[device];
        let (k, dm) = (self.classes, self.dim_in);
        let w = &theta[..k * dm];
        let b = &theta[k * dm..];
        let n = data.len();
        let mut probs = vec![0.0f64; k];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        grad.fill(0.0);
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            let x = data.row(i);
            let y = data.labels[i];
            // Per-sample forward in f64.
            for (c, p) in probs.iter_mut().enumerate() {
                let row = &w[c * dm..(c + 1) * dm];
                let mut acc = b[c] as f64;
                for (&wj, &xj) in row.iter().zip(x) {
                    acc += wj as f64 * xj as f64;
                }
                *p = acc;
            }
            loss += softmax_f64_row(&mut probs, y, &mut correct);
            for c in 0..k {
                let coef = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
                let row = &mut grad[c * dm..(c + 1) * dm];
                let cf = coef as f32;
                for (gj, &xj) in row.iter_mut().zip(x) {
                    *gj += cf * xj;
                }
                grad[k * dm + c] += cf;
            }
        }
        loss *= inv_n;
        add_l2(self.l2, theta, &mut loss, Some(grad));
        loss
    }
}

/// Softmax one f32 logit row in f64: fills `probs`, bumps `correct` on
/// an argmax hit, and returns the sample's cross-entropy loss. Shared
/// by every native softmax-output problem.
pub(crate) fn softmax_row(row: &[f32], y: usize, probs: &mut [f64], correct: &mut usize) -> f64 {
    for (p, &x) in probs.iter_mut().zip(row) {
        *p = x as f64;
    }
    softmax_f64_row(probs, y, correct)
}

/// Softmax an f64 logit row in place (same numerics as the per-sample
/// path: shift by max, exponentiate, normalize).
pub(crate) fn softmax_f64_row(probs: &mut [f64], y: usize, correct: &mut usize) -> f64 {
    let maxl = probs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0f64;
    for p in probs.iter_mut() {
        *p = (*p - maxl).exp();
        z += *p;
    }
    let mut best = 0usize;
    let mut bestp = f64::NEG_INFINITY;
    for (c, p) in probs.iter_mut().enumerate() {
        *p /= z;
        if *p >= bestp {
            bestp = *p;
            best = c;
        }
    }
    if best == y {
        *correct += 1;
    }
    -(probs[y].max(1e-300).ln())
}

impl GradientSource for LogisticProblem {
    fn dim(&self) -> usize {
        self.classes * (self.dim_in + 1)
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn make_scratch(&self) -> GradScratch {
        let n_max = self.shards.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut ws = GradScratch::default();
        ws.logits.reserve(n_max * self.classes);
        ws.probs.reserve(self.classes);
        ws
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        scratch: &mut GradScratch,
    ) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shards[device], theta, Some(grad), scratch).0
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let mut scratch = self.make_scratch();
        let (loss, correct) = self.loss_grad_on(&self.test, theta, None, &mut scratch);
        EvalMetrics {
            loss,
            accuracy: Some(correct as f64 / self.test.len() as f64),
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0x1091);
        let scale = 1.0 / (self.dim_in as f32).sqrt();
        let mut theta = vec![0.0f32; self.dim()];
        for t in theta[..self.w_len()].iter_mut() {
            *t = rng.gaussian_f32(0.0, scale);
        }
        theta
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[
            ("w", vec![self.classes, self.dim_in]),
            ("b", vec![self.classes]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synth::{train_test_split, MixtureSpec};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> LogisticProblem {
        let spec = MixtureSpec {
            num_classes: 4,
            dim: 8,
            num_samples: 400,
            separation: 1.5,
            noise: 1.0,
            seed: 77,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let parts = iid_partition(train.len(), 4, &mut rng);
        let shards = parts.iter().map(|p| train.subset(p)).collect();
        LogisticProblem::new(shards, test, 1e-3)
    }

    #[test]
    fn dims() {
        let p = small_problem();
        assert_eq!(p.dim(), 4 * 9);
        assert_eq!(p.num_devices(), 4);
        assert_eq!(p.layout().dim(), p.dim());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let theta = p.init_theta(3);
        check_gradient(&p, 0, &theta, &[0, 7, 17, 35], 2e-2);
    }

    #[test]
    fn batched_matches_naive_reference() {
        let p = small_problem();
        let theta = p.init_theta(11);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut g_ref = vec![0.0f32; p.dim()];
        for dev in 0..p.num_devices() {
            let loss = p.local_grad(dev, &theta, &mut g, &mut ws);
            let loss_ref = p.local_grad_naive(dev, &theta, &mut g_ref);
            assert!((loss - loss_ref).abs() < 1e-6 * loss_ref.abs().max(1.0));
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn gradient_descent_learns() {
        let p = small_problem();
        let mut theta = p.init_theta(5);
        let acc0 = p.eval(&theta).accuracy.unwrap();
        let m = p.num_devices();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..150 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-0.5, &step, &mut theta);
        }
        let acc = p.eval(&theta).accuracy.unwrap();
        assert!(
            acc > acc0 + 0.2 && acc > 0.6,
            "training failed: {acc0} -> {acc}"
        );
    }

    #[test]
    fn loss_decreases_with_descent_step() {
        let p = small_problem();
        let theta = p.init_theta(7);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let l0 = p.local_grad(1, &theta, &mut g, &mut ws);
        let mut theta2 = theta.clone();
        axpy(-0.1, &g, &mut theta2);
        let mut g2 = vec![0.0f32; p.dim()];
        let l1 = p.local_grad(1, &theta2, &mut g2, &mut ws);
        assert!(l1 < l0);
    }

    #[test]
    fn eval_reports_accuracy() {
        let p = small_problem();
        let theta = p.init_theta(9);
        let ev = p.eval(&theta);
        let acc = ev.accuracy.unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(ev.perplexity.is_none());
        assert!(ev.loss > 0.0);
    }
}
