//! Federated multinomial logistic regression on a partitioned
//! classification dataset (the CIFAR-stand-in convex workload).
//!
//! Parameters: `W ∈ ℝ^{K×D}` then `b ∈ ℝ^K`, flattened row-major;
//! `d = K(D+1)`. Loss: mean softmax cross-entropy over the device shard
//! plus `λ/2 ‖θ‖²` L2 regularization (making the problem strongly convex
//! — useful for convergence tests).

use super::{EvalMetrics, GradientSource, ParamLayout};
use crate::data::ClassificationDataset;
use crate::util::rng::Xoshiro256pp;

/// See module docs.
pub struct LogisticProblem {
    /// Per-device training shards.
    shards: Vec<ClassificationDataset>,
    /// Held-out evaluation data.
    test: ClassificationDataset,
    dim_in: usize,
    classes: usize,
    l2: f32,
}

impl LogisticProblem {
    pub fn new(
        shards: Vec<ClassificationDataset>,
        test: ClassificationDataset,
        l2: f32,
    ) -> Self {
        assert!(!shards.is_empty());
        let dim_in = shards[0].dim;
        let classes = shards[0].num_classes;
        for s in &shards {
            assert_eq!(s.dim, dim_in);
            assert_eq!(s.num_classes, classes);
            assert!(!s.is_empty(), "empty device shard");
        }
        assert_eq!(test.dim, dim_in);
        Self {
            shards,
            test,
            dim_in,
            classes,
            l2,
        }
    }

    #[inline]
    fn w_len(&self) -> usize {
        self.classes * self.dim_in
    }

    /// Forward pass logits for one sample.
    #[inline]
    fn logits(&self, theta: &[f32], x: &[f32], out: &mut [f64]) {
        let (k, dm) = (self.classes, self.dim_in);
        let w = &theta[..k * dm];
        let b = &theta[k * dm..];
        for c in 0..k {
            let row = &w[c * dm..(c + 1) * dm];
            let mut acc = b[c] as f64;
            for j in 0..dm {
                acc += row[j] as f64 * x[j] as f64;
            }
            out[c] = acc;
        }
    }

    /// Softmax in place; returns logsumexp.
    fn softmax(logits: &mut [f64]) -> f64 {
        let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0;
        for l in logits.iter_mut() {
            *l = (*l - maxl).exp();
            z += *l;
        }
        for l in logits.iter_mut() {
            *l /= z;
        }
        maxl + z.ln()
    }

    fn loss_grad_on(
        &self,
        data: &ClassificationDataset,
        theta: &[f32],
        grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (k, dm) = (self.classes, self.dim_in);
        let n = data.len();
        let mut probs = vec![0.0f64; k];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut grad = grad;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        for i in 0..n {
            let x = data.row(i);
            let y = data.labels[i];
            self.logits(theta, x, &mut probs);
            let lse = Self::softmax(&mut probs);
            // loss_i = lse − logit_y; probs now holds softmax.
            // Recover logit_y from prob: log p_y = logit_y − lse.
            let py = probs[y].max(1e-300);
            loss += -(py.ln());
            let _ = lse;
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
            if let Some(g) = grad.as_deref_mut() {
                let scale = 1.0 / n as f64;
                for c in 0..k {
                    let coef = (probs[c] - if c == y { 1.0 } else { 0.0 }) * scale;
                    let row = &mut g[c * dm..(c + 1) * dm];
                    let cf = coef as f32;
                    for j in 0..dm {
                        row[j] += cf * x[j];
                    }
                    g[k * dm + c] += cf;
                }
            }
        }
        loss /= n as f64;
        // L2 regularization.
        if self.l2 > 0.0 {
            let reg: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
            loss += 0.5 * self.l2 as f64 * reg;
            if let Some(g) = grad {
                for (gi, &ti) in g.iter_mut().zip(theta) {
                    *gi += self.l2 * ti;
                }
            }
        }
        (loss, correct)
    }
}

impl GradientSource for LogisticProblem {
    fn dim(&self) -> usize {
        self.classes * (self.dim_in + 1)
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn local_grad(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shards[device], theta, Some(grad)).0
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let (loss, correct) = self.loss_grad_on(&self.test, theta, None);
        EvalMetrics {
            loss,
            accuracy: Some(correct as f64 / self.test.len() as f64),
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0x1091);
        let scale = 1.0 / (self.dim_in as f32).sqrt();
        let mut theta = vec![0.0f32; self.dim()];
        for t in theta[..self.w_len()].iter_mut() {
            *t = rng.gaussian_f32(0.0, scale);
        }
        theta
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[
            ("w", vec![self.classes, self.dim_in]),
            ("b", vec![self.classes]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synth::{train_test_split, MixtureSpec};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> LogisticProblem {
        let spec = MixtureSpec {
            num_classes: 4,
            dim: 8,
            num_samples: 400,
            separation: 1.5,
            noise: 1.0,
            seed: 77,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let parts = iid_partition(train.len(), 4, &mut rng);
        let shards = parts.iter().map(|p| train.subset(p)).collect();
        LogisticProblem::new(shards, test, 1e-3)
    }

    #[test]
    fn dims() {
        let p = small_problem();
        assert_eq!(p.dim(), 4 * 9);
        assert_eq!(p.num_devices(), 4);
        assert_eq!(p.layout().dim(), p.dim());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let theta = p.init_theta(3);
        check_gradient(&p, 0, &theta, &[0, 7, 17, 35], 2e-2);
    }

    #[test]
    fn gradient_descent_learns() {
        let p = small_problem();
        let mut theta = p.init_theta(5);
        let acc0 = p.eval(&theta).accuracy.unwrap();
        let m = p.num_devices();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..150 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-0.5, &step, &mut theta);
        }
        let acc = p.eval(&theta).accuracy.unwrap();
        assert!(
            acc > acc0 + 0.2 && acc > 0.6,
            "training failed: {acc0} -> {acc}"
        );
    }

    #[test]
    fn loss_decreases_with_descent_step() {
        let p = small_problem();
        let theta = p.init_theta(7);
        let mut g = vec![0.0f32; p.dim()];
        let l0 = p.local_grad(1, &theta, &mut g);
        let mut theta2 = theta.clone();
        axpy(-0.1, &g, &mut theta2);
        let mut g2 = vec![0.0f32; p.dim()];
        let l1 = p.local_grad(1, &theta2, &mut g2);
        assert!(l1 < l0);
    }

    #[test]
    fn eval_reports_accuracy() {
        let p = small_problem();
        let theta = p.init_theta(9);
        let ev = p.eval(&theta);
        let acc = ev.accuracy.unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(ev.perplexity.is_none());
        assert!(ev.loss > 0.0);
    }
}
