//! Quadratic federated problem with known smoothness and PL constants.
//!
//! Device `m` holds `f_m(θ) = ½ (θ − c_m)ᵀ diag(a_m) (θ − c_m)` with
//! `a_m > 0`. The global objective is a strongly-convex quadratic whose
//! exact minimizer, optimum value, smoothness constant `L` and PL
//! constant `μ` are all available in closed form — this is the substrate
//! for the theory tests validating Corollary 1, Theorem 3 and the
//! hyperparameter condition `L/2 − 1/(2α) + βγ/α ≤ 0`.

use super::{EvalMetrics, GradScratch, GradientSource, ParamLayout};
use crate::util::rng::Xoshiro256pp;

/// See module docs.
#[derive(Clone, Debug)]
pub struct QuadraticProblem {
    dim: usize,
    m: usize,
    /// `m × d` diagonal curvatures.
    a: Vec<f32>,
    /// `m × d` per-device centers.
    c: Vec<f32>,
}

impl QuadraticProblem {
    /// Random instance: curvatures log-uniform in `[a_min, a_max]`,
    /// centers Gaussian with per-device offsets (heterogeneity ~ Non-IID
    /// spread of local optima).
    pub fn new(dim: usize, m: usize, a_min: f32, a_max: f32, spread: f32, seed: u64) -> Self {
        assert!(a_min > 0.0 && a_max >= a_min);
        let mut rng = Xoshiro256pp::stream(seed, 0x9AAD);
        let mut a = Vec::with_capacity(m * dim);
        let mut c = Vec::with_capacity(m * dim);
        let log_lo = (a_min as f64).ln();
        let log_hi = (a_max as f64).ln();
        for _ in 0..m {
            let dev_offset: f32 = rng.gaussian_f32(0.0, spread);
            for _ in 0..dim {
                a.push(rng.uniform(log_lo, log_hi).exp() as f32);
                c.push(rng.gaussian_f32(dev_offset, 1.0));
            }
        }
        Self { dim, m, a, c }
    }

    /// Variant where every device shares one center: `θ* = c` exactly
    /// and `f* = 0` (used by tests that need the loss to vanish, e.g.
    /// the AdaQuantFL level-growth pathology).
    pub fn shared_center(dim: usize, m: usize, a_min: f32, a_max: f32, seed: u64) -> Self {
        let mut p = Self::new(dim, m, a_min, a_max, 0.0, seed);
        let first = p.c[..dim].to_vec();
        for dev in 1..m {
            p.c[dev * dim..(dev + 1) * dim].copy_from_slice(&first);
        }
        p
    }

    fn a_row(&self, dev: usize) -> &[f32] {
        &self.a[dev * self.dim..(dev + 1) * self.dim]
    }

    fn c_row(&self, dev: usize) -> &[f32] {
        &self.c[dev * self.dim..(dev + 1) * self.dim]
    }

    /// Average curvature per coordinate: `ā_i = (1/M) Σ_m a_m[i]`.
    fn avg_curvature(&self) -> Vec<f64> {
        let mut avg = vec![0.0f64; self.dim];
        for dev in 0..self.m {
            for (i, &x) in self.a_row(dev).iter().enumerate() {
                avg[i] += x as f64;
            }
        }
        for x in &mut avg {
            *x /= self.m as f64;
        }
        avg
    }

    /// Global smoothness constant `L = max_i ā_i`.
    pub fn smoothness(&self) -> f64 {
        self.avg_curvature().into_iter().fold(0.0, f64::max)
    }

    /// PL constant `μ = min_i ā_i` (for quadratics PL = strong
    /// convexity).
    pub fn pl_constant(&self) -> f64 {
        self.avg_curvature().into_iter().fold(f64::INFINITY, f64::min)
    }

    /// Exact global minimizer: `θ*_i = Σ_m a_m[i] c_m[i] / Σ_m a_m[i]`.
    pub fn optimum(&self) -> Vec<f32> {
        let mut num = vec![0.0f64; self.dim];
        let mut den = vec![0.0f64; self.dim];
        for dev in 0..self.m {
            let a = self.a_row(dev);
            let c = self.c_row(dev);
            for i in 0..self.dim {
                num[i] += a[i] as f64 * c[i] as f64;
                den[i] += a[i] as f64;
            }
        }
        (0..self.dim).map(|i| (num[i] / den[i]) as f32).collect()
    }

    /// Optimal objective value `f(θ*)`.
    pub fn optimum_value(&self) -> f64 {
        let theta = self.optimum();
        self.global_loss(&theta)
    }
}

impl GradientSource for QuadraticProblem {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_devices(&self) -> usize {
        self.m
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        _scratch: &mut GradScratch,
    ) -> f64 {
        assert_eq!(theta.len(), self.dim);
        assert_eq!(grad.len(), self.dim);
        let a = self.a_row(device);
        let c = self.c_row(device);
        let mut loss = 0.0f64;
        for i in 0..self.dim {
            let diff = theta[i] - c[i];
            grad[i] = a[i] * diff;
            loss += 0.5 * a[i] as f64 * diff as f64 * diff as f64;
        }
        loss
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        EvalMetrics {
            loss: self.global_loss(theta),
            accuracy: None,
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0x717A);
        (0..self.dim).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("theta", vec![self.dim])])
    }
}

/// A quadratic population generated *on the fly*: device `m`'s
/// curvatures/center are regenerated from an id-keyed RNG stream inside
/// every [`GradientSource::local_grad`] call, so the problem costs O(1)
/// memory regardless of the device count — the substrate for the
/// million-device virtualized runs (DESIGN.md §Population).
///
/// Not bit-compatible with [`QuadraticProblem`] at the same seed: the
/// dense constructor draws all devices from one sequential stream whose
/// Box–Muller pair cache spans device boundaries, which an id-keyed
/// stream cannot reproduce. Virtualization equivalence tests therefore
/// compare lazy vs eager *engines over the same problem instance*, never
/// streamed vs dense problems.
#[derive(Clone, Debug)]
pub struct StreamedQuadratic {
    dim: usize,
    m: usize,
    log_lo: f64,
    log_hi: f64,
    spread: f32,
    seed: u64,
}

/// Devices sampled by [`StreamedQuadratic::eval`]'s global-loss
/// estimate (the exact mean is O(M·d) — unpayable at M = 10⁶ every
/// eval round).
const STREAMED_EVAL_DEVICES: usize = 64;

impl StreamedQuadratic {
    /// Spec-only constructor: O(1) memory and time. Parameters mirror
    /// [`QuadraticProblem::new`].
    pub fn new(dim: usize, m: usize, a_min: f32, a_max: f32, spread: f32, seed: u64) -> Self {
        assert!(a_min > 0.0 && a_max >= a_min);
        Self {
            dim,
            m,
            log_lo: (a_min as f64).ln(),
            log_hi: (a_max as f64).ln(),
            spread,
            seed,
        }
    }

    /// The id-keyed stream device `device`'s parameters are drawn from.
    fn device_rng(&self, device: usize) -> Xoshiro256pp {
        let tag = 0x9AAD ^ (device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Xoshiro256pp::stream(self.seed, tag)
    }
}

impl GradientSource for StreamedQuadratic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_devices(&self) -> usize {
        self.m
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        _scratch: &mut GradScratch,
    ) -> f64 {
        assert!(device < self.m, "device {device} out of range");
        assert_eq!(theta.len(), self.dim);
        assert_eq!(grad.len(), self.dim);
        // Same per-device draw order as the dense constructor: one
        // offset, then (curvature, center) per coordinate.
        let mut rng = self.device_rng(device);
        let dev_offset: f32 = rng.gaussian_f32(0.0, self.spread);
        let mut loss = 0.0f64;
        for i in 0..self.dim {
            let a = rng.uniform(self.log_lo, self.log_hi).exp() as f32;
            let c = rng.gaussian_f32(dev_offset, 1.0);
            let diff = theta[i] - c;
            grad[i] = a * diff;
            loss += 0.5 * a as f64 * diff as f64 * diff as f64;
        }
        loss
    }

    /// Sampled global-loss *estimate*: the mean local loss over the
    /// first `min(M, 64)` devices, not all `M`. Deterministic and
    /// comparable across rounds of one run, but not the exact global
    /// objective — million-device runs report it as a tracking metric
    /// only.
    fn global_loss(&self, theta: &[f32]) -> f64 {
        let n = self.m.min(STREAMED_EVAL_DEVICES);
        if n == 0 {
            return 0.0;
        }
        let mut scratch = self.make_scratch();
        let mut grad = vec![0.0f32; self.dim];
        let mut total = 0.0f64;
        for device in 0..n {
            total += self.local_grad(device, theta, &mut grad, &mut scratch);
        }
        total / n as f64
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        EvalMetrics {
            loss: self.global_loss(theta),
            accuracy: None,
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        // Identical to the dense problem: θ⁰ depends on the run seed
        // only, never on the population size.
        let mut rng = Xoshiro256pp::stream(seed, 0x717A);
        (0..self.dim).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("theta", vec![self.dim])])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn problem() -> QuadraticProblem {
        QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 42)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = problem();
        let theta = p.init_theta(1);
        check_gradient(&p, 3, &theta, &[0, 5, 31], 1e-3);
    }

    #[test]
    fn optimum_has_zero_gradient() {
        let p = problem();
        let theta = p.optimum();
        let mut ws = p.make_scratch();
        let mut total = vec![0.0f32; p.dim()];
        let mut g = vec![0.0f32; p.dim()];
        for dev in 0..p.num_devices() {
            p.local_grad(dev, &theta, &mut g, &mut ws);
            axpy(1.0 / p.num_devices() as f32, &g, &mut total);
        }
        let n = crate::util::vecmath::norm2(&total);
        assert!(n < 1e-4, "grad norm at optimum: {n}");
    }

    #[test]
    fn constants_bracket_curvature() {
        let p = problem();
        let (l, mu) = (p.smoothness(), p.pl_constant());
        assert!(l >= mu);
        assert!(mu > 0.0);
        assert!(l <= 2.0 + 1e-6);
        assert!(mu >= 0.5 - 1e-6);
    }

    #[test]
    fn gradient_descent_converges_at_pl_rate() {
        // f(θ_{k+1}) − f* ≤ (1 − αμ)(f(θ_k) − f*) for gradient descent
        // with α ≤ 1/L — the PL inequality our Theorem-3 test relies on.
        let p = problem();
        let alpha = (1.0 / p.smoothness()) as f32;
        let fstar = p.optimum_value();
        let mut theta = p.init_theta(2);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        let mut prev_gap = p.global_loss(&theta) - fstar;
        let rate = 1.0 - alpha as f64 * p.pl_constant();
        for _ in 0..25 {
            total.fill(0.0);
            for dev in 0..p.num_devices() {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / p.num_devices() as f32, &g, &mut total);
            }
            axpy(-alpha, &total.clone(), &mut theta);
            let gap = p.global_loss(&theta) - fstar;
            // Stop asserting once the gap is inside f32 arithmetic noise
            // (θ, gradients and f* are all computed in f32).
            if prev_gap < 1e-6 {
                break;
            }
            assert!(
                gap <= prev_gap * rate + 1e-9,
                "PL contraction violated: {gap} > {prev_gap} * {rate}"
            );
            prev_gap = gap;
        }
        assert!(prev_gap < 1e-3);
    }

    #[test]
    fn pl_inequality_holds_at_random_points() {
        // ‖∇f(θ)‖² ≥ 2μ (f(θ) − f*) — Assumption 4 exactly.
        let p = problem();
        let mu = p.pl_constant();
        let fstar = p.optimum_value();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for seed in 0..5u64 {
            let theta = p.init_theta(seed);
            total.fill(0.0);
            for dev in 0..p.num_devices() {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / p.num_devices() as f32, &g, &mut total);
            }
            let gsq = crate::util::vecmath::norm2_sq(&total);
            let gap = p.global_loss(&theta) - fstar;
            assert!(gsq + 1e-6 >= 2.0 * mu * gap, "PL violated: {gsq} < {}", 2.0 * mu * gap);
        }
    }

    #[test]
    fn deterministic_construction() {
        let a = QuadraticProblem::new(8, 3, 0.5, 2.0, 0.1, 9);
        let b = QuadraticProblem::new(8, 3, 0.5, 2.0, 0.1, 9);
        assert_eq!(a.a, b.a);
        assert_eq!(a.c, b.c);
    }

    #[test]
    fn streamed_gradient_matches_finite_differences() {
        let p = StreamedQuadratic::new(16, 5, 0.5, 2.0, 0.5, 42);
        let theta = p.init_theta(1);
        check_gradient(&p, 2, &theta, &[0, 7, 15], 1e-3);
    }

    #[test]
    fn streamed_local_grad_is_pure() {
        // Regenerating device parameters per call must be a pure
        // function of (device, θ): two calls agree bitwise, and calls
        // to *other* devices in between change nothing.
        let p = StreamedQuadratic::new(8, 1_000_000, 0.5, 2.0, 0.5, 7);
        let theta = p.init_theta(3);
        let mut ws = p.make_scratch();
        let mut g1 = vec![0.0f32; 8];
        let mut g2 = vec![0.0f32; 8];
        let l1 = p.local_grad(999_999, &theta, &mut g1, &mut ws);
        p.local_grad(123, &theta, &mut g2, &mut ws);
        let l2 = p.local_grad(999_999, &theta, &mut g2, &mut ws);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
    }

    #[test]
    fn streamed_devices_differ_and_eval_is_finite() {
        let p = StreamedQuadratic::new(8, 100, 0.5, 2.0, 0.5, 7);
        let theta = p.init_theta(3);
        let mut ws = p.make_scratch();
        let mut ga = vec![0.0f32; 8];
        let mut gb = vec![0.0f32; 8];
        p.local_grad(0, &theta, &mut ga, &mut ws);
        p.local_grad(1, &theta, &mut gb, &mut ws);
        assert_ne!(ga, gb, "distinct devices should draw distinct data");
        let ev = p.eval(&theta);
        assert!(ev.loss.is_finite() && ev.loss > 0.0);
        // Same init as the dense problem: θ⁰ is population-size-free.
        let dense = QuadraticProblem::new(8, 4, 0.5, 2.0, 0.5, 7);
        assert_eq!(p.init_theta(11), dense.init_theta(11));
    }
}
