//! Bigram softmax language model — the native-Rust WikiText-2 stand-in
//! workload (perplexity rows of Tables II/III).
//!
//! `P(next = c | prev = r) = softmax(W[r])_c` with `W ∈ ℝ^{V×V}` (a
//! learned transition logit table). This is exactly the model family the
//! Markov corpus (`crate::data::text`) is drawn from, so training can in
//! principle reach the corpus' entropy-rate perplexity floor. The
//! transformer LM lives in the JAX/HLO path (`crate::runtime`).
//!
//! The gradient only depends on the data through per-row bigram counts
//! (`∂L/∂W[r,c] = (total_r·p_c − count_{r,c})/n`), which are
//! θ-independent — so the counts are aggregated **once per shard at
//! construction** and every `local_grad` call is a pure dense `O(V²)`
//! pass over the logit table with zero allocation (the seed recounted
//! the shard and allocated `V²` counters on every call). The per-token
//! reference path is retained as
//! [`SoftmaxLmProblem::local_grad_naive`].

use super::{add_l2, EvalMetrics, GradScratch, GradientSource, ParamLayout};
use crate::data::TokenDataset;

/// Per-dataset bigram sufficient statistics.
struct BigramStats {
    /// `V×V` transition counts, row-major by previous token.
    counts: Vec<u32>,
    /// Per-row totals (`Σ_c counts[r,c]`).
    row_totals: Vec<u32>,
    /// Number of bigrams (`tokens − 1`).
    n: usize,
}

impl BigramStats {
    fn build(data: &TokenDataset, vocab: usize) -> Self {
        let mut counts = vec![0u32; vocab * vocab];
        let mut row_totals = vec![0u32; vocab];
        for w in data.tokens.windows(2) {
            counts[w[0] as usize * vocab + w[1] as usize] += 1;
            row_totals[w[0] as usize] += 1;
        }
        Self {
            counts,
            row_totals,
            n: data.len() - 1,
        }
    }
}

/// See module docs.
pub struct SoftmaxLmProblem {
    /// Per-device token shards, retained for the per-token reference
    /// path ([`SoftmaxLmProblem::local_grad_naive`]).
    shards: Vec<TokenDataset>,
    /// Counts for each device shard, aggregated at construction.
    shard_stats: Vec<BigramStats>,
    /// Counts for the held-out stream.
    test_stats: BigramStats,
    vocab: usize,
    l2: f32,
}

impl SoftmaxLmProblem {
    /// Bigram softmax LM over the shards' shared vocabulary with `l2`
    /// weight decay.
    pub fn new(shards: Vec<TokenDataset>, test: TokenDataset, l2: f32) -> Self {
        assert!(!shards.is_empty());
        let vocab = shards[0].vocab;
        for s in &shards {
            assert_eq!(s.vocab, vocab);
            assert!(s.len() >= 2, "shard too short for bigrams");
        }
        assert_eq!(test.vocab, vocab);
        assert!(test.len() >= 2);
        let shard_stats = shards.iter().map(|s| BigramStats::build(s, vocab)).collect();
        let test_stats = BigramStats::build(&test, vocab);
        Self {
            shards,
            shard_stats,
            test_stats,
            vocab,
            l2,
        }
    }

    /// Mean NLL (and optional gradient) from precomputed bigram counts:
    /// a dense `O(V²)` pass over the logit table, row-batched.
    fn loss_grad_on(
        &self,
        stats: &BigramStats,
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
        scratch: &mut GradScratch,
    ) -> f64 {
        let v = self.vocab;
        let n = stats.n;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        scratch.probs.clear();
        scratch.probs.resize(v, 0.0);
        let probs = &mut scratch.probs[..];
        let mut loss = 0.0f64;
        let inv_n = 1.0 / n as f64;
        for r in 0..v {
            let total = stats.row_totals[r];
            if total == 0 {
                continue;
            }
            let logits = &theta[r * v..(r + 1) * v];
            let mut maxl = f64::NEG_INFINITY;
            for &x in logits {
                maxl = maxl.max(x as f64);
            }
            let mut z = 0.0;
            for (p, &x) in probs.iter_mut().zip(logits) {
                *p = ((x as f64) - maxl).exp();
                z += *p;
            }
            let logz = maxl + z.ln();
            for p in probs.iter_mut() {
                *p /= z;
            }
            let crow = &stats.counts[r * v..(r + 1) * v];
            for c in 0..v {
                if crow[c] > 0 {
                    loss += crow[c] as f64 * (logz - theta[r * v + c] as f64);
                }
            }
            if let Some(g) = grad.as_deref_mut() {
                let grow = &mut g[r * v..(r + 1) * v];
                let tf = total as f64;
                for ((slot, &p), &cnt) in grow.iter_mut().zip(probs.iter()).zip(crow) {
                    *slot = ((tf * p - cnt as f64) * inv_n) as f32;
                }
            }
        }
        loss *= inv_n;
        add_l2(self.l2, theta, &mut loss, grad);
        loss
    }

    /// Retained per-token reference implementation (one softmax per
    /// bigram, f64 accumulation): ground truth for `tests/prop_grad.rs`
    /// and the baseline the `grad` bench measures the count-aggregated
    /// path against.
    pub fn local_grad_naive(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let data = &self.shards[device];
        let v = self.vocab;
        let n = data.len() - 1;
        let inv_n = 1.0 / n as f64;
        let mut acc = vec![0.0f64; v * v];
        let mut loss = 0.0f64;
        for w in data.tokens.windows(2) {
            let (r, y) = (w[0] as usize, w[1] as usize);
            let logits = &theta[r * v..(r + 1) * v];
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let z: f64 = logits.iter().map(|&x| ((x as f64) - maxl).exp()).sum();
            loss += maxl + z.ln() - theta[r * v + y] as f64;
            let arow = &mut acc[r * v..(r + 1) * v];
            for (slot, &x) in arow.iter_mut().zip(logits) {
                *slot += ((x as f64) - maxl).exp() / z;
            }
            acc[r * v + y] -= 1.0;
        }
        loss *= inv_n;
        for (g, a) in grad.iter_mut().zip(&acc) {
            *g = (a * inv_n) as f32;
        }
        add_l2(self.l2, theta, &mut loss, Some(grad));
        loss
    }
}

impl GradientSource for SoftmaxLmProblem {
    fn dim(&self) -> usize {
        self.vocab * self.vocab
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn make_scratch(&self) -> GradScratch {
        let mut ws = GradScratch::default();
        ws.probs.reserve(self.vocab);
        ws
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        scratch: &mut GradScratch,
    ) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shard_stats[device], theta, Some(grad), scratch)
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let mut scratch = self.make_scratch();
        let loss = self.loss_grad_on(&self.test_stats, theta, None, &mut scratch);
        EvalMetrics {
            loss,
            accuracy: None,
            perplexity: Some(loss.exp()),
        }
    }

    fn init_theta(&self, _seed: u64) -> Vec<f32> {
        // Zero logits = uniform predictions: perplexity starts at V.
        vec![0.0f32; self.dim()]
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("w", vec![self.vocab, self.vocab])])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::{markov_corpus, shard_corpus, CorpusSpec, MarkovChain};
    use crate::problems::check_gradient;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::vecmath::axpy;

    fn small_problem() -> (SoftmaxLmProblem, CorpusSpec) {
        let spec = CorpusSpec {
            vocab: 16,
            length: 20_000,
            peakedness: 2.0,
            seed: 55,
        };
        let full = markov_corpus(&spec);
        let test = full.slice(0, 4000);
        let train = full.slice(4000, full.len());
        let shards = shard_corpus(&train, 4);
        (SoftmaxLmProblem::new(shards, test, 1e-4), spec)
    }

    #[test]
    fn initial_perplexity_is_vocab() {
        let (p, spec) = small_problem();
        let theta = p.init_theta(0);
        let ev = p.eval(&theta);
        let ppl = ev.perplexity.unwrap();
        assert!((ppl - spec.vocab as f64).abs() < 0.5, "ppl={ppl}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (p, _) = small_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let theta: Vec<f32> = (0..p.dim()).map(|_| rng.gaussian_f32(0.0, 0.3)).collect();
        check_gradient(&p, 2, &theta, &[0, 17, 100, 255], 2e-2);
    }

    #[test]
    fn training_approaches_entropy_floor() {
        let (p, spec) = small_problem();
        let chain = MarkovChain::from_spec(&spec);
        let floor = chain.mean_row_entropy().exp();
        let mut theta = p.init_theta(0);
        let m = p.num_devices();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..300 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-4.0, &step, &mut theta);
        }
        let ppl = p.eval(&theta).perplexity.unwrap();
        assert!(
            ppl < spec.vocab as f64 * 0.6,
            "no learning: ppl={ppl}, vocab={}",
            spec.vocab
        );
        assert!(ppl > floor * 0.8, "below the information floor?!");
        assert!(ppl < floor * 2.0, "far from floor: {ppl} vs {floor}");
    }

    #[test]
    fn aggregated_count_gradient_matches_naive() {
        // The count-aggregated O(V²) gradient must match the retained
        // per-token reference on random θ.
        let (p, _) = small_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let theta: Vec<f32> = (0..p.dim()).map(|_| rng.gaussian_f32(0.0, 0.2)).collect();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let loss = p.local_grad(0, &theta, &mut g, &mut ws);
        let mut g_ref = vec![0.0f32; p.dim()];
        let loss_ref = p.local_grad_naive(0, &theta, &mut g_ref);
        assert!((loss - loss_ref).abs() < 1e-9 * loss_ref.abs().max(1.0));
        for (a, b) in g.iter().zip(&g_ref) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn counts_are_shard_stable() {
        // Precomputed stats must agree with a recount of the shard.
        let (p, _) = small_problem();
        for (shard, stats) in p.shards.iter().zip(&p.shard_stats) {
            let fresh = BigramStats::build(shard, p.vocab);
            assert_eq!(fresh.counts, stats.counts);
            assert_eq!(fresh.row_totals, stats.row_totals);
            assert_eq!(fresh.n, shard.len() - 1);
        }
    }
}
