//! Bigram softmax language model — the native-Rust WikiText-2 stand-in
//! workload (perplexity rows of Tables II/III).
//!
//! `P(next = c | prev = r) = softmax(W[r])_c` with `W ∈ ℝ^{V×V}` (a
//! learned transition logit table). This is exactly the model family the
//! Markov corpus (`crate::data::text`) is drawn from, so training can in
//! principle reach the corpus' entropy-rate perplexity floor. The
//! transformer LM lives in the JAX/HLO path (`crate::runtime`).

use super::{EvalMetrics, GradientSource, ParamLayout};
use crate::data::TokenDataset;

/// See module docs.
pub struct SoftmaxLmProblem {
    shards: Vec<TokenDataset>,
    test: TokenDataset,
    vocab: usize,
    l2: f32,
}

impl SoftmaxLmProblem {
    pub fn new(shards: Vec<TokenDataset>, test: TokenDataset, l2: f32) -> Self {
        assert!(!shards.is_empty());
        let vocab = shards[0].vocab;
        for s in &shards {
            assert_eq!(s.vocab, vocab);
            assert!(s.len() >= 2, "shard too short for bigrams");
        }
        assert_eq!(test.vocab, vocab);
        assert!(test.len() >= 2);
        Self {
            shards,
            test,
            vocab,
            l2,
        }
    }

    /// Mean NLL (and optional gradient) over a token stream's bigrams.
    fn loss_grad_on(
        &self,
        data: &TokenDataset,
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
    ) -> f64 {
        let v = self.vocab;
        let n = data.len() - 1;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        // Count bigrams first: gradient rows only depend on (prev ->
        // distribution of next), so aggregate counts make the pass
        // O(V² + n) instead of O(n·V).
        let mut counts = vec![0u32; v * v];
        let mut row_totals = vec![0u32; v];
        for w in data.tokens.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1;
            row_totals[w[0] as usize] += 1;
        }
        let mut probs = vec![0.0f64; v];
        let mut loss = 0.0f64;
        let inv_n = 1.0 / n as f64;
        for r in 0..v {
            let total = row_totals[r];
            if total == 0 {
                continue;
            }
            let logits = &theta[r * v..(r + 1) * v];
            let mut maxl = f64::NEG_INFINITY;
            for &x in logits {
                maxl = maxl.max(x as f64);
            }
            let mut z = 0.0;
            for (c, &x) in logits.iter().enumerate() {
                probs[c] = ((x as f64) - maxl).exp();
                z += probs[c];
            }
            let logz = maxl + z.ln();
            for p in probs.iter_mut() {
                *p /= z;
            }
            let crow = &counts[r * v..(r + 1) * v];
            for c in 0..v {
                if crow[c] > 0 {
                    loss += crow[c] as f64 * (logz - theta[r * v + c] as f64);
                }
            }
            if let Some(g) = grad.as_deref_mut() {
                let grow = &mut g[r * v..(r + 1) * v];
                let tf = total as f64;
                for c in 0..v {
                    grow[c] = ((tf * probs[c] - crow[c] as f64) * inv_n) as f32;
                }
            }
        }
        loss *= inv_n;
        if self.l2 > 0.0 {
            let reg: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
            loss += 0.5 * self.l2 as f64 * reg;
            if let Some(g) = grad {
                for (gi, &ti) in g.iter_mut().zip(theta) {
                    *gi += self.l2 * ti;
                }
            }
        }
        loss
    }
}

impl GradientSource for SoftmaxLmProblem {
    fn dim(&self) -> usize {
        self.vocab * self.vocab
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn local_grad(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shards[device], theta, Some(grad))
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let loss = self.loss_grad_on(&self.test, theta, None);
        EvalMetrics {
            loss,
            accuracy: None,
            perplexity: Some(loss.exp()),
        }
    }

    fn init_theta(&self, _seed: u64) -> Vec<f32> {
        // Zero logits = uniform predictions: perplexity starts at V.
        vec![0.0f32; self.dim()]
    }

    fn layout(&self) -> ParamLayout {
        ParamLayout::contiguous(&[("w", vec![self.vocab, self.vocab])])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use crate::data::text::{markov_corpus, shard_corpus, CorpusSpec, MarkovChain};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> (SoftmaxLmProblem, CorpusSpec) {
        let spec = CorpusSpec {
            vocab: 16,
            length: 20_000,
            peakedness: 2.0,
            seed: 55,
        };
        let full = markov_corpus(&spec);
        let test = full.slice(0, 4000);
        let train = full.slice(4000, full.len());
        let shards = shard_corpus(&train, 4);
        (SoftmaxLmProblem::new(shards, test, 1e-4), spec)
    }

    #[test]
    fn initial_perplexity_is_vocab() {
        let (p, spec) = small_problem();
        let theta = p.init_theta(0);
        let ev = p.eval(&theta);
        let ppl = ev.perplexity.unwrap();
        assert!((ppl - spec.vocab as f64).abs() < 0.5, "ppl={ppl}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (p, _) = small_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let theta: Vec<f32> = (0..p.dim()).map(|_| rng.gaussian_f32(0.0, 0.3)).collect();
        check_gradient(&p, 2, &theta, &[0, 17, 100, 255], 2e-2);
    }

    #[test]
    fn training_approaches_entropy_floor() {
        let (p, spec) = small_problem();
        let chain = MarkovChain::from_spec(&spec);
        let floor = chain.mean_row_entropy().exp();
        let mut theta = p.init_theta(0);
        let m = p.num_devices();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..300 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-4.0, &step, &mut theta);
        }
        let ppl = p.eval(&theta).perplexity.unwrap();
        assert!(
            ppl < spec.vocab as f64 * 0.6,
            "no learning: ppl={ppl}, vocab={}",
            spec.vocab
        );
        assert!(ppl > floor * 0.8, "below the information floor?!");
        assert!(ppl < floor * 2.0, "far from floor: {ppl} vs {floor}");
    }

    #[test]
    fn aggregated_count_gradient_matches_naive() {
        // The O(V²+n) count-based gradient must equal the naive per-
        // sample gradient.
        let (p, _) = small_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let theta: Vec<f32> = (0..p.dim()).map(|_| rng.gaussian_f32(0.0, 0.2)).collect();
        let mut g = vec![0.0f32; p.dim()];
        let loss = p.local_grad(0, &theta, &mut g);

        // Naive recomputation.
        let data = &p.shards[0];
        let v = p.vocab;
        let n = data.len() - 1;
        let mut g_naive = vec![0.0f64; p.dim()];
        let mut loss_naive = 0.0f64;
        for w in data.tokens.windows(2) {
            let (r, y) = (w[0] as usize, w[1] as usize);
            let logits = &theta[r * v..(r + 1) * v];
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let z: f64 = logits.iter().map(|&x| ((x as f64) - maxl).exp()).sum();
            loss_naive += maxl + z.ln() - theta[r * v + y] as f64;
            for c in 0..v {
                let pc = ((theta[r * v + c] as f64) - maxl).exp() / z;
                g_naive[r * v + c] += (pc - if c == y { 1.0 } else { 0.0 }) / n as f64;
            }
        }
        loss_naive /= n as f64;
        let reg: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
        loss_naive += 0.5 * p.l2 as f64 * reg;
        for (gn, &t) in g_naive.iter_mut().zip(&theta) {
            *gn += p.l2 as f64 * t as f64;
        }
        assert!((loss - loss_naive).abs() < 1e-9);
        for (a, b) in g.iter().zip(&g_naive) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
    }
}
