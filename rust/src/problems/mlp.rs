//! One-hidden-layer MLP with manual backprop — the non-convex
//! classification workload (deeper stand-in for the paper's
//! ResNet-18 / MobileNet-v2 rows; the full conv/transformer models run
//! through the JAX/HLO path, see `crate::runtime`).
//!
//! Architecture: `x → W1 x + b1 → tanh → W2 h + b2 → softmax`.
//! Layout: `W1 [H×D] | b1 [H] | W2 [K×H] | b2 [K]`, `d = H(D+1) + K(H+1)`.
//!
//! Both passes run batched over the whole device shard
//! ([`crate::util::gemm`]): two forward GEMMs (`X·W1ᵀ`, `H·W2ᵀ`), two
//! weight-gradient GEMMs (`δᵀ·H`, `δᵀ·X`) and one delta-backprop GEMM
//! (`δ·W2`), with only the softmax/tanh nonlinearities elementwise. The
//! per-sample pre-batching path is retained as
//! [`MlpProblem::local_grad_naive`].

use super::{
    add_l2, stage_output_deltas, zeroed, EvalMetrics, GradScratch, GradientSource, ParamLayout,
};
use crate::data::ClassificationDataset;
use crate::util::gemm::{col_sum_add, gemm_nn, gemm_nt, gemm_tn};
use crate::util::rng::Xoshiro256pp;

/// See module docs.
pub struct MlpProblem {
    shards: Vec<ClassificationDataset>,
    test: ClassificationDataset,
    dim_in: usize,
    hidden: usize,
    classes: usize,
    l2: f32,
}

impl MlpProblem {
    /// One-hidden-layer MLP (`hidden` tanh units) with `l2` weight decay.
    pub fn new(
        shards: Vec<ClassificationDataset>,
        test: ClassificationDataset,
        hidden: usize,
        l2: f32,
    ) -> Self {
        assert!(!shards.is_empty());
        assert!(hidden >= 1);
        let dim_in = shards[0].dim;
        let classes = shards[0].num_classes;
        for s in &shards {
            assert_eq!(s.dim, dim_in);
            assert!(!s.is_empty());
        }
        Self {
            shards,
            test,
            dim_in,
            hidden,
            classes,
            l2,
        }
    }

    fn offsets(&self) -> (usize, usize, usize, usize) {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let w1 = 0;
        let b1 = w1 + h * dm;
        let w2 = b1 + h;
        let b2 = w2 + k * h;
        (w1, b1, w2, b2)
    }

    /// Batched loss/gradient over one dataset; returns
    /// `(mean loss, correct predictions)`.
    fn loss_grad_on(
        &self,
        data: &ClassificationDataset,
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
        scratch: &mut GradScratch,
    ) -> (f64, usize) {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        let n = data.len();
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let w1 = &theta[o_w1..o_w1 + h * dm];
        let b1 = &theta[o_b1..o_b1 + h];
        let w2 = &theta[o_w2..o_w2 + k * h];
        let b2 = &theta[o_b2..o_b2 + k];

        // Forward: hidden[n×H] = tanh(X·W1ᵀ + 1·b1ᵀ).
        let hid = zeroed(&mut scratch.hidden, n * h);
        for row in hid.chunks_exact_mut(h) {
            row.copy_from_slice(b1);
        }
        gemm_nt(&data.features, w1, hid, n, h, dm);
        for v in hid.iter_mut() {
            *v = v.tanh();
        }

        // logits[n×K] = hidden·W2ᵀ + 1·b2ᵀ.
        let logits = zeroed(&mut scratch.logits, n * k);
        for row in logits.chunks_exact_mut(k) {
            row.copy_from_slice(b2);
        }
        gemm_nt(hid, w2, logits, n, k, h);

        // Softmax + CE per row; δ_out staged in place (× 1/n).
        scratch.probs.clear();
        scratch.probs.resize(k, 0.0);
        let probs = &mut scratch.probs[..];
        let want_grad = grad.is_some();
        let inv_n = 1.0 / n as f64;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for (row, &y) in logits.chunks_exact_mut(k).zip(&data.labels) {
            loss += super::logistic::softmax_row(row, y, probs, &mut correct);
            if want_grad {
                stage_output_deltas(row, probs, y, inv_n);
            }
        }
        loss *= inv_n;

        if let Some(g) = grad.as_deref_mut() {
            // Output layer: ∂W2[K×H] += δ_outᵀ·hidden, ∂b2 = colsum(δ_out).
            gemm_tn(logits, hid, &mut g[o_w2..o_w2 + k * h], k, h, n);
            col_sum_add(logits, &mut g[o_b2..o_b2 + k], k);
            // δ_hidden[n×H] = δ_out·W2, gated through tanh'.
            let dhid = zeroed(&mut scratch.dhidden, n * h);
            gemm_nn(logits, w2, dhid, n, h, k);
            for (dv, &hv) in dhid.iter_mut().zip(hid.iter()) {
                *dv *= 1.0 - hv * hv;
            }
            // Input layer: ∂W1[H×D] += δ_hidᵀ·X, ∂b1 = colsum(δ_hid).
            gemm_tn(dhid, &data.features, &mut g[o_w1..o_w1 + h * dm], h, dm, n);
            col_sum_add(dhid, &mut g[o_b1..o_b1 + h], h);
        }
        add_l2(self.l2, theta, &mut loss, grad);
        (loss, correct)
    }

    /// Retained per-sample reference implementation (the pre-batching
    /// path): ground truth for `tests/prop_grad.rs` and the baseline
    /// the `grad` bench measures the GEMM path against.
    pub fn local_grad_naive(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let data = &self.shards[device];
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        let n = data.len();
        grad.fill(0.0);
        let mut hid = vec![0.0f64; h];
        let mut probs = vec![0.0f64; k];
        let mut dhid = vec![0.0f64; h];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            let x = data.row(i);
            let y = data.labels[i];
            // Forward: hidden = tanh(W1 x + b1).
            for (a, hv) in hid.iter_mut().enumerate() {
                let row = &theta[o_w1 + a * dm..o_w1 + (a + 1) * dm];
                let mut acc = theta[o_b1 + a] as f64;
                for (&wj, &xj) in row.iter().zip(x) {
                    acc += wj as f64 * xj as f64;
                }
                *hv = acc.tanh();
            }
            // logits = W2 hid + b2.
            for (c, p) in probs.iter_mut().enumerate() {
                let row = &theta[o_w2 + c * h..o_w2 + (c + 1) * h];
                let mut acc = theta[o_b2 + c] as f64;
                for (&wa, &ha) in row.iter().zip(&hid) {
                    acc += wa as f64 * ha;
                }
                *p = acc;
            }
            loss += super::logistic::softmax_f64_row(&mut probs, y, &mut correct);
            // Backprop into W2/b2 and hidden, then through tanh.
            dhid.fill(0.0);
            for c in 0..k {
                let coef = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
                let row_w2 = &theta[o_w2 + c * h..o_w2 + (c + 1) * h];
                let grow = &mut grad[o_w2 + c * h..o_w2 + (c + 1) * h];
                for a in 0..h {
                    grow[a] += (coef * hid[a]) as f32;
                    dhid[a] += coef * row_w2[a] as f64;
                }
                grad[o_b2 + c] += coef as f32;
            }
            for a in 0..h {
                let dpre = dhid[a] * (1.0 - hid[a] * hid[a]);
                let grow = &mut grad[o_w1 + a * dm..o_w1 + (a + 1) * dm];
                let dp = dpre as f32;
                for (gj, &xj) in grow.iter_mut().zip(x) {
                    *gj += dp * xj;
                }
                grad[o_b1 + a] += dp;
            }
        }
        loss *= inv_n;
        add_l2(self.l2, theta, &mut loss, Some(grad));
        loss
    }
}

impl GradientSource for MlpProblem {
    fn dim(&self) -> usize {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        h * (dm + 1) + k * (h + 1)
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn make_scratch(&self) -> GradScratch {
        let n_max = self.shards.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut ws = GradScratch::default();
        ws.hidden.reserve(n_max * self.hidden);
        ws.dhidden.reserve(n_max * self.hidden);
        ws.logits.reserve(n_max * self.classes);
        ws.probs.reserve(self.classes);
        ws
    }

    fn local_grad(
        &self,
        device: usize,
        theta: &[f32],
        grad: &mut [f32],
        scratch: &mut GradScratch,
    ) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shards[device], theta, Some(grad), scratch).0
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let mut scratch = self.make_scratch();
        let (loss, correct) = self.loss_grad_on(&self.test, theta, None, &mut scratch);
        EvalMetrics {
            loss,
            accuracy: Some(correct as f64 / self.test.len() as f64),
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0x391B);
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let (o_w1, _o_b1, o_w2, _o_b2) = self.offsets();
        let mut theta = vec![0.0f32; self.dim()];
        let s1 = 1.0 / (dm as f32).sqrt();
        for t in theta[o_w1..o_w1 + h * dm].iter_mut() {
            *t = rng.gaussian_f32(0.0, s1);
        }
        let s2 = 1.0 / (h as f32).sqrt();
        for t in theta[o_w2..o_w2 + k * h].iter_mut() {
            *t = rng.gaussian_f32(0.0, s2);
        }
        theta
    }

    fn layout(&self) -> ParamLayout {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        ParamLayout::contiguous(&[
            ("w1", vec![h, dm]),
            ("b1", vec![h]),
            ("w2", vec![k, h]),
            ("b2", vec![k]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synth::{train_test_split, MixtureSpec};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> MlpProblem {
        let spec = MixtureSpec {
            num_classes: 3,
            dim: 6,
            num_samples: 300,
            separation: 1.5,
            noise: 0.8,
            seed: 88,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let parts = iid_partition(train.len(), 3, &mut rng);
        let shards = parts.iter().map(|p| train.subset(p)).collect();
        MlpProblem::new(shards, test, 8, 1e-4)
    }

    #[test]
    fn dims_and_layout() {
        let p = small_problem();
        // h(d+1) + k(h+1) = 8*7 + 3*9 = 83.
        assert_eq!(p.dim(), 83);
        assert_eq!(p.layout().dim(), 83);
        assert_eq!(p.layout().entries.len(), 4);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let theta = p.init_theta(3);
        // Check coords in each parameter block.
        check_gradient(&p, 1, &theta, &[0, 30, 48, 55, 70, 82], 3e-2);
    }

    #[test]
    fn batched_matches_naive_reference() {
        let p = small_problem();
        let theta = p.init_theta(12);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut g_ref = vec![0.0f32; p.dim()];
        for dev in 0..p.num_devices() {
            let loss = p.local_grad(dev, &theta, &mut g, &mut ws);
            let loss_ref = p.local_grad_naive(dev, &theta, &mut g_ref);
            assert!((loss - loss_ref).abs() < 1e-5 * loss_ref.abs().max(1.0));
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn training_improves_accuracy() {
        let p = small_problem();
        let mut theta = p.init_theta(4);
        let acc0 = p.eval(&theta).accuracy.unwrap();
        let m = p.num_devices();
        let mut ws = p.make_scratch();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..200 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g, &mut ws);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-0.5, &step, &mut theta);
        }
        let acc = p.eval(&theta).accuracy.unwrap();
        assert!(acc > acc0.max(0.55), "training failed: {acc0} -> {acc}");
    }
}
