//! One-hidden-layer MLP with manual backprop — the non-convex
//! classification workload (deeper stand-in for the paper's
//! ResNet-18 / MobileNet-v2 rows; the full conv/transformer models run
//! through the JAX/HLO path, see `crate::runtime`).
//!
//! Architecture: `x → W1 x + b1 → tanh → W2 h + b2 → softmax`.
//! Layout: `W1 [H×D] | b1 [H] | W2 [K×H] | b2 [K]`, `d = H(D+1) + K(H+1)`.

use super::{EvalMetrics, GradientSource, ParamLayout};
use crate::data::ClassificationDataset;
use crate::util::rng::Xoshiro256pp;

/// See module docs.
pub struct MlpProblem {
    shards: Vec<ClassificationDataset>,
    test: ClassificationDataset,
    dim_in: usize,
    hidden: usize,
    classes: usize,
    l2: f32,
}

impl MlpProblem {
    pub fn new(
        shards: Vec<ClassificationDataset>,
        test: ClassificationDataset,
        hidden: usize,
        l2: f32,
    ) -> Self {
        assert!(!shards.is_empty());
        assert!(hidden >= 1);
        let dim_in = shards[0].dim;
        let classes = shards[0].num_classes;
        for s in &shards {
            assert_eq!(s.dim, dim_in);
            assert!(!s.is_empty());
        }
        Self {
            shards,
            test,
            dim_in,
            hidden,
            classes,
            l2,
        }
    }

    fn offsets(&self) -> (usize, usize, usize, usize) {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let w1 = 0;
        let b1 = w1 + h * dm;
        let w2 = b1 + h;
        let b2 = w2 + k * h;
        (w1, b1, w2, b2)
    }

    #[allow(clippy::too_many_arguments)]
    fn loss_grad_on(
        &self,
        data: &ClassificationDataset,
        theta: &[f32],
        mut grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        let n = data.len();
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let mut hid = vec![0.0f64; h];
        let mut probs = vec![0.0f64; k];
        let mut dhid = vec![0.0f64; h];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            let x = data.row(i);
            let y = data.labels[i];
            // Forward: hidden = tanh(W1 x + b1).
            for a in 0..h {
                let row = &theta[o_w1 + a * dm..o_w1 + (a + 1) * dm];
                let mut acc = theta[o_b1 + a] as f64;
                for j in 0..dm {
                    acc += row[j] as f64 * x[j] as f64;
                }
                hid[a] = acc.tanh();
            }
            // logits = W2 hid + b2.
            for c in 0..k {
                let row = &theta[o_w2 + c * h..o_w2 + (c + 1) * h];
                let mut acc = theta[o_b2 + c] as f64;
                for a in 0..h {
                    acc += row[a] as f64 * hid[a];
                }
                probs[c] = acc;
            }
            // Softmax + CE.
            let maxl = probs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut z = 0.0;
            for p in probs.iter_mut() {
                *p = (*p - maxl).exp();
                z += *p;
            }
            for p in probs.iter_mut() {
                *p /= z;
            }
            loss += -(probs[y].max(1e-300).ln());
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
            if let Some(g) = grad.as_deref_mut() {
                // dlogits = probs − onehot(y).
                // Backprop into W2/b2 and hidden.
                dhid.fill(0.0);
                for c in 0..k {
                    let coef = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv_n;
                    let row_w2 = &theta[o_w2 + c * h..o_w2 + (c + 1) * h];
                    let grow = &mut g[o_w2 + c * h..o_w2 + (c + 1) * h];
                    for a in 0..h {
                        grow[a] += (coef * hid[a]) as f32;
                        dhid[a] += coef * row_w2[a] as f64;
                    }
                    g[o_b2 + c] += coef as f32;
                }
                // Through tanh: dpre = dhid * (1 − hid²).
                for a in 0..h {
                    let dpre = dhid[a] * (1.0 - hid[a] * hid[a]);
                    let grow = &mut g[o_w1 + a * dm..o_w1 + (a + 1) * dm];
                    let dp = dpre as f32;
                    for j in 0..dm {
                        grow[j] += dp * x[j];
                    }
                    g[o_b1 + a] += dp;
                }
            }
        }
        loss *= inv_n;
        if self.l2 > 0.0 {
            let reg: f64 = theta.iter().map(|&t| (t as f64) * (t as f64)).sum();
            loss += 0.5 * self.l2 as f64 * reg;
            if let Some(g) = grad {
                for (gi, &ti) in g.iter_mut().zip(theta) {
                    *gi += self.l2 * ti;
                }
            }
        }
        (loss, correct)
    }
}

impl GradientSource for MlpProblem {
    fn dim(&self) -> usize {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        h * (dm + 1) + k * (h + 1)
    }

    fn num_devices(&self) -> usize {
        self.shards.len()
    }

    fn local_grad(&self, device: usize, theta: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        self.loss_grad_on(&self.shards[device], theta, Some(grad)).0
    }

    fn eval(&self, theta: &[f32]) -> EvalMetrics {
        let (loss, correct) = self.loss_grad_on(&self.test, theta, None);
        EvalMetrics {
            loss,
            accuracy: Some(correct as f64 / self.test.len() as f64),
            perplexity: None,
        }
    }

    fn init_theta(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::stream(seed, 0x391B);
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        let (o_w1, _o_b1, o_w2, _o_b2) = self.offsets();
        let mut theta = vec![0.0f32; self.dim()];
        let s1 = 1.0 / (dm as f32).sqrt();
        for t in theta[o_w1..o_w1 + h * dm].iter_mut() {
            *t = rng.gaussian_f32(0.0, s1);
        }
        let s2 = 1.0 / (h as f32).sqrt();
        for t in theta[o_w2..o_w2 + k * h].iter_mut() {
            *t = rng.gaussian_f32(0.0, s2);
        }
        theta
    }

    fn layout(&self) -> ParamLayout {
        let (dm, h, k) = (self.dim_in, self.hidden, self.classes);
        ParamLayout::contiguous(&[
            ("w1", vec![h, dm]),
            ("b1", vec![h]),
            ("w2", vec![k, h]),
            ("b2", vec![k]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synth::{train_test_split, MixtureSpec};
    use crate::problems::check_gradient;
    use crate::util::vecmath::axpy;

    fn small_problem() -> MlpProblem {
        let spec = MixtureSpec {
            num_classes: 3,
            dim: 6,
            num_samples: 300,
            separation: 1.5,
            noise: 0.8,
            seed: 88,
        };
        let (train, test) = train_test_split(&spec, 0.2);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let parts = iid_partition(train.len(), 3, &mut rng);
        let shards = parts.iter().map(|p| train.subset(p)).collect();
        MlpProblem::new(shards, test, 8, 1e-4)
    }

    #[test]
    fn dims_and_layout() {
        let p = small_problem();
        // h(d+1) + k(h+1) = 8*7 + 3*9 = 83.
        assert_eq!(p.dim(), 83);
        assert_eq!(p.layout().dim(), 83);
        assert_eq!(p.layout().entries.len(), 4);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let theta = p.init_theta(3);
        // Check coords in each parameter block.
        check_gradient(&p, 1, &theta, &[0, 30, 48, 55, 70, 82], 3e-2);
    }

    #[test]
    fn training_improves_accuracy() {
        let p = small_problem();
        let mut theta = p.init_theta(4);
        let acc0 = p.eval(&theta).accuracy.unwrap();
        let m = p.num_devices();
        let mut g = vec![0.0f32; p.dim()];
        let mut total = vec![0.0f32; p.dim()];
        for _ in 0..200 {
            total.fill(0.0);
            for dev in 0..m {
                p.local_grad(dev, &theta, &mut g);
                axpy(1.0 / m as f32, &g, &mut total);
            }
            let step = total.clone();
            axpy(-0.5, &step, &mut theta);
        }
        let acc = p.eval(&theta).accuracy.unwrap();
        assert!(acc > acc0.max(0.55), "training failed: {acc0} -> {acc}");
    }
}
