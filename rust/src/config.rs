//! Experiment configuration: dataset/split/algorithm presets mirroring
//! the paper's evaluation matrix, TOML-file overrides, and problem
//! construction.
//!
//! Section V setup reproduced:
//! * three datasets — CIFAR-10 / CIFAR-100 / WikiText-2, substituted per
//!   DESIGN.md §3 by `synth-cf10` / `synth-cf100` / `synth-wt2`;
//! * splits — `IID-100` (the M = 100-device — 80 for WT-2 — large
//!   system), `IID` and `Non-IID` (M = 10; two classes per device for
//!   CF-10, ten for CF-100);
//! * β per dataset as selected in Section V-D: 0.1 (CF-10), 0.25
//!   (CF-100), 1.25 (WT-2).

use crate::coordinator::{AggregationMode, RunConfig, SlotPolicy, StalenessPolicy};
use crate::data::partition::{iid_partition, label_limited_partition};
use crate::data::synth::{gaussian_mixture, MixtureSpec};
use crate::data::text::{markov_corpus, shard_corpus, CorpusSpec};
use crate::problems::logistic::LogisticProblem;
use crate::problems::mlp::MlpProblem;
use crate::problems::quadratic::StreamedQuadratic;
use crate::problems::softmax_lm::SoftmaxLmProblem;
use crate::problems::GradientSource;
use crate::protocol::{ChaosSpec, ServeSpec};
use crate::quant::SectionSpec;
use crate::selection::SelectionSpec;
use crate::transport::scenario::NetworkSpec;
use crate::util::rng::Xoshiro256pp;
use crate::util::toml;
use std::path::Path;

/// Which synthetic stand-in dataset to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Gaussian mixture, 10 classes (CIFAR-10 stand-in; MLP model).
    Cf10,
    /// Gaussian mixture, 100 classes (CIFAR-100 stand-in; logistic
    /// model).
    Cf100,
    /// Markov character corpus (WikiText-2 stand-in; bigram softmax
    /// LM).
    Wt2,
}

impl DatasetKind {
    /// Parse a dataset name (`cf10`, `cf100`, `wt2` and aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cf10" | "cifar10" | "cf-10" => Some(Self::Cf10),
            "cf100" | "cifar100" | "cf-100" => Some(Self::Cf100),
            "wt2" | "wikitext2" | "wt-2" => Some(Self::Wt2),
            _ => None,
        }
    }

    /// Row label as printed in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Cf10 => "CF-10",
            Self::Cf100 => "CF-100",
            Self::Wt2 => "WT-2",
        }
    }

    /// β selected for this dataset in the paper's Section V-D.
    pub fn paper_beta(&self) -> f32 {
        match self {
            Self::Cf10 => 0.1,
            Self::Cf100 => 0.25,
            Self::Wt2 => 1.25,
        }
    }
}

/// Data split / system size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitKind {
    /// Large system: M = 100 devices (80 for WT-2), IID shards.
    IidLarge,
    /// M = 10, IID shards.
    Iid,
    /// M = 10, label-limited Non-IID shards (2 classes/device CF-10,
    /// 10 classes/device CF-100; WT-2 has no Non-IID row in the paper).
    NonIid,
}

impl SplitKind {
    /// Parse a split name (`iid-100`, `iid`, `non-iid` and aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "iid-100" | "iid-80" | "iid-large" | "iidlarge" => Some(Self::IidLarge),
            "iid" => Some(Self::Iid),
            "non-iid" | "noniid" | "non_iid" => Some(Self::NonIid),
            _ => None,
        }
    }

    /// Split label as printed in the tables (device-count aware).
    pub fn name(&self, ds: DatasetKind) -> &'static str {
        match (self, ds) {
            (Self::IidLarge, DatasetKind::Wt2) => "IID-80",
            (Self::IidLarge, _) => "IID-100",
            (Self::Iid, _) => "IID",
            (Self::NonIid, _) => "Non-IID",
        }
    }
}

/// One experiment cell: dataset × split (× hetero) with its
/// hyperparameters.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// Dataset stand-in to run on.
    pub dataset: DatasetKind,
    /// Data split / system size.
    pub split: SplitKind,
    /// Half the devices at 50% capacity (Table III / Figure 3).
    pub hetero: bool,
    /// Device count `M`.
    pub devices: usize,
    /// Communication rounds `K`.
    pub rounds: usize,
    /// Server learning rate `α`.
    pub alpha: f32,
    /// AQUILA tuning factor `β` (eq. 8).
    pub beta: f32,
    /// Base RNG seed (default 2023, the paper's year).
    pub seed: u64,
    /// Scale factor on default dataset sizes (CI/smoke runs use < 1).
    pub data_scale: f64,
    /// Device-selection strategy (`selection = "random-k:3"` in TOML,
    /// `--select` on the CLI; the deprecated `sample_k = K` key maps to
    /// `random-k:K`). Default: full participation.
    pub selection: SelectionSpec,
    /// Simulated network scenario (`network = "cellular:deadline=2"`
    /// in TOML, `--network` on the CLI). Default: the ideal zero-cost
    /// network.
    pub network: NetworkSpec,
    /// DAdaQuant time-adaptive schedule `b₀` — `dadaquant_b0` in TOML,
    /// `--dadaquant-b0` on the CLI. Defaults (2, 3, 16) match the
    /// paper's baseline configuration.
    pub dadaquant_b0: u8,
    /// DAdaQuant schedule patience (`dadaquant_patience`).
    pub dadaquant_patience: u32,
    /// DAdaQuant schedule level cap (`dadaquant_cap`).
    pub dadaquant_cap: u8,
    /// Quantization sectioning (`quant_sections = "tensor"` in TOML,
    /// `--quant-sections` on the CLI): `global` (default, the
    /// single-scale wire format), `tensor` (one scale per model
    /// tensor), or `fixed:N` (N-element blocks).
    pub quant_sections: SectionSpec,
    /// Coordinator-as-a-service settings (the TOML `[serve]` table,
    /// the `--serve`/`--connect` CLI flags). Ignored by in-process
    /// runs.
    pub serve: ServeSpec,
    /// Deterministic fault injection for served runs (the TOML
    /// `[chaos]` table, `--chaos` on the CLI). Default: disabled.
    /// Ignored by in-process runs.
    pub chaos: ChaosSpec,
    /// Virtualized population size (`population = 1000000` in TOML,
    /// `--population` on the CLI). When set, the dataset problem is
    /// replaced by an on-the-fly [`StreamedQuadratic`] with this many
    /// devices and the run defaults to a lazy slot store
    /// (EXPERIMENTS.md, "Million-device cookbook"). Default: off —
    /// the dataset's own device count.
    pub population: Option<usize>,
    /// Live-slot cache capacity for the lazy slot store (`slot_cache`
    /// in TOML, `--slot-cache` on the CLI; 0 = lazy but unbounded).
    /// Setting it forces [`SlotPolicy::Lazy`] even without
    /// `population`; unset, virtualized runs default to a cache of
    /// 8192 and dataset runs stay eager.
    pub slot_cache: Option<usize>,
    /// Aggregation mode (`aggregation = "buffered:m=32,..."` or a
    /// `[aggregation]` table in TOML, `--aggregation` on the CLI):
    /// the default synchronous barrier or the buffered-async event
    /// engine (DESIGN.md §Async).
    pub aggregation: AggregationMode,
}

/// Model dimension of the [`StreamedQuadratic`] problem virtualized
/// (`population`) runs train: large enough that quantized uploads
/// exercise the real packing path, small enough that a 1M-device
/// round's cohort fits comfortably in memory.
const STREAMED_POPULATION_DIM: usize = 256;

impl ExperimentSpec {
    /// Device count per the paper's setup.
    fn default_devices(ds: DatasetKind, split: SplitKind) -> usize {
        match (split, ds) {
            (SplitKind::IidLarge, DatasetKind::Wt2) => 80,
            (SplitKind::IidLarge, _) => 100,
            _ => 10,
        }
    }

    /// The paper's default cell for `dataset × split` (devices, rounds,
    /// α, β per Section V).
    pub fn new(dataset: DatasetKind, split: SplitKind, hetero: bool) -> Self {
        let devices = Self::default_devices(dataset, split);
        Self {
            dataset,
            split,
            hetero,
            devices,
            rounds: if devices >= 80 { 150 } else { 300 },
            alpha: match dataset {
                DatasetKind::Wt2 => 2.0,
                _ => 0.5,
            },
            beta: dataset.paper_beta(),
            seed: 2023,
            data_scale: 1.0,
            selection: SelectionSpec::Full,
            network: NetworkSpec::default(),
            dadaquant_b0: 2,
            dadaquant_patience: 3,
            dadaquant_cap: 16,
            quant_sections: SectionSpec::Global,
            serve: ServeSpec::default(),
            chaos: ChaosSpec::default(),
            population: None,
            slot_cache: None,
            aggregation: AggregationMode::Sync,
        }
    }

    /// Row label as printed in the tables.
    pub fn row_label(&self) -> String {
        format!("{} {}", self.dataset.name(), self.split.name(self.dataset))
    }

    /// Reduce dataset sizes and rounds (smoke tests / quick benches).
    pub fn scaled(mut self, data_scale: f64, rounds: usize) -> Self {
        self.data_scale = data_scale;
        self.rounds = rounds;
        self
    }

    /// Device count the run actually simulates: `population` when set
    /// (virtualized run), the dataset's device count otherwise.
    pub fn effective_devices(&self) -> usize {
        self.population.unwrap_or(self.devices)
    }

    /// Slot-store policy implied by `population`/`slot_cache` (see
    /// those fields' docs): an explicit `slot_cache` forces a lazy
    /// store with that capacity, a bare `population` defaults to a
    /// lazy store with an 8192-slot cache, and plain dataset runs stay
    /// eager.
    pub fn slot_policy(&self) -> SlotPolicy {
        match (self.slot_cache, self.population) {
            (Some(cache), _) => SlotPolicy::Lazy { cache },
            (None, Some(_)) => SlotPolicy::Lazy { cache: 8192 },
            (None, None) => SlotPolicy::Eager,
        }
    }

    /// The coordinator run-config for this experiment.
    pub fn run_config(&self) -> RunConfig {
        RunConfig {
            alpha: self.alpha,
            beta: self.beta,
            rounds: self.rounds,
            eval_every: (self.rounds / 10).max(1),
            seed: self.seed,
            threads: 0,
            dadaquant_b0: self.dadaquant_b0,
            dadaquant_patience: self.dadaquant_patience,
            dadaquant_cap: self.dadaquant_cap,
            network: self.network.clone(),
            quant_sections: self.quant_sections,
            slots: self.slot_policy(),
            aggregation: self.aggregation.clone(),
            ..RunConfig::default()
        }
    }

    /// Construct the federated problem (datasets, shards, model).
    /// With `population` set this is an on-the-fly
    /// [`StreamedQuadratic`] — per-device data is regenerated from
    /// `(seed, device_id)` inside every gradient call, so a 10⁷-device
    /// problem costs O(1) memory (DESIGN.md §Population).
    pub fn build_problem(&self) -> Box<dyn GradientSource> {
        if let Some(m) = self.population {
            return Box::new(StreamedQuadratic::new(
                STREAMED_POPULATION_DIM,
                m,
                0.5,
                2.0,
                0.5,
                self.seed,
            ));
        }
        let scale = |n: usize| ((n as f64 * self.data_scale) as usize).max(self.devices * 4);
        let mut rng = Xoshiro256pp::stream(self.seed, 0x5917);
        match self.dataset {
            DatasetKind::Cf10 => {
                let spec = MixtureSpec::cifar10_like(scale(6000), self.seed);
                let full = gaussian_mixture(&spec);
                let n_test = full.len() / 6;
                let test = full.subset(&(0..n_test).collect::<Vec<_>>());
                let train = full.subset(&(n_test..full.len()).collect::<Vec<_>>());
                let parts = match self.split {
                    SplitKind::NonIid => label_limited_partition(
                        &train.labels,
                        train.num_classes,
                        self.devices,
                        2,
                        &mut rng,
                    ),
                    _ => iid_partition(train.len(), self.devices, &mut rng),
                };
                let shards = parts.iter().map(|p| train.subset(p)).collect();
                Box::new(MlpProblem::new(shards, test, 32, 1e-4))
            }
            DatasetKind::Cf100 => {
                let spec = MixtureSpec::cifar100_like(scale(10_000), self.seed);
                let full = gaussian_mixture(&spec);
                let n_test = full.len() / 6;
                let test = full.subset(&(0..n_test).collect::<Vec<_>>());
                let train = full.subset(&(n_test..full.len()).collect::<Vec<_>>());
                let parts = match self.split {
                    SplitKind::NonIid => label_limited_partition(
                        &train.labels,
                        train.num_classes,
                        self.devices,
                        10,
                        &mut rng,
                    ),
                    _ => iid_partition(train.len(), self.devices, &mut rng),
                };
                let shards = parts.iter().map(|p| train.subset(p)).collect();
                Box::new(LogisticProblem::new(shards, test, 1e-4))
            }
            DatasetKind::Wt2 => {
                let spec = CorpusSpec::wikitext2_like(scale(120_000), self.seed);
                let full = markov_corpus(&spec);
                let n_test = full.len() / 6;
                let test = full.slice(0, n_test);
                let train = full.slice(n_test, full.len());
                let shards = shard_corpus(&train, self.devices);
                Box::new(SoftmaxLmProblem::new(shards, test, 1e-5))
            }
        }
    }

    /// Apply overrides from a parsed TOML map (`experiment` table).
    /// An unparseable `selection` value is an error — silently running
    /// full participation instead of the intended cohort would produce
    /// a mislabeled trace.
    pub fn apply_toml(
        &mut self,
        map: &std::collections::BTreeMap<String, toml::Value>,
    ) -> anyhow::Result<()> {
        let get = |k: &str| map.get(&format!("experiment.{k}")).or_else(|| map.get(k));
        if let Some(v) = get("dataset").and_then(|v| v.as_str()) {
            self.dataset = DatasetKind::parse(v).unwrap_or(self.dataset);
        }
        if let Some(v) = get("split").and_then(|v| v.as_str()) {
            self.split = SplitKind::parse(v).unwrap_or(self.split);
        }
        if let Some(v) = get("hetero").and_then(|v| v.as_bool()) {
            self.hetero = v;
        }
        if let Some(v) = get("devices").and_then(|v| v.as_i64()) {
            self.devices = v.max(1) as usize;
        }
        if let Some(v) = get("rounds").and_then(|v| v.as_i64()) {
            self.rounds = v.max(1) as usize;
        }
        if let Some(v) = get("alpha").and_then(|v| v.as_f64()) {
            self.alpha = v as f32;
        }
        if let Some(v) = get("beta").and_then(|v| v.as_f64()) {
            self.beta = v as f32;
        }
        if let Some(v) = get("seed").and_then(|v| v.as_i64()) {
            self.seed = v as u64;
        }
        if let Some(v) = get("data_scale").and_then(|v| v.as_f64()) {
            self.data_scale = v;
        }
        // Out-of-range schedule values are hard errors, matching the
        // CLI flags — silently clamping would run a different schedule
        // than the experiment file describes.
        if let Some(v) = get("dadaquant_b0").and_then(|v| v.as_i64()) {
            anyhow::ensure!((1..=32).contains(&v), "dadaquant_b0 must be in 1..=32, got {v}");
            self.dadaquant_b0 = v as u8;
        }
        if let Some(v) = get("dadaquant_patience").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 1, "dadaquant_patience must be >= 1, got {v}");
            self.dadaquant_patience = v as u32;
        }
        if let Some(v) = get("dadaquant_cap").and_then(|v| v.as_i64()) {
            anyhow::ensure!((1..=32).contains(&v), "dadaquant_cap must be in 1..=32, got {v}");
            self.dadaquant_cap = v as u8;
        }
        // Deprecated spelling first, so an explicit `selection` wins.
        if let Some(v) = get("sample_k").and_then(|v| v.as_i64()) {
            self.selection = SelectionSpec::RandomK(v.max(1) as usize);
        }
        if let Some(v) = get("selection").and_then(|v| v.as_str()) {
            self.selection = SelectionSpec::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown selection spec '{v}' (try: {})", SelectionSpec::SYNTAX)
            })?;
        }
        // Like `selection`, a bad network spec is a hard error —
        // silently running the ideal network instead of the intended
        // scenario would produce a mislabeled time-to-accuracy trace.
        if let Some(v) = get("network").and_then(|v| v.as_str()) {
            self.network = NetworkSpec::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown network spec '{v}' (try: {})", NetworkSpec::SYNTAX)
            })?;
        }
        // A bad sectioning spec is likewise a hard error — silently
        // quantizing with one global scale would mislabel the trace's
        // error/overhead trade-off.
        if let Some(v) = get("quant_sections").and_then(|v| v.as_str()) {
            self.quant_sections = SectionSpec::parse(v).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown quant_sections spec '{v}' (try: {})",
                    SectionSpec::SYNTAX
                )
            })?;
        }
        // The [serve] table configures the protocol coordinator
        // service; like the schedule keys, out-of-range values are
        // hard errors rather than silent clamps.
        if let Some(v) = map.get("serve.addr").and_then(|v| v.as_str()) {
            self.serve.addr = v.to_string();
        }
        if let Some(v) = map.get("serve.clients").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 1, "serve.clients must be >= 1, got {v}");
            self.serve.clients = v as usize;
        }
        for (key, slot) in [
            ("serve.heartbeat_ms", &mut self.serve.heartbeat_ms),
            ("serve.heartbeat_timeout_ms", &mut self.serve.heartbeat_timeout_ms),
            ("serve.round_timeout_ms", &mut self.serve.round_timeout_ms),
            ("serve.accept_timeout_ms", &mut self.serve.accept_timeout_ms),
        ] {
            if let Some(v) = map.get(key).and_then(|v| v.as_i64()) {
                anyhow::ensure!(v >= 1, "{key} must be >= 1, got {v}");
                *slot = v as u64;
            }
        }
        // The [chaos] table configures fault injection for served
        // runs. Out-of-range probabilities are hard errors — silently
        // clamping would run a different fault mix than the file says.
        for (key, slot) in [
            ("chaos.drop", &mut self.chaos.drop_p),
            ("chaos.stall", &mut self.chaos.stall_p),
            ("chaos.partial", &mut self.chaos.partial_p),
            ("chaos.corrupt", &mut self.chaos.corrupt_p),
            ("chaos.dup", &mut self.chaos.dup_p),
            ("chaos.accept", &mut self.chaos.accept_p),
        ] {
            if let Some(v) = map.get(key).and_then(|v| v.as_f64()) {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&v),
                    "{key} must be a probability in [0, 1], got {v}"
                );
                *slot = v;
            }
        }
        if let Some(v) = map.get("chaos.stall_ms").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 1, "chaos.stall_ms must be >= 1, got {v}");
            self.chaos.stall_ms = v as u64;
        }
        if let Some(v) = map.get("chaos.seed").and_then(|v| v.as_i64()) {
            self.chaos.seed = v as u64;
        }
        // Population virtualization keys. A non-positive population is
        // a hard error — it would silently run the dataset problem.
        if let Some(v) = get("population").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 1, "population must be >= 1, got {v}");
            self.population = Some(v as usize);
        }
        if let Some(v) = get("slot_cache").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 0, "slot_cache must be >= 0, got {v}");
            self.slot_cache = Some(v as usize);
        }
        // Aggregation mode: a compact spec string (`aggregation =
        // "buffered:m=32,staleness=poly:0.5"`) or an `[aggregation]`
        // table. Like `network`, a bad spec is a hard error — silently
        // running the sync barrier would mislabel the trace's
        // time-to-accuracy axis.
        if let Some(v) = get("aggregation").and_then(|v| v.as_str()) {
            self.aggregation = AggregationMode::parse(v).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown aggregation spec '{v}' (try: {})",
                    AggregationMode::SYNTAX
                )
            })?;
        }
        let agg_mode = map.get("aggregation.mode").and_then(|v| v.as_str());
        let agg_m = map.get("aggregation.m").and_then(|v| v.as_i64());
        let agg_staleness = map.get("aggregation.staleness").and_then(|v| v.as_str());
        let agg_inflight = map.get("aggregation.inflight").and_then(|v| v.as_i64());
        if agg_mode.is_some()
            || agg_m.is_some()
            || agg_staleness.is_some()
            || agg_inflight.is_some()
        {
            match agg_mode.unwrap_or("buffered") {
                "sync" => self.aggregation = AggregationMode::Sync,
                "buffered" => {
                    let m = agg_m
                        .ok_or_else(|| anyhow::anyhow!("[aggregation] buffered mode requires m"))?;
                    anyhow::ensure!(m >= 1, "aggregation.m must be >= 1, got {m}");
                    let staleness = match agg_staleness {
                        Some(s) => StalenessPolicy::parse(s).ok_or_else(|| {
                            anyhow::anyhow!(
                                "unknown aggregation.staleness spec '{s}' (try: {})",
                                StalenessPolicy::SYNTAX
                            )
                        })?,
                        None => StalenessPolicy::Constant(1.0),
                    };
                    let max_inflight = match agg_inflight {
                        Some(v) => {
                            anyhow::ensure!(v >= 1, "aggregation.inflight must be >= 1, got {v}");
                            v as usize
                        }
                        None => 2 * m as usize,
                    };
                    self.aggregation = AggregationMode::Buffered {
                        m: m as usize,
                        staleness,
                        max_inflight,
                    };
                }
                other => anyhow::bail!(
                    "unknown aggregation.mode '{other}' (expected sync or buffered)"
                ),
            }
        }
        Ok(())
    }

    /// Load a spec from a TOML file (starting from the cf10/iid
    /// default).
    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let map = toml::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        spec.apply_toml(&map)?;
        Ok(spec)
    }
}

/// The eight rows of Table II (homogeneous).
pub fn table2_rows() -> Vec<ExperimentSpec> {
    vec![
        ExperimentSpec::new(DatasetKind::Cf10, SplitKind::IidLarge, false),
        ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false),
        ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false),
        ExperimentSpec::new(DatasetKind::Cf100, SplitKind::IidLarge, false),
        ExperimentSpec::new(DatasetKind::Cf100, SplitKind::Iid, false),
        ExperimentSpec::new(DatasetKind::Cf100, SplitKind::NonIid, false),
        ExperimentSpec::new(DatasetKind::Wt2, SplitKind::IidLarge, false),
        ExperimentSpec::new(DatasetKind::Wt2, SplitKind::Iid, false),
    ]
}

/// The canonical `repro run` flag surface: every CLI flag the `run`
/// command parses, the TOML key or table with the same effect (`None`
/// for CLI-only flags), and a one-line help string. `repro list`
/// prints its `run` rows from this table, and unit tests diff it
/// against the flags `main.rs` actually parses, the keys
/// [`ExperimentSpec::apply_toml`] actually consumes, and the flags
/// README.md documents — so the surfaces cannot drift apart silently
/// (a new flag without a row here fails CI).
pub const RUN_FLAG_SURFACE: &[(&str, Option<&str>, &str)] = &[
    ("config", None, "experiment TOML file (required)"),
    ("algo", None, "algorithm name (see the list above)"),
    ("select", Some("selection"), "device-selection spec"),
    ("network", Some("network"), "simulated network spec"),
    ("quant-sections", Some("quant_sections"), "quantization sectioning spec"),
    ("aggregation", Some("aggregation"), "sync barrier | buffered-async engine"),
    ("dadaquant-b0", Some("dadaquant_b0"), "DAdaQuant schedule b0 (1..=32)"),
    ("dadaquant-patience", Some("dadaquant_patience"), "DAdaQuant schedule patience"),
    ("dadaquant-cap", Some("dadaquant_cap"), "DAdaQuant level cap (1..=32)"),
    ("population", Some("population"), "virtualized N-device run (lazy slots)"),
    ("slot-cache", Some("slot_cache"), "live-slot cache capacity (0 = unbounded)"),
    ("out", None, "stream per-round CSV to FILE"),
    ("jsonl", None, "stream JSON-lines to FILE"),
    ("serve", Some("serve"), "serve the run over TCP (coordinator)"),
    ("connect", None, "join a served run as a device client"),
    ("chaos", Some("chaos"), "deterministic fault injection"),
    ("checkpoint", None, "periodic checkpoint FILE"),
    ("checkpoint-every", None, "checkpoint cadence in rounds"),
    ("resume", None, "restart from a checkpoint FILE"),
];

/// The five rows of Table III (heterogeneous 100%–50%).
pub fn table3_rows() -> Vec<ExperimentSpec> {
    vec![
        ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, true),
        ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, true),
        ExperimentSpec::new(DatasetKind::Cf100, SplitKind::Iid, true),
        ExperimentSpec::new(DatasetKind::Cf100, SplitKind::NonIid, true),
        ExperimentSpec::new(DatasetKind::Wt2, SplitKind::Iid, true),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(DatasetKind::parse("CF10"), Some(DatasetKind::Cf10));
        assert_eq!(DatasetKind::parse("wikitext2"), Some(DatasetKind::Wt2));
        assert_eq!(DatasetKind::parse("mnist"), None);
        assert_eq!(SplitKind::parse("Non-IID"), Some(SplitKind::NonIid));
        assert_eq!(SplitKind::parse("iid-100"), Some(SplitKind::IidLarge));
    }

    #[test]
    fn paper_betas() {
        assert_eq!(DatasetKind::Cf10.paper_beta(), 0.1);
        assert_eq!(DatasetKind::Cf100.paper_beta(), 0.25);
        assert_eq!(DatasetKind::Wt2.paper_beta(), 1.25);
    }

    #[test]
    fn default_system_sizes_match_paper() {
        assert_eq!(
            ExperimentSpec::new(DatasetKind::Cf10, SplitKind::IidLarge, false).devices,
            100
        );
        assert_eq!(
            ExperimentSpec::new(DatasetKind::Wt2, SplitKind::IidLarge, false).devices,
            80
        );
        assert_eq!(
            ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false).devices,
            10
        );
    }

    #[test]
    fn table_shapes() {
        assert_eq!(table2_rows().len(), 8);
        assert_eq!(table3_rows().len(), 5);
        assert!(table3_rows().iter().all(|s| s.hetero));
    }

    #[test]
    fn build_problem_smoke() {
        let spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::NonIid, false)
            .scaled(0.05, 5);
        let p = spec.build_problem();
        assert_eq!(p.num_devices(), 10);
        assert!(p.dim() > 0);
        let theta = p.init_theta(1);
        let mut ws = p.make_scratch();
        let mut g = vec![0.0; p.dim()];
        let loss = p.local_grad(0, &theta, &mut g, &mut ws);
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn toml_overrides() {
        let text = "[experiment]\ndataset = \"wt2\"\nrounds = 42\nbeta = 0.5\n";
        let map = toml::parse(text).unwrap();
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.dataset, DatasetKind::Wt2);
        assert_eq!(spec.rounds, 42);
        assert_eq!(spec.beta, 0.5);
        assert_eq!(spec.selection, SelectionSpec::Full);
    }

    #[test]
    fn toml_selection_overrides() {
        let text = "[experiment]\nselection = \"round-robin:2\"\n";
        let map = toml::parse(text).unwrap();
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.selection, SelectionSpec::RoundRobin(2));

        // Deprecated sample_k maps to random-K...
        let map = toml::parse("[experiment]\nsample_k = 4\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.selection, SelectionSpec::RandomK(4));

        // ...but an explicit `selection` key wins over it.
        let map =
            toml::parse("[experiment]\nsample_k = 4\nselection = \"loss-weighted:2\"\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.selection, SelectionSpec::LossWeighted(2));

        // An unknown spec is a hard error, not a silent full-cohort run.
        let map = toml::parse("[experiment]\nselection = \"random-k\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_network_overrides() {
        use crate::transport::scenario::{LinkPreset, StragglerPolicy};
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert!(spec.network.is_ideal());
        let text = "[experiment]\nnetwork = \"cellular:deadline=2,policy=late\"\n";
        let map = toml::parse(text).unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.network.preset, LinkPreset::Cellular);
        assert_eq!(spec.network.deadline_s, 2.0);
        assert_eq!(spec.network.policy, StragglerPolicy::AdmitLate);
        // The spec flows into the run config.
        assert_eq!(spec.run_config().network, spec.network);
        // An unknown network spec is a hard error, not a silent ideal
        // network.
        let map = toml::parse("[experiment]\nnetwork = \"tachyon\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_quant_sections_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert_eq!(spec.quant_sections, SectionSpec::Global);
        let map = toml::parse("[experiment]\nquant_sections = \"tensor\"\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.quant_sections, SectionSpec::Tensor);
        let map = toml::parse("[experiment]\nquant_sections = \"fixed:1024\"\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.quant_sections, SectionSpec::Fixed(1024));
        // The spec flows into the run config.
        assert_eq!(spec.run_config().quant_sections, SectionSpec::Fixed(1024));
        // An unknown spec is a hard error, not a silent global run.
        let map = toml::parse("[experiment]\nquant_sections = \"per-bit\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_dadaquant_schedule_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        // Defaults mirror the engine's historical hardcoded values.
        assert_eq!((spec.dadaquant_b0, spec.dadaquant_patience, spec.dadaquant_cap), (2, 3, 16));
        let cfg = spec.run_config();
        assert_eq!((cfg.dadaquant_b0, cfg.dadaquant_patience, cfg.dadaquant_cap), (2, 3, 16));
        let text = "[experiment]\ndadaquant_b0 = 4\ndadaquant_patience = 5\ndadaquant_cap = 8\n";
        let map = toml::parse(text).unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!((spec.dadaquant_b0, spec.dadaquant_patience, spec.dadaquant_cap), (4, 5, 8));
        let cfg = spec.run_config();
        assert_eq!((cfg.dadaquant_b0, cfg.dadaquant_patience, cfg.dadaquant_cap), (4, 5, 8));
        // Out-of-range values are hard errors (same contract as the
        // CLI flags), not silent clamps.
        let map = toml::parse("[experiment]\ndadaquant_b0 = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
        let map = toml::parse("[experiment]\ndadaquant_cap = 99\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
        let map = toml::parse("[experiment]\ndadaquant_patience = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_serve_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert_eq!(spec.serve, ServeSpec::default());
        let text = "[serve]\naddr = \"0.0.0.0:9000\"\nclients = 4\nheartbeat_ms = 100\n\
                    heartbeat_timeout_ms = 800\nround_timeout_ms = 5000\n\
                    accept_timeout_ms = 3000\n";
        let map = toml::parse(text).unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.serve.addr, "0.0.0.0:9000");
        assert_eq!(spec.serve.clients, 4);
        assert_eq!(spec.serve.heartbeat_ms, 100);
        assert_eq!(spec.serve.heartbeat_timeout_ms, 800);
        assert_eq!(spec.serve.round_timeout_ms, 5000);
        assert_eq!(spec.serve.accept_timeout_ms, 3000);
        // Out-of-range values are hard errors, not silent clamps.
        let map = toml::parse("[serve]\nclients = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
        let map = toml::parse("[serve]\nheartbeat_timeout_ms = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_chaos_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert!(!spec.chaos.is_enabled());
        let text = "[chaos]\ndrop = 0.1\ncorrupt = 0.05\nstall = 0.2\nstall_ms = 7\nseed = 99\n";
        let map = toml::parse(text).unwrap();
        spec.apply_toml(&map).unwrap();
        assert!(spec.chaos.is_enabled());
        assert_eq!(spec.chaos.drop_p, 0.1);
        assert_eq!(spec.chaos.corrupt_p, 0.05);
        assert_eq!(spec.chaos.stall_p, 0.2);
        assert_eq!(spec.chaos.stall_ms, 7);
        assert_eq!(spec.chaos.seed, 99);
        // Untouched kinds keep their defaults.
        assert_eq!(spec.chaos.dup_p, 0.0);
        // A probability outside [0, 1] is a hard error, not a clamp.
        let map = toml::parse("[chaos]\ndrop = 1.5\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
        let map = toml::parse("[chaos]\nstall_ms = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn flag_surface_toml_keys_are_consumed_by_apply_toml() {
        // Forward drift gate: every TOML counterpart the canonical
        // table advertises must actually be read by apply_toml —
        // either directly (`get("key")`) or as a nested table
        // (`"key.…"`).
        let src = include_str!("config.rs");
        for (flag, toml_key, _) in RUN_FLAG_SURFACE {
            if let Some(key) = toml_key {
                let direct = format!("get(\"{key}\")");
                let table = format!("\"{key}.");
                assert!(
                    src.contains(&direct) || src.contains(&table),
                    "--{flag}: advertised TOML key '{key}' is never consumed by apply_toml"
                );
            }
        }
    }

    #[test]
    fn apply_toml_keys_are_documented_in_flag_surface() {
        // Reverse drift gate: every key apply_toml consumes must be
        // either the TOML counterpart of a CLI flag (canonical table)
        // or a known file-only experiment key. Adding a key to
        // apply_toml without updating one of the two lists fails here.
        let surfaced: std::collections::BTreeSet<&str> =
            RUN_FLAG_SURFACE.iter().filter_map(|(_, k, _)| *k).collect();
        let toml_only = [
            "dataset", "split", "hetero", "devices", "rounds", "alpha", "beta", "seed",
            "data_scale", "sample_k",
        ];
        let src = include_str!("config.rs");
        let body = src
            .split("fn apply_toml")
            .nth(1)
            .and_then(|rest| rest.split("fn from_file").next())
            .expect("apply_toml body");
        let mut checked = 0;
        for part in body.split("get(\"").skip(1) {
            let key = part.split('"').next().unwrap_or("");
            let covered = surfaced.contains(key)
                || toml_only.contains(&key)
                || key.split('.').next().is_some_and(|table| surfaced.contains(table));
            assert!(
                covered,
                "apply_toml consumes '{key}' but neither RUN_FLAG_SURFACE nor the \
                 file-only key list documents it"
            );
            checked += 1;
        }
        assert!(checked > 20, "scrape found too few keys ({checked}) — pattern rot?");
    }

    #[test]
    fn row_labels() {
        let s = ExperimentSpec::new(DatasetKind::Wt2, SplitKind::IidLarge, false);
        assert_eq!(s.row_label(), "WT-2 IID-80");
    }

    #[test]
    fn toml_population_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert_eq!(spec.slot_policy(), SlotPolicy::Eager);
        assert_eq!(spec.effective_devices(), 10);
        let map = toml::parse("[experiment]\npopulation = 100000\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.population, Some(100_000));
        assert_eq!(spec.effective_devices(), 100_000);
        // A bare population defaults to the bounded lazy store...
        assert_eq!(spec.slot_policy(), SlotPolicy::Lazy { cache: 8192 });
        // ...and an explicit slot_cache overrides the capacity.
        let map = toml::parse("[experiment]\nslot_cache = 64\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.slot_policy(), SlotPolicy::Lazy { cache: 64 });
        assert_eq!(spec.run_config().slots, SlotPolicy::Lazy { cache: 64 });
        // The virtualized problem streams the requested device count.
        let p = spec.build_problem();
        assert_eq!(p.num_devices(), 100_000);
        // A non-positive population is a hard error.
        let map = toml::parse("[experiment]\npopulation = 0\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }

    #[test]
    fn toml_aggregation_overrides() {
        let mut spec = ExperimentSpec::new(DatasetKind::Cf10, SplitKind::Iid, false);
        assert_eq!(spec.aggregation, AggregationMode::Sync);
        // Compact spec string under [experiment].
        let map =
            toml::parse("[experiment]\naggregation = \"buffered:m=32,staleness=poly:0.5\"\n")
                .unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(
            spec.aggregation,
            AggregationMode::Buffered {
                m: 32,
                staleness: StalenessPolicy::Poly(0.5),
                max_inflight: 64,
            }
        );
        // The spec flows into the run config.
        assert_eq!(spec.run_config().aggregation, spec.aggregation);
        // [aggregation] table spelling, with defaults filled in.
        let map = toml::parse("[aggregation]\nm = 8\ninflight = 40\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(
            spec.aggregation,
            AggregationMode::Buffered {
                m: 8,
                staleness: StalenessPolicy::Constant(1.0),
                max_inflight: 40,
            }
        );
        // mode = "sync" switches back.
        let map = toml::parse("[aggregation]\nmode = \"sync\"\n").unwrap();
        spec.apply_toml(&map).unwrap();
        assert_eq!(spec.aggregation, AggregationMode::Sync);
        // Bad specs are hard errors, not silent sync runs.
        let map = toml::parse("[experiment]\naggregation = \"buffered:m=0\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
        let map = toml::parse("[aggregation]\nstaleness = \"poly:0.5\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err(), "buffered table without m must error");
        let map = toml::parse("[aggregation]\nmode = \"eventual\"\n").unwrap();
        assert!(spec.apply_toml(&map).is_err());
    }
}
