//! Layout-aware sectioning of quantized uploads.
//!
//! AQUILA's mid-tread quantizer (Definition 2) historically used **one**
//! range `R = ‖v‖_∞` for the whole upload, so a single outlier tensor
//! (e.g. a bias whose gradient runs 100× hotter than the weight
//! matrices) inflates the quantization step of every coordinate. This
//! module partitions the flat (gathered) parameter vector into
//! *sections*, each quantized with its own scale:
//!
//! * [`SectionSpec::Global`] — one section, today's behavior; wire
//!   payloads are **byte-identical** to the pre-sectioning format.
//! * [`SectionSpec::Tensor`] — one section per [`ParamLayout`] entry
//!   (per named tensor), the FedFQ-style layer granularity.
//! * [`SectionSpec::Fixed`]`(N)` — fixed `N`-element blocks, the
//!   block-wise granularity of the quantization literature.
//!
//! Sections are resolved **over the device's masked support**: under a
//! HeteroFL [`CapacityMask`] a tensor's section covers exactly the
//! support positions that fall inside that tensor's flat index range,
//! so heterogeneous devices quantize each (sub)tensor with its own
//! scale too. Resolution happens once per device at engine
//! construction; the resolved [`Sections`] ride in
//! `algorithms::DeviceState` and in the wire v2 section table
//! (`transport::wire`).

use crate::hetero::CapacityMask;
use crate::problems::ParamLayout;
use std::fmt;

/// Hard cap on sections per upload: the wire v2 header stores the
/// section count as a `u16`. [`SectionSpec::resolve`] widens fixed
/// block sizes as needed so the cap is never exceeded.
pub const MAX_SECTIONS: usize = u16::MAX as usize;

/// How to partition an upload vector into quantization sections.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SectionSpec {
    /// One section for the whole vector (the pre-sectioning behavior;
    /// wire payloads stay byte-identical to the v1 single-scale
    /// encoding).
    #[default]
    Global,
    /// One section per [`ParamLayout`] tensor.
    Tensor,
    /// Fixed-size blocks of the given element count (≥ 1).
    Fixed(usize),
}

impl SectionSpec {
    /// Accepted config syntax, shown by `repro list` and error messages.
    pub const SYNTAX: &'static str = "global | tensor | fixed:N";

    /// Parse a spec string: `global`, `tensor`, or `fixed:N` (N ≥ 1).
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "global" => Some(Self::Global),
            "tensor" | "layer" => Some(Self::Tensor),
            _ => {
                let n = s.strip_prefix("fixed:")?.parse::<usize>().ok()?;
                if n >= 1 {
                    Some(Self::Fixed(n))
                } else {
                    None
                }
            }
        }
    }

    /// Resolve the spec into concrete section boundaries over a
    /// device's gathered (mask-support) vector.
    ///
    /// * `Global` ignores the layout: one section of `mask.support()`.
    /// * `Tensor` intersects each layout entry's flat index range with
    ///   the mask's sorted support indices (empty intersections are
    ///   dropped); requires `layout.dim() == mask.full_dim`.
    /// * `Fixed(n)` tiles the support in `n`-element blocks, widening
    ///   `n` if needed so the block count stays within
    ///   [`MAX_SECTIONS`].
    pub fn resolve(&self, layout: &ParamLayout, mask: &CapacityMask) -> Sections {
        let support = mask.support();
        match *self {
            SectionSpec::Global => Sections::global(support),
            SectionSpec::Tensor => {
                assert_eq!(
                    layout.dim(),
                    mask.full_dim,
                    "layout dim {} != mask dim {}",
                    layout.dim(),
                    mask.full_dim
                );
                assert!(
                    layout.entries.len() <= MAX_SECTIONS,
                    "layout has more tensors than the wire section cap"
                );
                let lens = layout.entries.iter().map(|e| {
                    mask.support_in_range(e.offset, e.offset + e.numel())
                });
                Sections::from_lens(lens)
            }
            SectionSpec::Fixed(n) => {
                // Widen the block so the count fits the u16 wire field.
                let n = n.max(support.div_ceil(MAX_SECTIONS)).max(1);
                let full = support / n;
                let rem = support - full * n;
                let lens = std::iter::repeat_n(n, full).chain((rem > 0).then_some(rem));
                Sections::from_lens(lens)
            }
        }
    }
}

impl fmt::Display for SectionSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SectionSpec::Global => write!(f, "global"),
            SectionSpec::Tensor => write!(f, "tensor"),
            SectionSpec::Fixed(n) => write!(f, "fixed:{n}"),
        }
    }
}

/// Resolved section boundaries over a vector: a partition of
/// `0..total()` into `count()` contiguous non-empty ranges (except the
/// degenerate empty-vector case, which has one empty section so the
/// partition is never zero-length).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sections {
    /// Cumulative boundaries: `bounds[0] = 0`, `bounds[i]` is the start
    /// of section `i`, `bounds[count()] = total()`.
    bounds: Vec<u32>,
}

impl Sections {
    /// The single-section partition of an `n`-element vector.
    pub fn global(n: usize) -> Self {
        Self {
            bounds: vec![0, u32::try_from(n).expect("vector too large for wire")],
        }
    }

    /// Build from section lengths; zero-length sections are dropped.
    /// An empty (or all-zero) iterator yields the degenerate
    /// single-empty-section partition of a zero-length vector.
    pub fn from_lens<I: IntoIterator<Item = usize>>(lens: I) -> Self {
        let mut bounds = vec![0u32];
        let mut acc = 0usize;
        for len in lens {
            if len == 0 {
                continue;
            }
            acc += len;
            bounds.push(u32::try_from(acc).expect("vector too large for wire"));
        }
        if bounds.len() == 1 {
            bounds.push(0);
        }
        assert!(bounds.len() - 1 <= MAX_SECTIONS, "too many sections");
        Self { bounds }
    }

    /// Number of sections (≥ 1).
    pub fn count(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total element count covered.
    pub fn total(&self) -> usize {
        *self.bounds.last().unwrap() as usize
    }

    /// Whether this is the single-section (global) partition — in which
    /// case quantizers emit the v1 single-scale wire form.
    pub fn is_global(&self) -> bool {
        self.count() == 1
    }

    /// Element range of section `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.bounds[i] as usize..self.bounds[i + 1] as usize
    }

    /// Iterate the section ranges in order.
    pub fn iter(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.count()).map(|i| self.range(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ParamLayout {
        ParamLayout::contiguous(&[("w1", vec![8, 6]), ("b1", vec![8]), ("w2", vec![4, 8])])
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for (s, spec) in [
            ("global", SectionSpec::Global),
            ("tensor", SectionSpec::Tensor),
            ("fixed:1024", SectionSpec::Fixed(1024)),
        ] {
            assert_eq!(SectionSpec::parse(s), Some(spec));
            assert_eq!(spec.to_string(), s);
        }
        assert_eq!(SectionSpec::parse("layer"), Some(SectionSpec::Tensor));
        assert_eq!(SectionSpec::parse(" Fixed:2 "), Some(SectionSpec::Fixed(2)));
        assert_eq!(SectionSpec::parse("fixed:0"), None);
        assert_eq!(SectionSpec::parse("fixed:"), None);
        assert_eq!(SectionSpec::parse("blocks"), None);
        assert_eq!(SectionSpec::default(), SectionSpec::Global);
    }

    #[test]
    fn global_partition() {
        let l = layout();
        let mask = CapacityMask::full(l.dim());
        let s = SectionSpec::Global.resolve(&l, &mask);
        assert!(s.is_global());
        assert_eq!(s.count(), 1);
        assert_eq!(s.total(), l.dim());
        assert_eq!(s.range(0), 0..l.dim());
    }

    #[test]
    fn tensor_partition_full_mask() {
        let l = layout();
        let mask = CapacityMask::full(l.dim());
        let s = SectionSpec::Tensor.resolve(&l, &mask);
        assert_eq!(s.count(), 3);
        assert_eq!(s.range(0), 0..48);
        assert_eq!(s.range(1), 48..56);
        assert_eq!(s.range(2), 56..88);
        assert_eq!(s.total(), 88);
        assert!(!s.is_global());
    }

    #[test]
    fn tensor_partition_masked_support() {
        let l = layout();
        let mask = CapacityMask::from_layout(&l, 0.5);
        let s = SectionSpec::Tensor.resolve(&l, &mask);
        // w1: 4×3 = 12, b1: 4, w2: 2×4 = 8 (the from_layout halves).
        assert_eq!(s.count(), 3);
        assert_eq!(s.range(0).len(), 12);
        assert_eq!(s.range(1).len(), 4);
        assert_eq!(s.range(2).len(), 8);
        assert_eq!(s.total(), mask.support());
    }

    #[test]
    fn fixed_partition_tiles_support() {
        let l = layout();
        let mask = CapacityMask::full(l.dim()); // 88 elements
        let s = SectionSpec::Fixed(32).resolve(&l, &mask);
        assert_eq!(s.count(), 3);
        assert_eq!(s.range(0).len(), 32);
        assert_eq!(s.range(1).len(), 32);
        assert_eq!(s.range(2).len(), 24);
        assert_eq!(s.total(), 88);
        // A block size larger than the vector degenerates to global.
        assert!(SectionSpec::Fixed(1000).resolve(&l, &mask).is_global());
    }

    #[test]
    fn fixed_partition_respects_section_cap() {
        let l = ParamLayout::contiguous(&[("theta", vec![1_000_000])]);
        let mask = CapacityMask::full(l.dim());
        let s = SectionSpec::Fixed(1).resolve(&l, &mask);
        assert!(s.count() <= MAX_SECTIONS);
        assert_eq!(s.total(), 1_000_000);
    }

    #[test]
    fn empty_support_degenerates_to_one_empty_section() {
        let s = Sections::global(0);
        assert_eq!(s.count(), 1);
        assert_eq!(s.total(), 0);
        assert!(s.is_global());
        let s2 = Sections::from_lens([0usize, 0, 0]);
        assert_eq!(s2.count(), 1);
        assert_eq!(s2.total(), 0);
    }

    #[test]
    fn from_lens_drops_empty_sections() {
        let s = Sections::from_lens([3usize, 0, 5]);
        assert_eq!(s.count(), 2);
        assert_eq!(s.range(0), 0..3);
        assert_eq!(s.range(1), 3..8);
        let ranges: Vec<_> = s.iter().collect();
        assert_eq!(ranges, vec![0..3, 3..8]);
    }
}
