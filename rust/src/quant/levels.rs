//! Adaptive quantization-level selection rules.
//!
//! * [`aquila_level`] — the paper's closed-form optimum (Theorem 1,
//!   eq. 19), derived by minimizing the Lemma-1 model-deviation bound.
//! * [`adaquantfl_level`] — AdaQuantFL's global-loss rule
//!   (Jhunjhunwala et al., 2021), used by the `AdaQuantFL` and `LAdaQ`
//!   baselines.
//! * [`dadaquant_time_level`] — DAdaQuant's time-adaptive doubling rule
//!   (Hönig et al., 2022), used by the `DAdaQuant` baseline.

use super::midtread::MAX_BITS;

/// AQUILA's optimal quantization level (eq. 19):
///
/// ```text
/// b* = ceil( log₂( R·√d / ‖v‖₂ + 1 ) )
/// ```
///
/// where `v = ∇f_m(θᵏ) − q_m^{k−1}` is the gradient innovation,
/// `R = ‖v‖_∞`, and `d` the model dimension.
///
/// Self-consistency (Theorem 1 remark): since `R ≤ ‖v‖₂`, the argument
/// lies in `(1, √d + 1]`, hence `1 ≤ b* ≤ ceil(log₂(√d + 1))` with **no
/// clamping needed** — unlike e.g. DAdaQuant's `max(1, round(...))`.
///
/// Degenerate input `‖v‖₂ = 0` (zero innovation — nothing to transmit)
/// returns 1.
pub fn aquila_level(innov_l2: f64, innov_linf: f32, d: usize) -> u8 {
    debug_assert!(innov_l2 >= 0.0);
    if innov_l2 <= 0.0 || innov_linf <= 0.0 {
        return 1;
    }
    let ratio = innov_linf as f64 * (d as f64).sqrt() / innov_l2;
    let b = (ratio + 1.0).log2().ceil();
    // f64 rounding can yield 0.0 for ratios within 1 ulp above 0.
    (b.max(1.0) as u8).min(MAX_BITS)
}

/// Upper bound on the AQUILA level for dimension `d`:
/// `ceil(log₂(√d + 1))`. Tested as an invariant of [`aquila_level`].
pub fn aquila_level_upper_bound(d: usize) -> u8 {
    (((d as f64).sqrt() + 1.0).log2().ceil() as u8).max(1)
}

/// The optimal granularity `τ* = ‖v‖₂ / (R√d)` (eq. 20) prior to
/// integrality rounding — exposed for the theory tests which verify that
/// `b*` is the integer minimizer of the Lemma-1 deviation objective.
pub fn aquila_tau_star(innov_l2: f64, innov_linf: f32, d: usize) -> f64 {
    if innov_linf <= 0.0 {
        return 1.0;
    }
    (innov_l2 / (innov_linf as f64 * (d as f64).sqrt())).min(1.0)
}

/// AdaQuantFL: `b_k = floor( sqrt(f(θ⁰)/f(θᵏ)) · b₀ )`, clamped to
/// `[1, cap]`.
///
/// The paper's Section II criticism — that this grows without bound as
/// the loss decays (potentially past 32 bits) — is reproduced by the
/// baselines; `cap` defaults to 32 ("a floating point is represented by
/// 32 bits in our case").
pub fn adaquantfl_level(f0: f64, fk: f64, b0: u8, cap: u8) -> u8 {
    assert!(b0 >= 1);
    if !(fk > 0.0) || !(f0 > 0.0) {
        return cap;
    }
    let b = ((f0 / fk).sqrt() * b0 as f64).floor();
    (b.max(1.0) as u64).min(cap as u64) as u8
}

/// DAdaQuant's time-adaptive component: the level doubles each time the
/// running-best training loss stagnates for `patience` evaluations,
/// starting from `b0`. (Simplified faithful reimplementation of the
/// time-adaptation rule; the client-adaptation component lives in the
/// `DAdaQuant` baseline.)
#[derive(Clone, Debug)]
pub struct DadaquantSchedule {
    level: u8,
    best_loss: f64,
    stale: u32,
    patience: u32,
    cap: u8,
}

impl DadaquantSchedule {
    /// `b0` is clamped into `[1, cap]` (and `cap` to at least 1), so a
    /// misconfigured schedule can never start above its cap and then
    /// *shrink* on the first stagnation — the level sequence is always
    /// non-decreasing.
    pub fn new(b0: u8, patience: u32, cap: u8) -> Self {
        let cap = cap.max(1);
        Self {
            level: b0.clamp(1, cap),
            best_loss: f64::INFINITY,
            stale: 0,
            patience: patience.max(1),
            cap,
        }
    }

    /// Feed the current global loss estimate; returns the level to use.
    pub fn observe(&mut self, loss: f64) -> u8 {
        if loss < self.best_loss {
            self.best_loss = loss;
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.patience {
                self.level = (self.level.saturating_mul(2)).min(self.cap);
                self.stale = 0;
            }
        }
        self.level
    }

    /// Current level, without observing a new loss.
    pub fn level(&self) -> u8 {
        self.level
    }
}

/// DAdaQuant time-level convenience for tests.
pub fn dadaquant_time_level(sched: &mut DadaquantSchedule, loss: f64) -> u8 {
    sched.observe(loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::vecmath::l2sq_and_linf;

    #[test]
    fn aquila_level_at_least_one_never_clamped() {
        let mut rng = Xoshiro256pp::seed_from_u64(20);
        for _ in 0..200 {
            let d = 1 + rng.next_bounded(4096) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
            let (l2sq, linf) = l2sq_and_linf(&v);
            let b = aquila_level(l2sq.sqrt(), linf, d);
            assert!(b >= 1);
            assert!(
                b <= aquila_level_upper_bound(d),
                "b={b} exceeds bound for d={d}"
            );
        }
    }

    #[test]
    fn aquila_level_upper_bound_values() {
        // d = 1M -> sqrt(d) = 1000 -> ceil(log2(1001)) = 10.
        assert_eq!(aquila_level_upper_bound(1_000_000), 10);
        // d = 1 -> ceil(log2(2)) = 1.
        assert_eq!(aquila_level_upper_bound(1), 1);
        assert_eq!(aquila_level_upper_bound(16), 3); // ceil(log2(5)) = 3
    }

    #[test]
    fn aquila_degenerate_zero_innovation() {
        assert_eq!(aquila_level(0.0, 0.0, 100), 1);
    }

    #[test]
    fn aquila_spiky_vector_needs_more_bits() {
        // A one-hot innovation has R = ‖v‖₂ -> ratio √d -> max level;
        // a flat vector has R√d/‖v‖₂ = 1 -> b = 1.
        let d = 1024;
        let mut spiky = vec![0.0f32; d];
        spiky[7] = 3.0;
        let (l2sq, linf) = l2sq_and_linf(&spiky);
        let b_spiky = aquila_level(l2sq.sqrt(), linf, d);
        let flat = vec![0.5f32; d];
        let (l2sq_f, linf_f) = l2sq_and_linf(&flat);
        let b_flat = aquila_level(l2sq_f.sqrt(), linf_f, d);
        assert_eq!(b_flat, 1);
        assert_eq!(b_spiky, aquila_level_upper_bound(d));
        assert!(b_spiky > b_flat);
    }

    #[test]
    fn tau_star_in_unit_interval() {
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        for _ in 0..100 {
            let d = 2 + rng.next_bounded(1000) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let (l2sq, linf) = l2sq_and_linf(&v);
            let t = aquila_tau_star(l2sq.sqrt(), linf, d);
            assert!(t > 0.0 && t <= 1.0, "tau*={t}");
        }
    }

    #[test]
    fn adaquantfl_grows_as_loss_decays() {
        let b0 = 2;
        let f0 = 2.3;
        let early = adaquantfl_level(f0, 2.3, b0, 32);
        let mid = adaquantfl_level(f0, 0.5, b0, 32);
        let late = adaquantfl_level(f0, 0.01, b0, 32);
        assert_eq!(early, 2);
        assert!(mid > early);
        assert!(late > mid);
        // The pathology the paper calls out: level exceeds 32 without cap.
        assert_eq!(adaquantfl_level(f0, 1e-6, b0, 32), 32);
    }

    #[test]
    fn adaquantfl_degenerate_loss() {
        assert_eq!(adaquantfl_level(1.0, 0.0, 2, 32), 32);
        assert_eq!(adaquantfl_level(1.0, f64::NAN, 2, 32), 32);
    }

    #[test]
    fn dadaquant_schedule_clamps_b0_to_cap() {
        // b0 above the cap starts *at* the cap instead of overshooting
        // and shrinking on the first stagnation.
        let mut s = DadaquantSchedule::new(16, 2, 4);
        assert_eq!(s.level(), 4);
        assert_eq!(s.observe(1.0), 4);
        assert_eq!(s.observe(1.0), 4);
        assert_eq!(s.observe(1.0), 4);
        // A zero cap degrades to the minimum valid level.
        assert_eq!(DadaquantSchedule::new(3, 1, 0).level(), 1);
    }

    #[test]
    fn dadaquant_schedule_doubles_on_stagnation() {
        let mut s = DadaquantSchedule::new(1, 2, 16);
        assert_eq!(s.observe(1.0), 1);
        assert_eq!(s.observe(0.9), 1); // improving
        assert_eq!(s.observe(0.95), 1); // stale 1
        assert_eq!(s.observe(0.95), 2); // stale 2 -> double
        assert_eq!(s.observe(0.95), 2);
        assert_eq!(s.observe(0.95), 4);
        for _ in 0..20 {
            s.observe(1.0);
        }
        assert!(s.level() <= 16);
    }
}
