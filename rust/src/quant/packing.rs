//! Bit-packing of quantization codes into a wire byte stream.
//!
//! The communication costs reported by every table and figure are the
//! *actual serialized sizes* of what devices send, so the ψ vectors are
//! really packed at `b` bits per element (LSB-first within a little-endian
//! `u64` accumulator) rather than estimated as `d·b/8`.

/// Number of payload bytes for `n` codes at `bits` bits each.
#[inline]
pub const fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Pack `codes` (each `< 2^bits`) into a byte vector.
///
/// Codes are written LSB-first: code `i` occupies bit positions
/// `[i·b, (i+1)·b)` of the stream.
pub fn pack(codes: &[u32], bits: u8) -> Vec<u8> {
    assert!((1..=32).contains(&bits));
    let mut out = Vec::with_capacity(packed_len(codes.len(), bits));
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let b = bits as u32;
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    for &c in codes {
        debug_assert!((c as u64) <= mask, "code {c} exceeds {bits} bits");
        acc |= ((c as u64) & mask) << acc_bits;
        acc_bits += b;
        while acc_bits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    out
}

/// Unpack `n` codes of `bits` bits each from `bytes`.
pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    assert!(
        bytes.len() >= packed_len(n, bits),
        "byte stream too short: {} < {}",
        bytes.len(),
        packed_len(n, bits)
    );
    let mut out = Vec::with_capacity(n);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let b = bits as u32;
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    let mut iter = bytes.iter();
    for _ in 0..n {
        while acc_bits < b {
            acc |= (*iter.next().expect("length checked") as u64) << acc_bits;
            acc_bits += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= b;
        acc_bits -= b;
    }
    out
}

/// Pack a sign bitmap (1 bit per element, 1 = negative).
pub fn pack_signs(signs: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; signs.len().div_ceil(8)];
    for (i, &s) in signs.iter().enumerate() {
        if s {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Unpack a sign bitmap of `n` elements.
pub fn unpack_signs(bytes: &[u8], n: usize) -> Vec<bool> {
    assert!(bytes.len() >= n.div_ceil(8));
    (0..n).map(|i| bytes[i / 8] & (1 << (i % 8)) != 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        for bits in 1..=32u8 {
            let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
            let codes: Vec<u32> =
                (0..251).map(|_| (rng.next_u64() & mask) as u32).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(codes.len(), bits));
            let unpacked = unpack(&packed, bits, codes.len());
            assert_eq!(unpacked, codes, "bits={bits}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 3), 2); // 9 bits -> 2 bytes
        assert_eq!(packed_len(1000, 4), 500);
        assert_eq!(packed_len(0, 7), 0);
    }

    #[test]
    fn empty_roundtrip() {
        assert_eq!(pack(&[], 5), Vec::<u8>::new());
        assert_eq!(unpack(&[], 5, 0), Vec::<u32>::new());
    }

    #[test]
    fn boundary_codes() {
        for bits in [1u8, 7, 8, 9, 31, 32] {
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes = vec![0, max, 0, max, max];
            assert_eq!(unpack(&pack(&codes, bits), bits, 5), codes);
        }
    }

    #[test]
    fn known_layout() {
        // Two 4-bit codes 0xA, 0x5 -> single byte 0x5A (LSB-first).
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0x5A]);
        // Three 3-bit codes 1, 2, 4: code0 occupies stream bits 0–2
        // (bit0 = 1), code1 bits 3–5 (bit4 = 1), code2 bits 6–8
        // (bit8 = 1) ⇒ byte0 = 0b0001_0001 = 0x11, byte1 = 0x01.
        assert_eq!(pack(&[1, 2, 4], 3), vec![0x11, 0x01]);
    }

    #[test]
    fn signs_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let signs: Vec<bool> = (0..77).map(|_| rng.bernoulli(0.5)).collect();
        let packed = pack_signs(&signs);
        assert_eq!(packed.len(), 10);
        assert_eq!(unpack_signs(&packed, 77), signs);
    }

    #[test]
    #[should_panic]
    fn unpack_rejects_short_stream() {
        unpack(&[0u8; 3], 8, 4);
    }
}
