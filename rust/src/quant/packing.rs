//! Bit-packing of quantization codes into a wire byte stream.
//!
//! The communication costs reported by every table and figure are the
//! *actual serialized sizes* of what devices send, so the ψ vectors are
//! really packed at `b` bits per element (LSB-first within a little-endian
//! `u64` accumulator) rather than estimated as `d·b/8`.
//!
//! Layout invariant: code `i` occupies bit positions `[i·b, (i+1)·b)` of
//! the stream, bytes little-endian. Fixed-width codes therefore make any
//! sub-range O(1)-addressable — [`unpack_range`] and the streaming
//! [`for_each_code`] start mid-stream without touching earlier bytes,
//! which is what the shard-parallel server fold builds on (§Perf in
//! DESIGN.md). Both the packer and the unpackers move whole little-endian
//! `u64` words instead of single bytes.

use super::code_mask;

/// Number of payload bytes for `n` codes at `bits` bits each.
#[inline]
pub const fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Pack `codes` (each `< 2^bits`) into a byte vector.
///
/// Codes are written LSB-first: code `i` occupies bit positions
/// `[i·b, (i+1)·b)` of the stream.
pub fn pack(codes: &[u32], bits: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed_len(codes.len(), bits));
    pack_into(codes, bits, &mut out);
    out
}

/// Append the packed representation of `codes` to `out` (buffer-reusing
/// form of [`pack`]; the device hot path packs into a per-device wire
/// buffer that persists across rounds).
///
/// Thin wrapper over [`PackWriter`]: the accumulator flushes whole
/// little-endian `u64` words; only the final partial word is written
/// byte-wise.
pub fn pack_into(codes: &[u32], bits: u8, out: &mut Vec<u8>) {
    out.reserve(packed_len(codes.len(), bits));
    let mut w = PackWriter::new(out, bits);
    for &c in codes {
        w.push(c);
    }
    w.finish();
}

/// Word-streaming bit-packer: codes are pushed one at a time and whole
/// little-endian `u64` words are flushed to the output buffer as they
/// fill, so fused quantize kernels can emit packed bytes directly with
/// no intermediate `codes: Vec<u32>`.
///
/// The produced bytes are exactly those of [`pack_into`] (which is a
/// thin wrapper over this type). Dropping a writer without calling
/// [`PackWriter::finish`] loses the buffered partial word.
pub struct PackWriter<'a> {
    out: &'a mut Vec<u8>,
    b: u32,
    mask: u64,
    acc: u64,
    acc_bits: u32,
}

impl<'a> PackWriter<'a> {
    /// Start a packed stream appending to `out` at `bits` per code.
    #[inline]
    pub fn new(out: &'a mut Vec<u8>, bits: u8) -> Self {
        assert!((1..=32).contains(&bits));
        Self {
            out,
            b: bits as u32,
            mask: code_mask(bits),
            acc: 0,
            acc_bits: 0,
        }
    }

    /// Append one code to the stream.
    #[inline]
    pub fn push(&mut self, c: u32) {
        debug_assert!((c as u64) <= self.mask, "code {c} exceeds {} bits", self.b);
        let c = (c as u64) & self.mask;
        self.acc |= c << self.acc_bits;
        let filled = self.acc_bits + self.b;
        if filled >= 64 {
            self.out.extend_from_slice(&self.acc.to_le_bytes());
            self.acc_bits = filled - 64;
            // The high `acc_bits` bits of `c` did not fit in the flushed
            // word; `c >> b` is 0 when the code ended exactly on the
            // word boundary.
            self.acc = c >> (self.b - self.acc_bits);
        } else {
            self.acc_bits = filled;
        }
    }

    /// Flush the final partial word (if any) and end the stream.
    #[inline]
    pub fn finish(self) {
        if self.acc_bits > 0 {
            let tail = (self.acc_bits as usize).div_ceil(8);
            self.out.extend_from_slice(&self.acc.to_le_bytes()[..tail]);
        }
    }
}

/// Visit codes `start..end` of the packed stream in order, without
/// materializing a `Vec<u32>` — the core of the fused
/// dequantize–scatter kernels.
///
/// Each code is extracted with one unaligned little-endian `u64` load:
/// a code starts at most 7 bits into its first byte, so the ≤ 32 code
/// bits always sit inside one 8-byte window. Codes whose window would
/// run past the buffer (only possible within the last 7 bytes) fall
/// back to a zero-padded load.
#[inline]
pub fn for_each_code<F: FnMut(u32)>(bytes: &[u8], bits: u8, start: usize, end: usize, mut f: F) {
    assert!((1..=32).contains(&bits));
    assert!(start <= end, "bad code range {start}..{end}");
    assert!(
        bytes.len() >= packed_len(end, bits),
        "byte stream too short: {} < {}",
        bytes.len(),
        packed_len(end, bits)
    );
    let b = bits as usize;
    let mask = code_mask(bits);
    // Largest index whose 8-byte window fits: (i·b)/8 + 8 ≤ len.
    let fast_end = if bytes.len() >= 8 {
        end.min(((bytes.len() - 8) * 8 + 7) / b + 1)
    } else {
        start
    };
    let mut i = start;
    while i < fast_end {
        let bit = i * b;
        let w = u64::from_le_bytes(bytes[bit / 8..bit / 8 + 8].try_into().unwrap());
        f(((w >> (bit & 7)) & mask) as u32);
        i += 1;
    }
    while i < end {
        let bit = i * b;
        let byte = bit / 8;
        let mut buf = [0u8; 8];
        let avail = (bytes.len() - byte).min(8);
        buf[..avail].copy_from_slice(&bytes[byte..byte + avail]);
        let w = u64::from_le_bytes(buf);
        f(((w >> (bit & 7)) & mask) as u32);
        i += 1;
    }
}

/// Unpack the code sub-range `start..end` from `bytes`. Because codes
/// are fixed-width, the range is addressed directly at bit offset
/// `start·b` — no decode of the preceding codes.
pub fn unpack_range(bytes: &[u8], bits: u8, start: usize, end: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(end.saturating_sub(start));
    for_each_code(bytes, bits, start, end, |c| out.push(c));
    out
}

/// Unpack `n` codes of `bits` bits each from `bytes`.
pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u32> {
    unpack_range(bytes, bits, 0, n)
}

/// Pack a sign bitmap (1 bit per element, 1 = negative).
pub fn pack_signs(signs: &[bool]) -> Vec<u8> {
    let mut out = Vec::with_capacity(signs.len().div_ceil(8));
    pack_signs_into(signs, &mut out);
    out
}

/// Append a packed sign bitmap to `out` (buffer-reusing form).
pub fn pack_signs_into(signs: &[bool], out: &mut Vec<u8>) {
    let base = out.len();
    out.resize(base + signs.len().div_ceil(8), 0);
    let bitmap = &mut out[base..];
    for (i, &s) in signs.iter().enumerate() {
        if s {
            bitmap[i / 8] |= 1 << (i % 8);
        }
    }
}

/// Read sign bit `i` of a packed sign bitmap.
#[inline]
pub fn sign_at(bytes: &[u8], i: usize) -> bool {
    bytes[i / 8] & (1 << (i % 8)) != 0
}

/// Unpack a sign bitmap of `n` elements.
pub fn unpack_signs(bytes: &[u8], n: usize) -> Vec<bool> {
    assert!(bytes.len() >= n.div_ceil(8));
    (0..n).map(|i| sign_at(bytes, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        for bits in 1..=32u8 {
            let mask = code_mask(bits);
            let codes: Vec<u32> =
                (0..251).map(|_| (rng.next_u64() & mask) as u32).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(codes.len(), bits));
            let unpacked = unpack(&packed, bits, codes.len());
            assert_eq!(unpacked, codes, "bits={bits}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 3), 2); // 9 bits -> 2 bytes
        assert_eq!(packed_len(1000, 4), 500);
        assert_eq!(packed_len(0, 7), 0);
    }

    #[test]
    fn empty_roundtrip() {
        assert_eq!(pack(&[], 5), Vec::<u8>::new());
        assert_eq!(unpack(&[], 5, 0), Vec::<u32>::new());
    }

    #[test]
    fn boundary_codes() {
        for bits in [1u8, 7, 8, 9, 31, 32] {
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes = vec![0, max, 0, max, max];
            assert_eq!(unpack(&pack(&codes, bits), bits, 5), codes);
        }
    }

    #[test]
    fn known_layout() {
        // Two 4-bit codes 0xA, 0x5 -> single byte 0x5A (LSB-first).
        assert_eq!(pack(&[0xA, 0x5], 4), vec![0x5A]);
        // Three 3-bit codes 1, 2, 4: code0 occupies stream bits 0–2
        // (bit0 = 1), code1 bits 3–5 (bit4 = 1), code2 bits 6–8
        // (bit8 = 1) ⇒ byte0 = 0b0001_0001 = 0x11, byte1 = 0x01.
        assert_eq!(pack(&[1, 2, 4], 3), vec![0x11, 0x01]);
    }

    #[test]
    fn range_matches_full_unpack() {
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        for bits in [1u8, 3, 4, 7, 8, 13, 17, 32] {
            let n = 513;
            let mask = code_mask(bits);
            let codes: Vec<u32> =
                (0..n).map(|_| (rng.next_u64() & mask) as u32).collect();
            let packed = pack(&codes, bits);
            for (start, end) in [(0, n), (1, n), (0, n - 1), (17, 400), (n, n), (n - 3, n)] {
                assert_eq!(
                    unpack_range(&packed, bits, start, end),
                    codes[start..end],
                    "bits={bits} range={start}..{end}"
                );
            }
        }
    }

    #[test]
    fn pack_into_appends() {
        let mut buf = vec![0xEEu8];
        pack_into(&[0xA, 0x5], 4, &mut buf);
        assert_eq!(buf, vec![0xEE, 0x5A]);
    }

    #[test]
    fn signs_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let signs: Vec<bool> = (0..77).map(|_| rng.bernoulli(0.5)).collect();
        let packed = pack_signs(&signs);
        assert_eq!(packed.len(), 10);
        assert_eq!(unpack_signs(&packed, 77), signs);
        for (i, &s) in signs.iter().enumerate() {
            assert_eq!(sign_at(&packed, i), s);
        }
    }

    #[test]
    #[should_panic]
    fn unpack_rejects_short_stream() {
        unpack(&[0u8; 3], 8, 4);
    }

    #[test]
    #[should_panic]
    fn range_rejects_short_stream() {
        // end = 4 needs 4 bytes even if the range itself is small.
        unpack_range(&[0u8; 3], 8, 3, 4);
    }
}
