//! Deterministic mid-tread quantizer (paper Definition 2, Lemma 4).
//!
//! Every element of a vector `v` (in AQUILA, the *gradient innovation*
//! `∇f_m(θᵏ) − q_m^{k−1}`) is mapped to an unsigned integer
//!
//! ```text
//! ψᵢ = floor( (vᵢ + R) / (2τR) + 1/2 ),   R = ‖v‖_∞,  τ = 1/(2^b − 1)
//! ```
//!
//! and reconstructed (Lemma 4) as
//!
//! ```text
//! Δqᵢ = 2τR·ψᵢ − R .
//! ```
//!
//! Properties verified by the tests below and by property tests in
//! `rust/tests/prop_quant.rs`:
//!
//! * `ψᵢ ∈ [0, 2^b − 1]` — every code fits in `b` bits;
//! * the reconstruction error obeys `|vᵢ − Δqᵢ| ≤ τR` per element
//!   (mid-tread rounding to the nearest grid point);
//! * `R = 0` (zero innovation) round-trips to the zero vector.
//!
//! Figure 1 of the paper (`Q(2.4) = 2` at step Ω = 1) corresponds to the
//! simplified mid-tread map; see `figure1_example` in the tests.
//!
//! This Rust implementation is the L3 production hot path; it is
//! bit-compatible with the L1 Pallas kernel
//! (`python/compile/kernels/aquila_quant.py`) — parity is asserted by the
//! `hlo_parity` integration test when artifacts are built.

/// Maximum supported quantization level. `ψ` is stored in `u32`; levels
/// this high are never selected by AQUILA (eq. 19 bounds `b* ≤
/// ceil(log2(√d + 1))`) but fixed-level baselines may request them.
pub const MAX_BITS: u8 = 32;

/// A quantized vector: the on-the-wire representation of a gradient
/// innovation before bit-packing.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedVec {
    /// Quantization level `b` (bits per element), `1 ..= MAX_BITS`.
    pub bits: u8,
    /// Quantization range `R = ‖v‖_∞` at quantization time. For
    /// sectioned vectors this is the *global* `‖v‖_∞` (the max section
    /// scale), kept for metrics; reconstruction uses `section_scales`.
    pub range: f32,
    /// Integer codes, each in `[0, 2^b − 1]`.
    pub psi: Vec<u32>,
    /// Per-section `(scale, len)` pairs (`crate::quant::sections`;
    /// serialized as the wire v2 section table). Empty = single global
    /// `range` — the v1 wire form.
    pub section_scales: Vec<(f32, u32)>,
}

impl QuantizedVec {
    /// Quantization granularity `τ = 1/(2^b − 1)`.
    #[inline]
    pub fn tau(&self) -> f64 {
        tau(self.bits)
    }

    /// Dimension of the underlying vector.
    #[inline]
    pub fn dim(&self) -> usize {
        self.psi.len()
    }

    /// Whether this vector carries per-section scales (wire v2).
    #[inline]
    pub fn is_sectioned(&self) -> bool {
        !self.section_scales.is_empty()
    }

    /// An all-zero quantization (used for `q_m^{-1} = 0` at round 0).
    pub fn zeros(bits: u8, d: usize) -> Self {
        Self {
            bits,
            range: 0.0,
            psi: vec![0; d],
            section_scales: Vec::new(),
        }
    }
}

/// `τ = 1/(2^b − 1)` in f64 (exact for all `b ≤ 32`).
#[inline]
pub fn tau(bits: u8) -> f64 {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    1.0 / (((1u64 << bits) - 1) as f64)
}

/// Quantize `v` at level `bits` with range `R = ‖v‖_∞` (Definition 2).
pub fn quantize(v: &[f32], bits: u8) -> QuantizedVec {
    quantize_buf(v, bits, Vec::new())
}

/// Buffer-reusing form of [`quantize`] (see
/// [`quantize_with_range_into`]).
pub fn quantize_buf(v: &[f32], bits: u8, psi: Vec<u32>) -> QuantizedVec {
    let range = crate::util::vecmath::norm_inf(v);
    quantize_with_range_into(v, bits, range, psi)
}

/// Quantize with an externally supplied range (the range of the
/// innovation is usually already known from the fused norm pass).
pub fn quantize_with_range(v: &[f32], bits: u8, range: f32) -> QuantizedVec {
    quantize_with_range_into(v, bits, range, Vec::new())
}

/// Buffer-reusing form of [`quantize_with_range`]: `psi` is cleared and
/// refilled, keeping its capacity (the coordinator recycles each
/// device's code buffer across rounds — §Perf).
pub fn quantize_with_range_into(v: &[f32], bits: u8, range: f32, mut psi: Vec<u32>) -> QuantizedVec {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    psi.clear();
    psi.reserve(v.len());
    quantize_slice_append(v, bits, range, &mut psi);
    QuantizedVec {
        bits,
        range,
        psi,
        section_scales: Vec::new(),
    }
}

/// Destination for quantized codes: either the legacy `psi: Vec<u32>`
/// or a word-streaming [`crate::quant::packing::PackWriter`]. The
/// quantize cores are generic over this, so the unpacked and the fused
/// quantize→pack paths share one arithmetic path and are bit-identical
/// by construction (the dedup point of the former `quantize*_append`
/// wrapper ladder).
trait CodeSink {
    fn put(&mut self, code: u32);
    fn put_zeros(&mut self, n: usize);
}

impl CodeSink for Vec<u32> {
    #[inline(always)]
    fn put(&mut self, code: u32) {
        self.push(code);
    }

    #[inline]
    fn put_zeros(&mut self, n: usize) {
        self.resize(self.len() + n, 0);
    }
}

impl CodeSink for crate::quant::packing::PackWriter<'_> {
    #[inline(always)]
    fn put(&mut self, code: u32) {
        self.push(code);
    }

    #[inline]
    fn put_zeros(&mut self, n: usize) {
        for _ in 0..n {
            self.push(0);
        }
    }
}

/// Quantize one slice at an externally supplied range, *appending* its
/// codes to `psi` — the shared core of the global and sectioned
/// quantizers. Arithmetic is exactly Definition 2, unchanged from the
/// pre-sectioning implementation (so `global` wire payloads stay
/// byte-identical).
fn quantize_slice_append(v: &[f32], bits: u8, range: f32, psi: &mut Vec<u32>) {
    quantize_slice_sink(v, bits, range, psi);
}

/// Sink-generic core of [`quantize_slice_append`]; the fused packed
/// quantizers call it with a [`crate::quant::packing::PackWriter`].
fn quantize_slice_sink<S: CodeSink>(v: &[f32], bits: u8, range: f32, sink: &mut S) {
    assert!(range >= 0.0 && range.is_finite(), "range must be finite ≥ 0");
    if range == 0.0 {
        sink.put_zeros(v.len());
        return;
    }
    let max_code = crate::quant::max_code(bits);
    if bits <= 12 {
        // f32 fast path — must stay bit-identical to
        // `quantize_innovation_fused` (§Perf).
        let t32 = tau(bits) as f32;
        let inv_step = 1.0 / (2.0 * t32 * range);
        let maxc = max_code as f32;
        for &x in v {
            let code = ((x + range) * inv_step + 0.5).floor().clamp(0.0, maxc);
            sink.put(code as u32);
        }
    } else {
        let t = tau(bits);
        // 1 / (2τR): hoisted out of the loop; f64 so b near 32 stays
        // exact.
        let inv_step = 1.0 / (2.0 * t * range as f64);
        for &x in v {
            let code = ((x as f64 + range as f64) * inv_step + 0.5).floor();
            // Clamp guards the pathological case |vᵢ| marginally above R
            // due to an externally supplied range; with R = ‖v‖_∞ it
            // never fires.
            let code = code.clamp(0.0, max_code as f64) as u32;
            sink.put(code);
        }
    }
}

/// Section-aware [`quantize`]: one range `R_s = ‖v_s‖_∞` per section
/// of `sections` (Definition 2 applied per section). Codes still use
/// one `bits` level for the whole payload; only the scales vary. A
/// single-section partition produces the plain global form —
/// byte-identical on the wire to [`quantize`].
pub fn quantize_sections(v: &[f32], bits: u8, sections: &crate::quant::Sections) -> QuantizedVec {
    quantize_sections_buf(v, bits, sections, Vec::new())
}

/// Buffer-reusing form of [`quantize_sections`] (see
/// [`quantize_with_range_into`] for the recycling contract).
pub fn quantize_sections_buf(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    mut psi: Vec<u32>,
) -> QuantizedVec {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    assert_eq!(sections.total(), v.len(), "sections must cover the vector");
    if sections.is_global() {
        return quantize_buf(v, bits, psi);
    }
    psi.clear();
    psi.reserve(v.len());
    let mut scales = Vec::with_capacity(sections.count());
    let mut range = 0.0f32;
    for r in sections.iter() {
        let slice = &v[r.clone()];
        let rs = crate::util::vecmath::norm_inf(slice);
        quantize_slice_append(slice, bits, rs, &mut psi);
        scales.push((rs, r.len() as u32));
        range = range.max(rs);
    }
    QuantizedVec {
        bits,
        range,
        psi,
        section_scales: scales,
    }
}

/// Reconstruct `Δq` per Lemma 4: `Δqᵢ = 2τR·ψᵢ − R` (with the
/// section's own `R` for sectioned vectors).
pub fn dequantize_into(q: &QuantizedVec, out: &mut [f32]) {
    assert_eq!(q.psi.len(), out.len());
    if q.is_sectioned() {
        let mut off = 0usize;
        for &(scale, len) in &q.section_scales {
            let len = len as usize;
            dequantize_slice(&q.psi[off..off + len], q.bits, scale, &mut out[off..off + len]);
            off += len;
        }
        debug_assert_eq!(off, out.len());
        return;
    }
    dequantize_slice(&q.psi, q.bits, q.range, out);
}

/// Lemma-4 reconstruction of one slice at one scale — shared by the
/// global and sectioned [`dequantize_into`] paths.
fn dequantize_slice(psi: &[u32], bits: u8, range: f32, out: &mut [f32]) {
    if range == 0.0 {
        out.fill(0.0);
        return;
    }
    let step = 2.0 * tau(bits) * range as f64;
    let r = range as f64;
    for (o, &code) in out.iter_mut().zip(psi) {
        *o = (step * code as f64 - r) as f32;
    }
}

/// Reconstruct into a fresh vector.
pub fn dequantize(q: &QuantizedVec) -> Vec<f32> {
    let mut out = vec![0.0f32; q.psi.len()];
    dequantize_into(q, &mut out);
    out
}

/// Fused server-side kernel (§Perf): reconstruct codes `codes.start..
/// codes.end` straight from the *packed* wire body and scatter-add
/// `scale · Δqᵢ` into one contiguous output shard — no ψ vector and no
/// dense scratch are ever materialized.
///
/// `targets` maps code position → full-model coordinate (`None` =
/// identity, the full-capacity fast path); `out` is the shard slice
/// `direction[out_base .. out_base + out.len()]`, so every touched
/// coordinate must satisfy `out_base ≤ idx < out_base + out.len()` —
/// the caller selects `codes` accordingly (contiguous because mask
/// indices are sorted).
///
/// Per-element arithmetic is exactly [`dequantize_into`] followed by
/// `out += scale · Δq` and is independent of shard boundaries, which is
/// what makes the shard-parallel fold bit-identical to the serial one.
#[allow(clippy::too_many_arguments)]
pub fn dequantize_scatter_add(
    body: &[u8],
    bits: u8,
    range: f32,
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    if codes.is_empty() || range == 0.0 {
        // Δq ≡ 0 at range 0 (Lemma 4 reconstruction collapses to −R = 0).
        return;
    }
    let step = 2.0 * tau(bits) * range as f64;
    let r = range as f64;
    match targets {
        None => {
            let mut j = codes.start - out_base;
            crate::quant::packing::for_each_code(body, bits, codes.start, codes.end, |c| {
                out[j] += scale * ((step * c as f64 - r) as f32);
                j += 1;
            });
        }
        Some(idx) => {
            let mut p = codes.start;
            crate::quant::packing::for_each_code(body, bits, codes.start, codes.end, |c| {
                out[idx[p] as usize - out_base] += scale * ((step * c as f64 - r) as f32);
                p += 1;
            });
        }
    }
}

/// Result of the fused quantize pass used on the AQUILA device hot path.
#[derive(Clone, Debug)]
pub struct QuantizeOutcome {
    /// Wire representation of the innovation.
    pub quantized: QuantizedVec,
    /// `‖Δq‖₂²` — LHS term 1 of the skip criterion (eq. 8).
    pub dq_norm_sq: f64,
    /// `‖ε‖₂² = ‖v − Δq‖₂²` — LHS term 2 of the skip criterion.
    pub err_norm_sq: f64,
}

/// Fused device-step quantization: quantize the implicit innovation
/// `v = g − q_prev` (never materialized), reconstruct `Δq` into
/// `dq_out`, and accumulate the two norms the skip rule needs — all in a
/// single traversal. This mirrors pass 2 of the L1 Pallas kernel.
pub fn quantize_innovation_fused(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
) -> QuantizeOutcome {
    quantize_innovation_fused_buf(g, q_prev, bits, range, dq_out, Vec::new())
}

/// Buffer-reusing form of [`quantize_innovation_fused`]: `psi` is
/// cleared and refilled with the codes (keeping its capacity) and ends
/// up owned by the returned [`QuantizedVec`]. The device hot path hands
/// in its recycled per-device code buffer so the quantize step performs
/// zero allocations in steady state.
pub fn quantize_innovation_fused_buf(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    mut psi: Vec<u32>,
) -> QuantizeOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert!((1..=MAX_BITS).contains(&bits));
    psi.clear();
    psi.reserve(g.len());
    let (dq_norm_sq, err_norm_sq) =
        fused_quantize_slice_append(g, q_prev, bits, range, dq_out, &mut psi);
    QuantizeOutcome {
        quantized: QuantizedVec {
            bits,
            range,
            psi,
            section_scales: Vec::new(),
        },
        dq_norm_sq,
        err_norm_sq,
    }
}

/// Section-aware [`quantize_innovation_fused_buf`]: quantize the
/// implicit innovation `v = g − q_prev` with one externally supplied
/// range per section (`ranges[i]` for `sections.range(i)` — usually the
/// per-section `‖v_s‖_∞` from the fused norm pass). Returns the summed
/// `‖Δq‖₂²` / `‖ε‖₂²` across sections, so AQUILA's eq. 8 skip rule is
/// evaluated on the whole upload exactly as in the global case. A
/// single-section partition delegates to the global path and produces
/// byte-identical wire payloads.
pub fn quantize_innovation_fused_sections_buf(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    ranges: &[f32],
    sections: &crate::quant::Sections,
    dq_out: &mut [f32],
    mut psi: Vec<u32>,
) -> QuantizeOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert_eq!(sections.total(), g.len(), "sections must cover the vector");
    assert_eq!(ranges.len(), sections.count(), "one range per section");
    assert!((1..=MAX_BITS).contains(&bits));
    if sections.is_global() {
        return quantize_innovation_fused_buf(g, q_prev, bits, ranges[0], dq_out, psi);
    }
    psi.clear();
    psi.reserve(g.len());
    let mut dq_norm_sq = 0.0f64;
    let mut err_norm_sq = 0.0f64;
    let mut scales = Vec::with_capacity(sections.count());
    let mut range = 0.0f32;
    for (i, r) in sections.iter().enumerate() {
        let (a, b) = fused_quantize_slice_append(
            &g[r.clone()],
            &q_prev[r.clone()],
            bits,
            ranges[i],
            &mut dq_out[r.clone()],
            &mut psi,
        );
        dq_norm_sq += a;
        err_norm_sq += b;
        scales.push((ranges[i], r.len() as u32));
        range = range.max(ranges[i]);
    }
    QuantizeOutcome {
        quantized: QuantizedVec {
            bits,
            range,
            psi,
            section_scales: scales,
        },
        dq_norm_sq,
        err_norm_sq,
    }
}

/// The fused quantize pass over one slice at one range, *appending*
/// codes to `psi` and returning `(‖Δq‖₂², ‖ε‖₂²)` for the slice — the
/// shared core of the global and sectioned device steps. Per-element
/// arithmetic is unchanged from the pre-sectioning implementation.
fn fused_quantize_slice_append(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    psi: &mut Vec<u32>,
) -> (f64, f64) {
    fused_quantize_slice_sink(g, q_prev, bits, range, dq_out, psi)
}

/// Sink-generic core of [`fused_quantize_slice_append`]: one traversal
/// computes codes, reconstructs `Δq`, and accumulates the two skip-rule
/// norms, emitting codes into either a `psi` vector or a word-streaming
/// [`crate::quant::packing::PackWriter`]. One arithmetic path for both
/// sinks means the packed and unpacked forms agree bitwise (codes,
/// norms, `dq_out`) by construction.
fn fused_quantize_slice_sink<S: CodeSink>(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    sink: &mut S,
) -> (f64, f64) {
    let d = g.len();
    if range == 0.0 {
        sink.put_zeros(d);
        dq_out.fill(0.0);
        // ε = v − 0 = v; with range 0 the innovation is exactly zero.
        return (0.0, 0.0);
    }
    let max_code = crate::quant::max_code(bits);
    let mut dq_norm_sq = 0.0f64;
    let mut err_norm_sq = 0.0f64;
    if bits <= 12 {
        // Fast path (§Perf): all arithmetic in f32. Codes ≤ 4095 are
        // exact in f32, and this is precisely the arithmetic the L1
        // Pallas kernel performs (jax f32), so parity *improves*. The
        // loop auto-vectorizes (~4× over the f64 path).
        let t32 = tau(bits) as f32;
        let step = 2.0 * t32 * range;
        let inv_step = 1.0 / step;
        let maxc = max_code as f32;
        // Four independent accumulator lanes break the f64-add
        // dependency chain (§Perf iteration 2: +25% on d = 1M).
        let mut dq_acc = [0.0f64; 4];
        let mut err_acc = [0.0f64; 4];
        for i in 0..d {
            let v = g[i] - q_prev[i];
            let code = ((v + range) * inv_step + 0.5).floor().clamp(0.0, maxc);
            let dq = step * code - range;
            let err = v - dq;
            let lane = i & 3;
            dq_acc[lane] += (dq as f64) * (dq as f64);
            err_acc[lane] += (err as f64) * (err as f64);
            dq_out[i] = dq;
            sink.put(code as u32);
        }
        dq_norm_sq = dq_acc.iter().sum();
        err_norm_sq = err_acc.iter().sum();
    } else {
        // High-precision path: codes up to 2³² − 1 need f64.
        let t = tau(bits);
        let rf = range as f64;
        let step = 2.0 * t * rf;
        let inv_step = 1.0 / step;
        for i in 0..d {
            let v = (g[i] - q_prev[i]) as f64;
            let code = ((v + rf) * inv_step + 0.5).floor().clamp(0.0, max_code as f64) as u32;
            let dq = step * code as f64 - rf;
            let err = v - dq;
            dq_norm_sq += dq * dq;
            err_norm_sq += err * err;
            dq_out[i] = dq as f32;
            sink.put(code);
        }
    }
    (dq_norm_sq, err_norm_sq)
}

/// Result of the fused quantize→pack device kernels: the packed wire
/// form of the innovation plus the two norms the skip rule needs.
#[derive(Clone, Debug)]
pub struct PackedOutcome {
    /// Packed wire representation of the innovation.
    pub packed: crate::quant::PackedVec,
    /// `‖Δq‖₂²` — LHS term 1 of the skip criterion (eq. 8).
    pub dq_norm_sq: f64,
    /// `‖ε‖₂² = ‖v − Δq‖₂²` — LHS term 2 of the skip criterion.
    pub err_norm_sq: f64,
}

/// Fused quantize→pack device step (§Perf): quantize the implicit
/// innovation `v = g − q_prev`, reconstruct `Δq` into `dq_out`,
/// accumulate the two skip-rule norms, and emit the packed
/// little-endian wire body — all in one traversal, with no intermediate
/// `codes: Vec<u32>`. It shares its per-element arithmetic and
/// norm-accumulation order with [`quantize_innovation_fused`] (one
/// sink-generic core), so norms and `dq_out` agree *bitwise* with the
/// unpacked path and the body bytes equal
/// `packing::pack_into(&psi, bits, ..)` over the unpacked codes.
pub fn quantize_innovation_packed(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
) -> PackedOutcome {
    quantize_innovation_packed_buf(g, q_prev, bits, range, dq_out, Vec::new())
}

/// Buffer-reusing form of [`quantize_innovation_packed`]: `body` is
/// cleared and refilled (keeping its capacity) and ends up owned by the
/// returned [`crate::quant::PackedVec`], so the device hot path
/// performs zero allocations in steady state.
pub fn quantize_innovation_packed_buf(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    mut body: Vec<u8>,
) -> PackedOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert!((1..=MAX_BITS).contains(&bits));
    body.clear();
    body.reserve(crate::quant::packing::packed_len(g.len(), bits));
    let mut w = crate::quant::packing::PackWriter::new(&mut body, bits);
    let (dq_norm_sq, err_norm_sq) = fused_quantize_slice_sink(g, q_prev, bits, range, dq_out, &mut w);
    w.finish();
    debug_assert_eq!(body.len(), crate::quant::packing::packed_len(g.len(), bits));
    PackedOutcome {
        packed: crate::quant::PackedVec {
            bits,
            scale: range,
            len: u32::try_from(g.len()).expect("vector too large for wire"),
            body,
            section_scales: Vec::new(),
        },
        dq_norm_sq,
        err_norm_sq,
    }
}

/// Section-aware [`quantize_innovation_packed_buf`]: one externally
/// supplied range per section, one continuous packed bit stream across
/// sections (exactly what `pack_into` over the concatenated ψ would
/// produce), and summed skip-rule norms. A single-section partition
/// delegates to the global path — byte-identical v1 wire form.
#[allow(clippy::too_many_arguments)]
pub fn quantize_innovation_packed_sections_buf(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    ranges: &[f32],
    sections: &crate::quant::Sections,
    dq_out: &mut [f32],
    mut body: Vec<u8>,
) -> PackedOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert_eq!(sections.total(), g.len(), "sections must cover the vector");
    assert_eq!(ranges.len(), sections.count(), "one range per section");
    assert!((1..=MAX_BITS).contains(&bits));
    if sections.is_global() {
        return quantize_innovation_packed_buf(g, q_prev, bits, ranges[0], dq_out, body);
    }
    body.clear();
    body.reserve(crate::quant::packing::packed_len(g.len(), bits));
    let mut dq_norm_sq = 0.0f64;
    let mut err_norm_sq = 0.0f64;
    let mut scales = Vec::with_capacity(sections.count());
    let mut range = 0.0f32;
    let mut w = crate::quant::packing::PackWriter::new(&mut body, bits);
    for (i, r) in sections.iter().enumerate() {
        let (a, b) = fused_quantize_slice_sink(
            &g[r.clone()],
            &q_prev[r.clone()],
            bits,
            ranges[i],
            &mut dq_out[r.clone()],
            &mut w,
        );
        dq_norm_sq += a;
        err_norm_sq += b;
        scales.push((ranges[i], r.len() as u32));
        range = range.max(ranges[i]);
    }
    w.finish();
    PackedOutcome {
        packed: crate::quant::PackedVec {
            bits,
            scale: range,
            len: u32::try_from(g.len()).expect("vector too large for wire"),
            body,
            section_scales: scales,
        },
        dq_norm_sq,
        err_norm_sq,
    }
}

/// Fused quantize→pack of a *full* vector at `R = ‖v‖_∞` — the packed
/// counterpart of [`quantize_buf`], used by the full-gradient
/// algorithms (AdaQuantFL, DAdaQuant).
pub fn quantize_packed_buf(v: &[f32], bits: u8, body: Vec<u8>) -> crate::quant::PackedVec {
    let range = crate::util::vecmath::norm_inf(v);
    quantize_with_range_packed_buf(v, bits, range, body)
}

/// Packed counterpart of [`quantize_with_range_into`].
pub fn quantize_with_range_packed_buf(
    v: &[f32],
    bits: u8,
    range: f32,
    mut body: Vec<u8>,
) -> crate::quant::PackedVec {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    body.clear();
    body.reserve(crate::quant::packing::packed_len(v.len(), bits));
    let mut w = crate::quant::packing::PackWriter::new(&mut body, bits);
    quantize_slice_sink(v, bits, range, &mut w);
    w.finish();
    crate::quant::PackedVec {
        bits,
        scale: range,
        len: u32::try_from(v.len()).expect("vector too large for wire"),
        body,
        section_scales: Vec::new(),
    }
}

/// Packed counterpart of [`quantize_sections_buf`]: per-section
/// `R_s = ‖v_s‖_∞` scales, one continuous packed stream. A
/// single-section partition delegates to [`quantize_packed_buf`].
pub fn quantize_sections_packed_buf(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    mut body: Vec<u8>,
) -> crate::quant::PackedVec {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    assert_eq!(sections.total(), v.len(), "sections must cover the vector");
    if sections.is_global() {
        return quantize_packed_buf(v, bits, body);
    }
    body.clear();
    body.reserve(crate::quant::packing::packed_len(v.len(), bits));
    let mut scales = Vec::with_capacity(sections.count());
    let mut range = 0.0f32;
    let mut w = crate::quant::packing::PackWriter::new(&mut body, bits);
    for r in sections.iter() {
        let slice = &v[r.clone()];
        let rs = crate::util::vecmath::norm_inf(slice);
        quantize_slice_sink(slice, bits, rs, &mut w);
        scales.push((rs, r.len() as u32));
        range = range.max(rs);
    }
    w.finish();
    crate::quant::PackedVec {
        bits,
        scale: range,
        len: u32::try_from(v.len()).expect("vector too large for wire"),
        body,
        section_scales: scales,
    }
}

/// Element-block size of [`quantize_innovation_packed_par`], chosen so a
/// full block's packed size is a whole number of little-endian `u64`
/// words for *every* level `b`: `65536·b` bits = `1024·b` words. The
/// streaming packer's carry accumulator is therefore exactly empty at
/// every block boundary, so blocks packed independently concatenate to
/// the serial byte stream — the word-level analogue of the fixed shard
/// grid that makes `parallel_for_shards` / `util::gemm` reductions
/// thread-invariant.
pub const FUSED_BLOCK: usize = 65536;

/// Thread-parallel form of [`quantize_innovation_packed_buf`] for wide
/// models (global single-scale payloads only; sectioned payloads use
/// the serial kernel). The vector is cut on the fixed [`FUSED_BLOCK`]
/// grid regardless of `threads`:
///
/// * **bytes** — each full block packs into a disjoint whole-word byte
///   range, so the packed body is *byte-identical* to the serial kernel
///   (and to quantize-then-`pack_into`) at any thread count;
/// * **norms** — per-block partial sums are reduced in block order, so
///   `dq_norm_sq` / `err_norm_sq` are bit-identical at any thread
///   count. They equal the serial kernel's norms bitwise whenever
///   `d ≤ FUSED_BLOCK` (one block ⇒ same accumulation grouping); above
///   that the fixed block grid regroups the f64 additions, which is why
///   the *engine* device phase parallelizes across the cohort with the
///   serial kernel per device instead of using this one.
pub fn quantize_innovation_packed_par(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    mut body: Vec<u8>,
    threads: usize,
) -> PackedOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert!((1..=MAX_BITS).contains(&bits));
    let d = g.len();
    let n_blocks = d.div_ceil(FUSED_BLOCK).max(1);
    let threads = threads.clamp(1, n_blocks);
    body.clear();
    body.resize(crate::quant::packing::packed_len(d, bits), 0);
    let block_bytes = crate::quant::packing::packed_len(FUSED_BLOCK, bits);
    let mut partials = vec![(0.0f64, 0.0f64); n_blocks];
    // One worker packs a contiguous run of blocks: per block, pack into
    // a reused scratch and copy into the block's disjoint byte range.
    let work = |parts: &mut [(f64, f64)],
                gs: &[f32],
                qs: &[f32],
                dqs: &mut [f32],
                bys: &mut [u8]| {
        let mut scratch: Vec<u8> = Vec::with_capacity(block_bytes);
        for (j, p) in parts.iter_mut().enumerate() {
            let lo = j * FUSED_BLOCK;
            let hi = (lo + FUSED_BLOCK).min(gs.len());
            scratch.clear();
            let mut w = crate::quant::packing::PackWriter::new(&mut scratch, bits);
            *p = fused_quantize_slice_sink(&gs[lo..hi], &qs[lo..hi], bits, range, &mut dqs[lo..hi], &mut w);
            w.finish();
            let byte0 = j * block_bytes;
            bys[byte0..byte0 + scratch.len()].copy_from_slice(&scratch);
        }
    };
    if threads <= 1 {
        work(&mut partials, g, q_prev, dq_out, &mut body);
    } else {
        let per = n_blocks.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut parts_rest = partials.as_mut_slice();
            let mut dq_rest = &mut *dq_out;
            let mut body_rest = body.as_mut_slice();
            let mut blk0 = 0usize;
            while blk0 < n_blocks {
                let nb = per.min(n_blocks - blk0);
                let (parts, pr) = parts_rest.split_at_mut(nb);
                parts_rest = pr;
                let elem0 = blk0 * FUSED_BLOCK;
                let elems = (nb * FUSED_BLOCK).min(d - elem0);
                let (dqs, dr) = dq_rest.split_at_mut(elems);
                dq_rest = dr;
                let bytes = if blk0 + nb == n_blocks {
                    body_rest.len()
                } else {
                    nb * block_bytes
                };
                let (bys, br) = body_rest.split_at_mut(bytes);
                body_rest = br;
                let gs = &g[elem0..elem0 + elems];
                let qs = &q_prev[elem0..elem0 + elems];
                let work = &work;
                scope.spawn(move || work(parts, gs, qs, dqs, bys));
                blk0 += nb;
            }
        });
    }
    // Fixed reduction: per-block partials summed in block order —
    // invariant to the thread count.
    let mut dq_norm_sq = 0.0f64;
    let mut err_norm_sq = 0.0f64;
    for &(a, b) in &partials {
        dq_norm_sq += a;
        err_norm_sq += b;
    }
    PackedOutcome {
        packed: crate::quant::PackedVec {
            bits,
            scale: range,
            len: u32::try_from(d).expect("vector too large for wire"),
            body,
            section_scales: Vec::new(),
        },
        dq_norm_sq,
        err_norm_sq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn figure1_example() {
        // Paper Fig. 1: simplified mid-tread quantizer with step Ω = 1
        // maps 2.4 to 2. Our full quantizer reproduces this with a grid
        // whose spacing is 1 around the value: v ∈ [−R, R], spacing
        // 2τR = 1 → R = 2.5 ⇒ wait: choose b with 2^b − 1 = 5, i.e. not
        // integral. Instead check the defining property directly: the
        // reconstruction is the nearest grid point below-or-equal at
        // half-step boundaries.
        let v = [2.4f32, -2.4, 0.0, 2.5];
        let q = quantize(&v, 3); // grid spacing 2R/7
        let dq = dequantize(&q);
        let t = tau(3);
        for (orig, rec) in v.iter().zip(&dq) {
            assert!(
                (orig - rec).abs() as f64 <= t * q.range as f64 + 1e-6,
                "error bound violated: {orig} -> {rec}"
            );
        }
    }

    #[test]
    fn codes_fit_in_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for bits in 1..=16u8 {
            let v: Vec<f32> = (0..257).map(|_| rng.gaussian_f32(0.0, 3.0)).collect();
            let q = quantize(&v, bits);
            let max = (1u64 << bits) - 1;
            assert!(q.psi.iter().all(|&c| (c as u64) <= max), "bits={bits}");
        }
    }

    #[test]
    fn extremes_map_to_end_codes() {
        let v = [5.0f32, -5.0, 0.0];
        let q = quantize(&v, 4);
        assert_eq!(q.psi[0], 15); // +R -> 2^b − 1
        assert_eq!(q.psi[1], 0); // −R -> 0
        let dq = dequantize(&q);
        assert!((dq[0] - 5.0).abs() < 1e-6);
        assert!((dq[1] + 5.0).abs() < 1e-6);
    }

    #[test]
    fn per_element_error_bounded_by_tau_r() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for bits in [1u8, 2, 3, 5, 8, 12, 16] {
            let v: Vec<f32> = (0..1000).map(|_| rng.gaussian_f32(0.5, 2.0)).collect();
            let q = quantize(&v, bits);
            let dq = dequantize(&q);
            let bound = tau(bits) * q.range as f64 + 1e-5;
            for (a, b) in v.iter().zip(&dq) {
                assert!(((a - b).abs() as f64) <= bound, "bits={bits}");
            }
        }
    }

    #[test]
    fn zero_vector_roundtrip() {
        let v = [0.0f32; 8];
        let q = quantize(&v, 4);
        assert_eq!(q.range, 0.0);
        assert_eq!(dequantize(&q), vec![0.0f32; 8]);
    }

    #[test]
    fn single_element() {
        let v = [7.25f32];
        let q = quantize(&v, 1);
        // R = 7.25, grid {−R, +R}; 7.25 -> +R.
        let dq = dequantize(&q);
        assert!((dq[0] - 7.25).abs() < 1e-6);
    }

    #[test]
    fn one_bit_is_sign_like() {
        let v = [3.0f32, -3.0, 2.9, -0.1];
        let q = quantize(&v, 1);
        let dq = dequantize(&q);
        // grid is {−R, +R} = {−3, 3}; −0.1 rounds to −3 (midpoint at 0
        // rounds up: (−0.1+3)/6 + 0.5 = 0.983 -> 0).
        assert_eq!(dq, vec![3.0, -3.0, 3.0, -3.0]);
    }

    #[test]
    fn fused_matches_composed() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = 513;
        let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
        let (l2, linf) = crate::util::vecmath::l2sq_and_linf(&v);

        let composed = quantize_with_range(&v, 6, linf);
        let composed_dq = dequantize(&composed);

        let mut dq = vec![0.0f32; d];
        let out = quantize_innovation_fused(&g, &qp, 6, linf, &mut dq);
        assert_eq!(out.quantized.psi, composed.psi);
        for (a, b) in dq.iter().zip(&composed_dq) {
            assert!((a - b).abs() < 1e-6);
        }
        // Norms consistent with materialized versions.
        let dq_n = crate::util::vecmath::norm2_sq(&dq);
        assert!((out.dq_norm_sq - dq_n).abs() / dq_n.max(1.0) < 1e-5);
        let err: Vec<f32> = v.iter().zip(&dq).map(|(a, b)| a - b).collect();
        let err_n = crate::util::vecmath::norm2_sq(&err);
        assert!((out.err_norm_sq - err_n).abs() <= 1e-5 * err_n.max(1.0));
        let _ = l2;
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let v: Vec<f32> = (0..128).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let q = quantize(&v, 24);
        let dq = dequantize(&q);
        for (a, b) in v.iter().zip(&dq) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bits() {
        quantize(&[1.0], 0);
    }

    #[test]
    fn fused_buf_reuses_capacity() {
        let g = [1.0f32, -2.0, 0.5];
        let qp = [0.0f32; 3];
        let mut dq = [0.0f32; 3];
        let psi = Vec::with_capacity(64);
        let cap_ptr = psi.as_ptr();
        let out = quantize_innovation_fused_buf(&g, &qp, 4, 2.0, &mut dq, psi);
        assert_eq!(out.quantized.psi.len(), 3);
        assert_eq!(out.quantized.psi.as_ptr(), cap_ptr, "buffer not reused");
        let composed = quantize_with_range(&[1.0, -2.0, 0.5], 4, 2.0);
        assert_eq!(out.quantized.psi, composed.psi);
    }

    #[test]
    fn scatter_add_matches_dequantize_then_add() {
        use crate::quant::packing::pack;
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        for bits in [1u8, 4, 7, 13] {
            let d = 301;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.5)).collect();
            let q = quantize(&v, bits);
            let body = pack(&q.psi, bits);
            // Reference: dense dequantize then scaled add.
            let mut expect = vec![0.25f32; d];
            let dq = dequantize(&q);
            for (e, x) in expect.iter_mut().zip(&dq) {
                *e += 0.5 * x;
            }
            // Fused over two shards: [0, 100) and [100, d).
            let mut out = vec![0.25f32; d];
            let (lo, hi) = out.split_at_mut(100);
            dequantize_scatter_add(&body, bits, q.range, 0..100, None, 0, 0.5, lo);
            dequantize_scatter_add(&body, bits, q.range, 100..d, None, 100, 0.5, hi);
            for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn scatter_add_through_indices() {
        use crate::quant::packing::pack;
        let v = [1.0f32, -1.0, 0.5, -0.25];
        let q = quantize(&v, 6);
        let body = pack(&q.psi, 6);
        // Support positions 0..4 target coordinates 1, 3, 4, 7 of an
        // 8-wide model.
        let idx: Vec<u32> = vec![1, 3, 4, 7];
        let mut out = vec![0.0f32; 8];
        dequantize_scatter_add(&body, 6, q.range, 0..4, Some(&idx), 0, 2.0, &mut out);
        let dq = dequantize(&q);
        for (k, &t) in idx.iter().enumerate() {
            assert_eq!(out[t as usize], 2.0 * dq[k], "k={k}");
        }
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn sectioned_single_section_is_global() {
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(90);
        let v: Vec<f32> = (0..129).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let global = quantize(&v, 5);
        let sect = quantize_sections(&v, 5, &Sections::global(v.len()));
        assert_eq!(sect, global);
        assert!(!sect.is_sectioned());
    }

    #[test]
    fn sectioned_scales_follow_section_ranges() {
        use crate::quant::Sections;
        // Two sections with wildly different magnitudes: the small
        // section must get its own (small) scale and near-lossless
        // reconstruction relative to the global grid.
        let mut v = vec![0.01f32, -0.02, 0.015, 0.005];
        v.extend_from_slice(&[100.0, -50.0, 75.0, -100.0]);
        let sections = Sections::from_lens([4usize, 4]);
        let q = quantize_sections(&v, 6, &sections);
        assert!(q.is_sectioned());
        assert_eq!(q.section_scales.len(), 2);
        assert_eq!(q.section_scales[0], (0.02, 4));
        assert_eq!(q.section_scales[1], (100.0, 4));
        assert_eq!(q.range, 100.0);
        let dq = dequantize(&q);
        for (i, (a, b)) in v.iter().zip(&dq).enumerate() {
            let rs = if i < 4 { 0.02 } else { 100.0 };
            assert!(
                ((a - b).abs() as f64) <= tau(6) * rs + 1e-6,
                "i={i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn fused_sections_matches_composed_per_section() {
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(91);
        let d = 257;
        let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
        let sections = Sections::from_lens([100usize, 57, 100]);
        let ranges: Vec<f32> = sections
            .iter()
            .map(|r| crate::util::vecmath::norm_inf(&v[r]))
            .collect();
        let mut dq = vec![0.0f32; d];
        let out = quantize_innovation_fused_sections_buf(
            &g,
            &qp,
            6,
            &ranges,
            &sections,
            &mut dq,
            Vec::new(),
        );
        let composed = quantize_sections(&v, 6, &sections);
        assert_eq!(out.quantized, composed);
        // Summed norms consistent with the materialized reconstruction.
        let dq_n = crate::util::vecmath::norm2_sq(&dq);
        assert!((out.dq_norm_sq - dq_n).abs() / dq_n.max(1.0) < 1e-5);
        let err: Vec<f32> = v.iter().zip(&dq).map(|(a, b)| a - b).collect();
        let err_n = crate::util::vecmath::norm2_sq(&err);
        assert!((out.err_norm_sq - err_n).abs() <= 1e-5 * err_n.max(1.0));
        // Single-section partition delegates to the global path.
        let gsec = Sections::global(d);
        let (l2sq, linf) = crate::util::vecmath::l2sq_and_linf(&v);
        let mut dq2 = vec![0.0f32; d];
        let out2 = quantize_innovation_fused_sections_buf(
            &g,
            &qp,
            6,
            &[linf],
            &gsec,
            &mut dq2,
            Vec::new(),
        );
        let mut dq3 = vec![0.0f32; d];
        let out3 = quantize_innovation_fused(&g, &qp, 6, linf, &mut dq3);
        assert_eq!(out2.quantized, out3.quantized);
        assert_eq!(out2.dq_norm_sq.to_bits(), out3.dq_norm_sq.to_bits());
        let _ = l2sq;
    }

    #[test]
    fn scatter_add_zero_range_is_noop() {
        let mut out = vec![1.0f32; 4];
        dequantize_scatter_add(&[], 4, 0.0, 0..4, None, 0, 1.0, &mut out);
        dequantize_scatter_add(&[0xFF], 4, 1.0, 2..2, None, 0, 1.0, &mut out);
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn packed_matches_fused_then_pack() {
        use crate::quant::packing::pack;
        let mut rng = Xoshiro256pp::seed_from_u64(101);
        for bits in [1u8, 4, 6, 12, 13, 16] {
            let d = 517;
            let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
            let linf = crate::util::vecmath::norm_inf(&v);
            let mut dq1 = vec![0.0f32; d];
            let legacy = quantize_innovation_fused(&g, &qp, bits, linf, &mut dq1);
            let mut dq2 = vec![0.0f32; d];
            let out = quantize_innovation_packed(&g, &qp, bits, linf, &mut dq2);
            assert_eq!(out.packed.body, pack(&legacy.quantized.psi, bits), "bits={bits}");
            assert_eq!(out.packed.scale, linf);
            assert_eq!(out.packed.dim(), d);
            assert_eq!(out.dq_norm_sq.to_bits(), legacy.dq_norm_sq.to_bits());
            assert_eq!(out.err_norm_sq.to_bits(), legacy.err_norm_sq.to_bits());
            for (a, b) in dq1.iter().zip(&dq2) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn packed_sections_matches_fused_then_pack() {
        use crate::quant::packing::pack;
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(102);
        let d = 301;
        let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
        let sections = Sections::from_lens([120usize, 64, 117]);
        let ranges: Vec<f32> = sections
            .iter()
            .map(|r| crate::util::vecmath::norm_inf(&v[r]))
            .collect();
        let mut dq1 = vec![0.0f32; d];
        let legacy = quantize_innovation_fused_sections_buf(
            &g,
            &qp,
            5,
            &ranges,
            &sections,
            &mut dq1,
            Vec::new(),
        );
        let mut dq2 = vec![0.0f32; d];
        let out = quantize_innovation_packed_sections_buf(
            &g,
            &qp,
            5,
            &ranges,
            &sections,
            &mut dq2,
            Vec::new(),
        );
        assert_eq!(out.packed.body, pack(&legacy.quantized.psi, 5));
        assert_eq!(out.packed.section_scales, legacy.quantized.section_scales);
        assert_eq!(out.dq_norm_sq.to_bits(), legacy.dq_norm_sq.to_bits());
        assert_eq!(out.err_norm_sq.to_bits(), legacy.err_norm_sq.to_bits());
        // Single-section partition delegates to the (v1) global path.
        let out2 = quantize_innovation_packed_sections_buf(
            &g,
            &qp,
            5,
            &[crate::util::vecmath::norm_inf(&v)],
            &Sections::global(d),
            &mut dq2,
            Vec::new(),
        );
        assert!(!out2.packed.is_sectioned());
    }

    #[test]
    fn packed_full_matches_quantize_then_pack() {
        use crate::quant::packing::pack;
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(103);
        let v: Vec<f32> = (0..273).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        let q = quantize(&v, 7);
        let p = quantize_packed_buf(&v, 7, Vec::new());
        assert_eq!(p.body, pack(&q.psi, 7));
        assert_eq!(p.scale, q.range);
        let sections = Sections::from_lens([100usize, 173]);
        let qs = quantize_sections(&v, 7, &sections);
        let ps = quantize_sections_packed_buf(&v, 7, &sections, Vec::new());
        assert_eq!(ps.body, pack(&qs.psi, 7));
        assert_eq!(ps.section_scales, qs.section_scales);
        assert_eq!(ps.scale, qs.range);
    }

    #[test]
    fn packed_buf_reuses_capacity() {
        let g = [1.0f32, -2.0, 0.5];
        let qp = [0.0f32; 3];
        let mut dq = [0.0f32; 3];
        let body = Vec::with_capacity(64);
        let cap_ptr = body.as_ptr();
        let out = quantize_innovation_packed_buf(&g, &qp, 4, 2.0, &mut dq, body);
        assert_eq!(out.packed.body.as_ptr(), cap_ptr, "buffer not reused");
        // Stale bytes from a previous (larger) round must not leak.
        let mut stale = out.packed.body;
        stale.extend_from_slice(&[0xAB; 32]);
        let out2 = quantize_innovation_packed_buf(&g, &qp, 4, 2.0, &mut dq, stale);
        let fresh = quantize_innovation_packed_buf(&g, &qp, 4, 2.0, &mut dq, Vec::new());
        assert_eq!(out2.packed, fresh.packed);
    }

    #[test]
    fn packed_par_thread_invariant_and_matches_serial() {
        let mut rng = Xoshiro256pp::seed_from_u64(104);
        // Spans several FUSED_BLOCK blocks with a partial tail.
        let d = 2 * FUSED_BLOCK + 12_345;
        let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
        let linf = crate::util::vecmath::norm_inf(&v);
        for bits in [3u8, 4, 13] {
            let mut dq_s = vec![0.0f32; d];
            let serial = quantize_innovation_packed(&g, &qp, bits, linf, &mut dq_s);
            let mut ref_out: Option<PackedOutcome> = None;
            for threads in [1usize, 2, 7] {
                let mut dq_p = vec![0.0f32; d];
                let par = quantize_innovation_packed_par(
                    &g,
                    &qp,
                    bits,
                    linf,
                    &mut dq_p,
                    Vec::new(),
                    threads,
                );
                // Bytes identical to the serial kernel at any thread count.
                assert_eq!(par.packed, serial.packed, "bits={bits} threads={threads}");
                for (a, b) in dq_s.iter().zip(&dq_p) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                // Norms thread-invariant (block-ordered reduction).
                if let Some(r) = &ref_out {
                    assert_eq!(par.dq_norm_sq.to_bits(), r.dq_norm_sq.to_bits());
                    assert_eq!(par.err_norm_sq.to_bits(), r.err_norm_sq.to_bits());
                } else {
                    ref_out = Some(par);
                }
            }
        }
        // At d ≤ FUSED_BLOCK (one block) the par norms equal the serial
        // kernel's bitwise, not just the bytes.
        let d2 = 10_000;
        let mut dq_a = vec![0.0f32; d2];
        let mut dq_b = vec![0.0f32; d2];
        let linf2 = crate::util::vecmath::norm_inf(&v[..d2]);
        let a = quantize_innovation_packed(&g[..d2], &qp[..d2], 4, linf2, &mut dq_a);
        let b = quantize_innovation_packed_par(&g[..d2], &qp[..d2], 4, linf2, &mut dq_b, Vec::new(), 7);
        assert_eq!(a.packed, b.packed);
        assert_eq!(a.dq_norm_sq.to_bits(), b.dq_norm_sq.to_bits());
        assert_eq!(a.err_norm_sq.to_bits(), b.err_norm_sq.to_bits());
    }
}
