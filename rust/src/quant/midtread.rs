//! Deterministic mid-tread quantizer (paper Definition 2, Lemma 4).
//!
//! Every element of a vector `v` (in AQUILA, the *gradient innovation*
//! `∇f_m(θᵏ) − q_m^{k−1}`) is mapped to an unsigned integer
//!
//! ```text
//! ψᵢ = floor( (vᵢ + R) / (2τR) + 1/2 ),   R = ‖v‖_∞,  τ = 1/(2^b − 1)
//! ```
//!
//! and reconstructed (Lemma 4) as
//!
//! ```text
//! Δqᵢ = 2τR·ψᵢ − R .
//! ```
//!
//! Properties verified by the tests below and by property tests in
//! `rust/tests/prop_quant.rs`:
//!
//! * `ψᵢ ∈ [0, 2^b − 1]` — every code fits in `b` bits;
//! * the reconstruction error obeys `|vᵢ − Δqᵢ| ≤ τR` per element
//!   (mid-tread rounding to the nearest grid point);
//! * `R = 0` (zero innovation) round-trips to the zero vector.
//!
//! Figure 1 of the paper (`Q(2.4) = 2` at step Ω = 1) corresponds to the
//! simplified mid-tread map; see `figure1_example` in the tests.
//!
//! This Rust implementation is the L3 production hot path; it is
//! bit-compatible with the L1 Pallas kernel
//! (`python/compile/kernels/aquila_quant.py`) — parity is asserted by the
//! `hlo_parity` integration test when artifacts are built.

/// Maximum supported quantization level. `ψ` is stored in `u32`; levels
/// this high are never selected by AQUILA (eq. 19 bounds `b* ≤
/// ceil(log2(√d + 1))`) but fixed-level baselines may request them.
pub const MAX_BITS: u8 = 32;

/// A quantized vector: the on-the-wire representation of a gradient
/// innovation before bit-packing.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedVec {
    /// Quantization level `b` (bits per element), `1 ..= MAX_BITS`.
    pub bits: u8,
    /// Quantization range `R = ‖v‖_∞` at quantization time.
    pub range: f32,
    /// Integer codes, each in `[0, 2^b − 1]`.
    pub psi: Vec<u32>,
}

impl QuantizedVec {
    /// Quantization granularity `τ = 1/(2^b − 1)`.
    #[inline]
    pub fn tau(&self) -> f64 {
        tau(self.bits)
    }

    /// Dimension of the underlying vector.
    #[inline]
    pub fn dim(&self) -> usize {
        self.psi.len()
    }

    /// An all-zero quantization (used for `q_m^{-1} = 0` at round 0).
    pub fn zeros(bits: u8, d: usize) -> Self {
        Self {
            bits,
            range: 0.0,
            psi: vec![0; d],
        }
    }
}

/// `τ = 1/(2^b − 1)` in f64 (exact for all `b ≤ 32`).
#[inline]
pub fn tau(bits: u8) -> f64 {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    1.0 / (((1u64 << bits) - 1) as f64)
}

/// Quantize `v` at level `bits` with range `R = ‖v‖_∞` (Definition 2).
pub fn quantize(v: &[f32], bits: u8) -> QuantizedVec {
    quantize_buf(v, bits, Vec::new())
}

/// Buffer-reusing form of [`quantize`] (see
/// [`quantize_with_range_into`]).
pub fn quantize_buf(v: &[f32], bits: u8, psi: Vec<u32>) -> QuantizedVec {
    let range = crate::util::vecmath::norm_inf(v);
    quantize_with_range_into(v, bits, range, psi)
}

/// Quantize with an externally supplied range (the range of the
/// innovation is usually already known from the fused norm pass).
pub fn quantize_with_range(v: &[f32], bits: u8, range: f32) -> QuantizedVec {
    quantize_with_range_into(v, bits, range, Vec::new())
}

/// Buffer-reusing form of [`quantize_with_range`]: `psi` is cleared and
/// refilled, keeping its capacity (the coordinator recycles each
/// device's code buffer across rounds — §Perf).
pub fn quantize_with_range_into(v: &[f32], bits: u8, range: f32, mut psi: Vec<u32>) -> QuantizedVec {
    assert!((1..=MAX_BITS).contains(&bits), "bits must be in 1..=32");
    assert!(range >= 0.0 && range.is_finite(), "range must be finite ≥ 0");
    psi.clear();
    psi.reserve(v.len());
    if range == 0.0 {
        psi.resize(v.len(), 0);
        return QuantizedVec { bits, range, psi };
    }
    let max_code = crate::quant::max_code(bits);
    if bits <= 12 {
        // f32 fast path — must stay bit-identical to
        // `quantize_innovation_fused` (§Perf).
        let t32 = tau(bits) as f32;
        let inv_step = 1.0 / (2.0 * t32 * range);
        let maxc = max_code as f32;
        for &x in v {
            let code = ((x + range) * inv_step + 0.5).floor().clamp(0.0, maxc);
            psi.push(code as u32);
        }
    } else {
        let t = tau(bits);
        // 1 / (2τR): hoisted out of the loop; f64 so b near 32 stays
        // exact.
        let inv_step = 1.0 / (2.0 * t * range as f64);
        for &x in v {
            let code = ((x as f64 + range as f64) * inv_step + 0.5).floor();
            // Clamp guards the pathological case |vᵢ| marginally above R
            // due to an externally supplied range; with R = ‖v‖_∞ it
            // never fires.
            let code = code.clamp(0.0, max_code as f64) as u32;
            psi.push(code);
        }
    }
    QuantizedVec { bits, range, psi }
}

/// Reconstruct `Δq` per Lemma 4: `Δqᵢ = 2τR·ψᵢ − R`.
pub fn dequantize_into(q: &QuantizedVec, out: &mut [f32]) {
    assert_eq!(q.psi.len(), out.len());
    if q.range == 0.0 {
        out.fill(0.0);
        return;
    }
    let step = 2.0 * q.tau() * q.range as f64;
    let r = q.range as f64;
    for (o, &code) in out.iter_mut().zip(&q.psi) {
        *o = (step * code as f64 - r) as f32;
    }
}

/// Reconstruct into a fresh vector.
pub fn dequantize(q: &QuantizedVec) -> Vec<f32> {
    let mut out = vec![0.0f32; q.psi.len()];
    dequantize_into(q, &mut out);
    out
}

/// Fused server-side kernel (§Perf): reconstruct codes `codes.start..
/// codes.end` straight from the *packed* wire body and scatter-add
/// `scale · Δqᵢ` into one contiguous output shard — no ψ vector and no
/// dense scratch are ever materialized.
///
/// `targets` maps code position → full-model coordinate (`None` =
/// identity, the full-capacity fast path); `out` is the shard slice
/// `direction[out_base .. out_base + out.len()]`, so every touched
/// coordinate must satisfy `out_base ≤ idx < out_base + out.len()` —
/// the caller selects `codes` accordingly (contiguous because mask
/// indices are sorted).
///
/// Per-element arithmetic is exactly [`dequantize_into`] followed by
/// `out += scale · Δq` and is independent of shard boundaries, which is
/// what makes the shard-parallel fold bit-identical to the serial one.
#[allow(clippy::too_many_arguments)]
pub fn dequantize_scatter_add(
    body: &[u8],
    bits: u8,
    range: f32,
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    if codes.is_empty() || range == 0.0 {
        // Δq ≡ 0 at range 0 (Lemma 4 reconstruction collapses to −R = 0).
        return;
    }
    let step = 2.0 * tau(bits) * range as f64;
    let r = range as f64;
    match targets {
        None => {
            let mut j = codes.start - out_base;
            crate::quant::packing::for_each_code(body, bits, codes.start, codes.end, |c| {
                out[j] += scale * ((step * c as f64 - r) as f32);
                j += 1;
            });
        }
        Some(idx) => {
            let mut p = codes.start;
            crate::quant::packing::for_each_code(body, bits, codes.start, codes.end, |c| {
                out[idx[p] as usize - out_base] += scale * ((step * c as f64 - r) as f32);
                p += 1;
            });
        }
    }
}

/// Result of the fused quantize pass used on the AQUILA device hot path.
#[derive(Clone, Debug)]
pub struct QuantizeOutcome {
    /// Wire representation of the innovation.
    pub quantized: QuantizedVec,
    /// `‖Δq‖₂²` — LHS term 1 of the skip criterion (eq. 8).
    pub dq_norm_sq: f64,
    /// `‖ε‖₂² = ‖v − Δq‖₂²` — LHS term 2 of the skip criterion.
    pub err_norm_sq: f64,
}

/// Fused device-step quantization: quantize the implicit innovation
/// `v = g − q_prev` (never materialized), reconstruct `Δq` into
/// `dq_out`, and accumulate the two norms the skip rule needs — all in a
/// single traversal. This mirrors pass 2 of the L1 Pallas kernel.
pub fn quantize_innovation_fused(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
) -> QuantizeOutcome {
    quantize_innovation_fused_buf(g, q_prev, bits, range, dq_out, Vec::new())
}

/// Buffer-reusing form of [`quantize_innovation_fused`]: `psi` is
/// cleared and refilled with the codes (keeping its capacity) and ends
/// up owned by the returned [`QuantizedVec`]. The device hot path hands
/// in its recycled per-device code buffer so the quantize step performs
/// zero allocations in steady state.
pub fn quantize_innovation_fused_buf(
    g: &[f32],
    q_prev: &[f32],
    bits: u8,
    range: f32,
    dq_out: &mut [f32],
    mut psi: Vec<u32>,
) -> QuantizeOutcome {
    assert_eq!(g.len(), q_prev.len());
    assert_eq!(g.len(), dq_out.len());
    assert!((1..=MAX_BITS).contains(&bits));
    let d = g.len();
    psi.clear();
    psi.reserve(d);
    if range == 0.0 {
        psi.resize(d, 0);
        dq_out.fill(0.0);
        // ε = v − 0 = v; with range 0 the innovation is exactly zero.
        return QuantizeOutcome {
            quantized: QuantizedVec {
                bits,
                range,
                psi,
            },
            dq_norm_sq: 0.0,
            err_norm_sq: 0.0,
        };
    }
    let max_code = crate::quant::max_code(bits);
    let mut dq_norm_sq = 0.0f64;
    let mut err_norm_sq = 0.0f64;
    if bits <= 12 {
        // Fast path (§Perf): all arithmetic in f32. Codes ≤ 4095 are
        // exact in f32, and this is precisely the arithmetic the L1
        // Pallas kernel performs (jax f32), so parity *improves*. The
        // loop auto-vectorizes (~4× over the f64 path).
        let t32 = tau(bits) as f32;
        let step = 2.0 * t32 * range;
        let inv_step = 1.0 / step;
        let maxc = max_code as f32;
        psi.resize(d, 0);
        let psi_s = psi.as_mut_slice();
        // Four independent accumulator lanes break the f64-add
        // dependency chain (§Perf iteration 2: +25% on d = 1M).
        let mut dq_acc = [0.0f64; 4];
        let mut err_acc = [0.0f64; 4];
        for i in 0..d {
            let v = g[i] - q_prev[i];
            let code = ((v + range) * inv_step + 0.5).floor().clamp(0.0, maxc);
            let dq = step * code - range;
            let err = v - dq;
            let lane = i & 3;
            dq_acc[lane] += (dq as f64) * (dq as f64);
            err_acc[lane] += (err as f64) * (err as f64);
            dq_out[i] = dq;
            psi_s[i] = code as u32;
        }
        dq_norm_sq = dq_acc.iter().sum();
        err_norm_sq = err_acc.iter().sum();
    } else {
        // High-precision path: codes up to 2³² − 1 need f64.
        let t = tau(bits);
        let rf = range as f64;
        let step = 2.0 * t * rf;
        let inv_step = 1.0 / step;
        for i in 0..d {
            let v = (g[i] - q_prev[i]) as f64;
            let code = ((v + rf) * inv_step + 0.5).floor().clamp(0.0, max_code as f64) as u32;
            let dq = step * code as f64 - rf;
            let err = v - dq;
            dq_norm_sq += dq * dq;
            err_norm_sq += err * err;
            dq_out[i] = dq as f32;
            psi.push(code);
        }
    }
    QuantizeOutcome {
        quantized: QuantizedVec { bits, range, psi },
        dq_norm_sq,
        err_norm_sq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn figure1_example() {
        // Paper Fig. 1: simplified mid-tread quantizer with step Ω = 1
        // maps 2.4 to 2. Our full quantizer reproduces this with a grid
        // whose spacing is 1 around the value: v ∈ [−R, R], spacing
        // 2τR = 1 → R = 2.5 ⇒ wait: choose b with 2^b − 1 = 5, i.e. not
        // integral. Instead check the defining property directly: the
        // reconstruction is the nearest grid point below-or-equal at
        // half-step boundaries.
        let v = [2.4f32, -2.4, 0.0, 2.5];
        let q = quantize(&v, 3); // grid spacing 2R/7
        let dq = dequantize(&q);
        let t = tau(3);
        for (orig, rec) in v.iter().zip(&dq) {
            assert!(
                (orig - rec).abs() as f64 <= t * q.range as f64 + 1e-6,
                "error bound violated: {orig} -> {rec}"
            );
        }
    }

    #[test]
    fn codes_fit_in_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for bits in 1..=16u8 {
            let v: Vec<f32> = (0..257).map(|_| rng.gaussian_f32(0.0, 3.0)).collect();
            let q = quantize(&v, bits);
            let max = (1u64 << bits) - 1;
            assert!(q.psi.iter().all(|&c| (c as u64) <= max), "bits={bits}");
        }
    }

    #[test]
    fn extremes_map_to_end_codes() {
        let v = [5.0f32, -5.0, 0.0];
        let q = quantize(&v, 4);
        assert_eq!(q.psi[0], 15); // +R -> 2^b − 1
        assert_eq!(q.psi[1], 0); // −R -> 0
        let dq = dequantize(&q);
        assert!((dq[0] - 5.0).abs() < 1e-6);
        assert!((dq[1] + 5.0).abs() < 1e-6);
    }

    #[test]
    fn per_element_error_bounded_by_tau_r() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for bits in [1u8, 2, 3, 5, 8, 12, 16] {
            let v: Vec<f32> = (0..1000).map(|_| rng.gaussian_f32(0.5, 2.0)).collect();
            let q = quantize(&v, bits);
            let dq = dequantize(&q);
            let bound = tau(bits) * q.range as f64 + 1e-5;
            for (a, b) in v.iter().zip(&dq) {
                assert!(((a - b).abs() as f64) <= bound, "bits={bits}");
            }
        }
    }

    #[test]
    fn zero_vector_roundtrip() {
        let v = [0.0f32; 8];
        let q = quantize(&v, 4);
        assert_eq!(q.range, 0.0);
        assert_eq!(dequantize(&q), vec![0.0f32; 8]);
    }

    #[test]
    fn single_element() {
        let v = [7.25f32];
        let q = quantize(&v, 1);
        // R = 7.25, grid {−R, +R}; 7.25 -> +R.
        let dq = dequantize(&q);
        assert!((dq[0] - 7.25).abs() < 1e-6);
    }

    #[test]
    fn one_bit_is_sign_like() {
        let v = [3.0f32, -3.0, 2.9, -0.1];
        let q = quantize(&v, 1);
        let dq = dequantize(&q);
        // grid is {−R, +R} = {−3, 3}; −0.1 rounds to −3 (midpoint at 0
        // rounds up: (−0.1+3)/6 + 0.5 = 0.983 -> 0).
        assert_eq!(dq, vec![3.0, -3.0, 3.0, -3.0]);
    }

    #[test]
    fn fused_matches_composed() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = 513;
        let g: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let qp: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = g.iter().zip(&qp).map(|(a, b)| a - b).collect();
        let (l2, linf) = crate::util::vecmath::l2sq_and_linf(&v);

        let composed = quantize_with_range(&v, 6, linf);
        let composed_dq = dequantize(&composed);

        let mut dq = vec![0.0f32; d];
        let out = quantize_innovation_fused(&g, &qp, 6, linf, &mut dq);
        assert_eq!(out.quantized.psi, composed.psi);
        for (a, b) in dq.iter().zip(&composed_dq) {
            assert!((a - b).abs() < 1e-6);
        }
        // Norms consistent with materialized versions.
        let dq_n = crate::util::vecmath::norm2_sq(&dq);
        assert!((out.dq_norm_sq - dq_n).abs() / dq_n.max(1.0) < 1e-5);
        let err: Vec<f32> = v.iter().zip(&dq).map(|(a, b)| a - b).collect();
        let err_n = crate::util::vecmath::norm2_sq(&err);
        assert!((out.err_norm_sq - err_n).abs() <= 1e-5 * err_n.max(1.0));
        let _ = l2;
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let v: Vec<f32> = (0..128).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let q = quantize(&v, 24);
        let dq = dequantize(&q);
        for (a, b) in v.iter().zip(&dq) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bits() {
        quantize(&[1.0], 0);
    }

    #[test]
    fn fused_buf_reuses_capacity() {
        let g = [1.0f32, -2.0, 0.5];
        let qp = [0.0f32; 3];
        let mut dq = [0.0f32; 3];
        let psi = Vec::with_capacity(64);
        let cap_ptr = psi.as_ptr();
        let out = quantize_innovation_fused_buf(&g, &qp, 4, 2.0, &mut dq, psi);
        assert_eq!(out.quantized.psi.len(), 3);
        assert_eq!(out.quantized.psi.as_ptr(), cap_ptr, "buffer not reused");
        let composed = quantize_with_range(&[1.0, -2.0, 0.5], 4, 2.0);
        assert_eq!(out.quantized.psi, composed.psi);
    }

    #[test]
    fn scatter_add_matches_dequantize_then_add() {
        use crate::quant::packing::pack;
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        for bits in [1u8, 4, 7, 13] {
            let d = 301;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.5)).collect();
            let q = quantize(&v, bits);
            let body = pack(&q.psi, bits);
            // Reference: dense dequantize then scaled add.
            let mut expect = vec![0.25f32; d];
            let dq = dequantize(&q);
            for (e, x) in expect.iter_mut().zip(&dq) {
                *e += 0.5 * x;
            }
            // Fused over two shards: [0, 100) and [100, d).
            let mut out = vec![0.25f32; d];
            let (lo, hi) = out.split_at_mut(100);
            dequantize_scatter_add(&body, bits, q.range, 0..100, None, 0, 0.5, lo);
            dequantize_scatter_add(&body, bits, q.range, 100..d, None, 100, 0.5, hi);
            for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn scatter_add_through_indices() {
        use crate::quant::packing::pack;
        let v = [1.0f32, -1.0, 0.5, -0.25];
        let q = quantize(&v, 6);
        let body = pack(&q.psi, 6);
        // Support positions 0..4 target coordinates 1, 3, 4, 7 of an
        // 8-wide model.
        let idx: Vec<u32> = vec![1, 3, 4, 7];
        let mut out = vec![0.0f32; 8];
        dequantize_scatter_add(&body, 6, q.range, 0..4, Some(&idx), 0, 2.0, &mut out);
        let dq = dequantize(&q);
        for (k, &t) in idx.iter().enumerate() {
            assert_eq!(out[t as usize], 2.0 * dq[k], "k={k}");
        }
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn scatter_add_zero_range_is_noop() {
        let mut out = vec![1.0f32; 4];
        dequantize_scatter_add(&[], 4, 0.0, 0..4, None, 0, 1.0, &mut out);
        dequantize_scatter_add(&[0xFF], 4, 1.0, 2..2, None, 0, 1.0, &mut out);
        assert_eq!(out, vec![1.0; 4]);
    }
}
