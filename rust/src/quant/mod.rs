//! Quantization: the mid-tread quantizer of Definition 2, the QSGD
//! stochastic baseline, adaptive level rules (AQUILA eq. 19, AdaQuantFL,
//! DAdaQuant), and the bit-packed wire encoding.

pub mod levels;
pub mod midtread;
pub mod packing;
pub mod qsgd;

pub use levels::{adaquantfl_level, aquila_level, aquila_level_upper_bound, aquila_tau_star};
pub use midtread::{
    dequantize, dequantize_into, quantize, quantize_innovation_fused, quantize_with_range,
    QuantizeOutcome, QuantizedVec, MAX_BITS,
};
