//! Quantization: the mid-tread quantizer of Definition 2, the QSGD
//! stochastic baseline, adaptive level rules (AQUILA eq. 19, AdaQuantFL,
//! DAdaQuant), layout-aware sectioning (per-tensor / fixed-block
//! scales), and the bit-packed wire encoding.

pub mod levels;
pub mod midtread;
pub mod packing;
pub mod qsgd;
pub mod sections;

pub use levels::{adaquantfl_level, aquila_level, aquila_level_upper_bound, aquila_tau_star};
pub use midtread::{
    dequantize, dequantize_into, quantize, quantize_innovation_fused, quantize_innovation_packed,
    quantize_with_range, PackedOutcome, QuantizeOutcome, QuantizedVec, MAX_BITS,
};
pub use sections::{SectionSpec, Sections};

/// A quantized vector whose codes are already bit-packed into the wire
/// body — the output of the fused quantize→pack kernels
/// ([`midtread::quantize_innovation_packed_buf`],
/// [`qsgd::quantize_packed_buf`]). Compared to [`QuantizedVec`] /
/// [`qsgd::QsgdVec`] the intermediate `codes: Vec<u32>` never exists:
/// `body` holds exactly the bytes the unpacked form would serialize to
/// (mid-tread: `packing::pack_into(&psi, bits, ..)`; QSGD: sign bitmap
/// followed by the packed magnitudes), so `transport::wire::encode`
/// appends it verbatim and the wire stream stays byte-identical to the
/// unpacked path.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedVec {
    /// Quantization level `b` (bits per element).
    pub bits: u8,
    /// Wire header scale — mid-tread: range `R = ‖v‖_∞` (the max
    /// section scale when sectioned); QSGD: `‖v‖₂`.
    pub scale: f32,
    /// Element count of the underlying vector.
    pub len: u32,
    /// Packed wire body bytes.
    pub body: Vec<u8>,
    /// Per-section `(scale, len)` pairs (wire v2 section table). Empty
    /// = single global scale — the v1 wire form.
    pub section_scales: Vec<(f32, u32)>,
}

impl PackedVec {
    /// Dimension of the underlying vector.
    #[inline]
    pub fn dim(&self) -> usize {
        self.len as usize
    }

    /// Whether this vector carries per-section scales (wire v2).
    #[inline]
    pub fn is_sectioned(&self) -> bool {
        !self.section_scales.is_empty()
    }
}

/// Bit mask covering the low `bits` bits of a code word — the single
/// source of the `(1 << b) − 1` expression previously duplicated across
/// `packing`, `midtread`, and `qsgd` (each with its own `b == 32`
/// special case).
///
/// Valid for `bits ∈ 1..=32`; `code_mask(32)` is `u32::MAX as u64`.
#[inline]
#[allow(clippy::manual_range_contains)] // RangeInclusive::contains is not const
pub const fn code_mask(bits: u8) -> u64 {
    assert!(bits >= 1 && bits <= 32, "bits must be in 1..=32");
    (1u64 << bits) - 1
}

/// Largest code representable at `bits` bits: `2^b − 1`.
#[inline]
pub const fn max_code(bits: u8) -> u32 {
    code_mask(bits) as u32
}

#[cfg(test)]
mod tests {
    use super::{code_mask, max_code};

    #[test]
    fn code_mask_boundaries() {
        assert_eq!(code_mask(1), 0x1);
        assert_eq!(code_mask(4), 0xF);
        assert_eq!(code_mask(8), 0xFF);
        assert_eq!(code_mask(31), (1u64 << 31) - 1);
        assert_eq!(code_mask(32), u32::MAX as u64);
        for bits in 1..=32u8 {
            assert_eq!(code_mask(bits).count_ones(), bits as u32);
            assert_eq!(max_code(bits) as u64, code_mask(bits));
        }
    }

    #[test]
    #[should_panic]
    fn code_mask_rejects_zero_bits() {
        code_mask(0);
    }

    #[test]
    #[should_panic]
    fn code_mask_rejects_wide_bits() {
        code_mask(33);
    }
}
