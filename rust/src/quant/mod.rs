//! Quantization: the mid-tread quantizer of Definition 2, the QSGD
//! stochastic baseline, adaptive level rules (AQUILA eq. 19, AdaQuantFL,
//! DAdaQuant), layout-aware sectioning (per-tensor / fixed-block
//! scales), and the bit-packed wire encoding.

pub mod levels;
pub mod midtread;
pub mod packing;
pub mod qsgd;
pub mod sections;

pub use levels::{adaquantfl_level, aquila_level, aquila_level_upper_bound, aquila_tau_star};
pub use midtread::{
    dequantize, dequantize_into, quantize, quantize_innovation_fused, quantize_with_range,
    QuantizeOutcome, QuantizedVec, MAX_BITS,
};
pub use sections::{SectionSpec, Sections};

/// Bit mask covering the low `bits` bits of a code word — the single
/// source of the `(1 << b) − 1` expression previously duplicated across
/// `packing`, `midtread`, and `qsgd` (each with its own `b == 32`
/// special case).
///
/// Valid for `bits ∈ 1..=32`; `code_mask(32)` is `u32::MAX as u64`.
#[inline]
#[allow(clippy::manual_range_contains)] // RangeInclusive::contains is not const
pub const fn code_mask(bits: u8) -> u64 {
    assert!(bits >= 1 && bits <= 32, "bits must be in 1..=32");
    (1u64 << bits) - 1
}

/// Largest code representable at `bits` bits: `2^b − 1`.
#[inline]
pub const fn max_code(bits: u8) -> u32 {
    code_mask(bits) as u32
}

#[cfg(test)]
mod tests {
    use super::{code_mask, max_code};

    #[test]
    fn code_mask_boundaries() {
        assert_eq!(code_mask(1), 0x1);
        assert_eq!(code_mask(4), 0xF);
        assert_eq!(code_mask(8), 0xFF);
        assert_eq!(code_mask(31), (1u64 << 31) - 1);
        assert_eq!(code_mask(32), u32::MAX as u64);
        for bits in 1..=32u8 {
            assert_eq!(code_mask(bits).count_ones(), bits as u32);
            assert_eq!(max_code(bits) as u64, code_mask(bits));
        }
    }

    #[test]
    #[should_panic]
    fn code_mask_rejects_zero_bits() {
        code_mask(0);
    }

    #[test]
    #[should_panic]
    fn code_mask_rejects_wide_bits() {
        code_mask(33);
    }
}
