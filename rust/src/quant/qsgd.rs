//! QSGD stochastic quantizer (Alistarh et al., 2017) — the fixed-level
//! stochastic baseline column of Tables II/III.
//!
//! For a vector `v` and `s = 2^b − 1` levels:
//!
//! ```text
//! Q(vᵢ) = ‖v‖₂ · sign(vᵢ) · ξᵢ,     ξᵢ ∈ {l/s, (l+1)/s}
//! ```
//!
//! where `l = floor(|vᵢ|/‖v‖₂ · s)` and `ξᵢ = (l+1)/s` with probability
//! `|vᵢ|/‖v‖₂·s − l` (stochastic rounding — unbiased: `E[Q(v)] = v`).
//!
//! Wire format: `‖v‖₂` (f32) + 1 sign bit + `b` magnitude bits per
//! element.

use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::norm2;

/// A QSGD-quantized vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QsgdVec {
    /// Magnitude bits per element.
    pub bits: u8,
    /// `‖v‖₂` scale. For sectioned vectors this is the max section
    /// norm, kept for metrics; reconstruction uses `section_scales`.
    pub norm: f32,
    /// Magnitude codes in `[0, 2^b − 1]`.
    pub mags: Vec<u32>,
    /// Sign bits (true = negative).
    pub signs: Vec<bool>,
    /// Per-section `(‖v_s‖₂, len)` pairs (`crate::quant::sections`;
    /// serialized as the wire v2 section table). Empty = single global
    /// `norm` — the v1 wire form.
    pub section_scales: Vec<(f32, u32)>,
}

impl QsgdVec {
    /// Element count `d`.
    pub fn dim(&self) -> usize {
        self.mags.len()
    }

    /// Whether this vector carries per-section norms (wire v2).
    pub fn is_sectioned(&self) -> bool {
        !self.section_scales.is_empty()
    }
}

/// Stochastically quantize `v` at `bits` magnitude bits.
pub fn quantize(v: &[f32], bits: u8, rng: &mut Xoshiro256pp) -> QsgdVec {
    quantize_buf(v, bits, rng, Vec::new(), Vec::new())
}

/// Buffer-reusing form of [`quantize`]: `mags`/`signs` are cleared and
/// refilled keeping their capacity, then owned by the returned
/// [`QsgdVec`] (the coordinator recycles them per device — §Perf).
pub fn quantize_buf(
    v: &[f32],
    bits: u8,
    rng: &mut Xoshiro256pp,
    mut mags: Vec<u32>,
    mut signs: Vec<bool>,
) -> QsgdVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    let norm = norm2(v) as f32;
    mags.clear();
    mags.reserve(v.len());
    signs.clear();
    signs.reserve(v.len());
    quantize_slice_append(v, bits, norm, rng, &mut mags, &mut signs);
    QsgdVec {
        bits,
        norm,
        mags,
        signs,
        section_scales: Vec::new(),
    }
}

/// Section-aware [`quantize`]: one norm `‖v_s‖₂` per section of
/// `sections`. A single-section partition produces the plain global
/// form — byte-identical on the wire to [`quantize`].
pub fn quantize_sections(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    rng: &mut Xoshiro256pp,
) -> QsgdVec {
    quantize_sections_buf(v, bits, sections, rng, Vec::new(), Vec::new())
}

/// Buffer-reusing form of [`quantize_sections`] (see [`quantize_buf`]
/// for the recycling contract).
pub fn quantize_sections_buf(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    rng: &mut Xoshiro256pp,
    mut mags: Vec<u32>,
    mut signs: Vec<bool>,
) -> QsgdVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    assert_eq!(sections.total(), v.len(), "sections must cover the vector");
    if sections.is_global() {
        return quantize_buf(v, bits, rng, mags, signs);
    }
    mags.clear();
    mags.reserve(v.len());
    signs.clear();
    signs.reserve(v.len());
    let mut scales = Vec::with_capacity(sections.count());
    let mut norm = 0.0f32;
    for r in sections.iter() {
        let slice = &v[r.clone()];
        let ns = norm2(slice) as f32;
        quantize_slice_append(slice, bits, ns, rng, &mut mags, &mut signs);
        scales.push((ns, r.len() as u32));
        norm = norm.max(ns);
    }
    QsgdVec {
        bits,
        norm,
        mags,
        signs,
        section_scales: scales,
    }
}

/// Fused quantize→pack form of [`quantize`] (§Perf): emits the wire
/// body — the sign bitmap followed by the packed magnitude words —
/// directly, so the intermediate `mags: Vec<u32>` / `signs: Vec<bool>`
/// never exist. Per-element arithmetic and RNG consumption order are
/// identical to [`quantize`], so the produced bytes are exactly
/// `pack_signs(&q.signs)` followed by `pack(&q.mags, bits)`.
pub fn quantize_packed(v: &[f32], bits: u8, rng: &mut Xoshiro256pp) -> crate::quant::PackedVec {
    quantize_packed_buf(v, bits, rng, Vec::new())
}

/// Buffer-reusing form of [`quantize_packed`]: `body` is cleared and
/// refilled keeping its capacity, then owned by the returned
/// [`crate::quant::PackedVec`].
pub fn quantize_packed_buf(
    v: &[f32],
    bits: u8,
    rng: &mut Xoshiro256pp,
    mut body: Vec<u8>,
) -> crate::quant::PackedVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    let norm = norm2(v) as f32;
    let mut w = BodyWriter::start(&mut body, v.len(), bits);
    w.quantize_slice(v, norm, rng);
    w.finish();
    debug_assert_eq!(
        body.len(),
        v.len().div_ceil(8) + crate::quant::packing::packed_len(v.len(), bits)
    );
    crate::quant::PackedVec {
        bits,
        scale: norm,
        len: v.len() as u32,
        body,
        section_scales: Vec::new(),
    }
}

/// Section-aware fused quantize→pack (see [`quantize_sections_buf`]).
/// The magnitude stream is continuous across sections — the word
/// accumulator carries over section boundaries — so the body is
/// byte-identical to packing the sectioned codes in one call. A
/// single-section partition delegates to [`quantize_packed_buf`].
pub fn quantize_sections_packed_buf(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    rng: &mut Xoshiro256pp,
    mut body: Vec<u8>,
) -> crate::quant::PackedVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    assert_eq!(sections.total(), v.len(), "sections must cover the vector");
    if sections.is_global() {
        return quantize_packed_buf(v, bits, rng, body);
    }
    let mut scales = Vec::with_capacity(sections.count());
    let mut norm = 0.0f32;
    let mut w = BodyWriter::start(&mut body, v.len(), bits);
    for r in sections.iter() {
        let slice = &v[r.clone()];
        let ns = norm2(slice) as f32;
        w.quantize_slice(slice, ns, rng);
        scales.push((ns, r.len() as u32));
        norm = norm.max(ns);
    }
    w.finish();
    crate::quant::PackedVec {
        bits,
        scale: norm,
        len: v.len() as u32,
        body,
        section_scales: scales,
    }
}

/// Streaming writer for the QSGD wire body. The sign bitmap (1 bit per
/// element, pre-zeroed) occupies the front of the buffer and is written
/// in place; magnitude codes are packed through a local little-endian
/// `u64` accumulator (same flush discipline as
/// [`crate::quant::packing::PackWriter`], inlined here because the
/// bitmap region and the magnitude stream share one buffer).
struct BodyWriter<'a> {
    body: &'a mut Vec<u8>,
    b: u32,
    mask: u64,
    acc: u64,
    acc_bits: u32,
    /// Global element index — addresses the sign bitmap.
    elem: usize,
}

impl<'a> BodyWriter<'a> {
    fn start(body: &'a mut Vec<u8>, n: usize, bits: u8) -> Self {
        body.clear();
        let sign_bytes = n.div_ceil(8);
        body.reserve(sign_bytes + crate::quant::packing::packed_len(n, bits));
        body.resize(sign_bytes, 0);
        Self {
            body,
            b: bits as u32,
            mask: crate::quant::code_mask(bits),
            acc: 0,
            acc_bits: 0,
            elem: 0,
        }
    }

    #[inline]
    fn push_mag(&mut self, c: u32) {
        let c = (c as u64) & self.mask;
        self.acc |= c << self.acc_bits;
        let filled = self.acc_bits + self.b;
        if filled >= 64 {
            self.body.extend_from_slice(&self.acc.to_le_bytes());
            self.acc_bits = filled - 64;
            self.acc = c >> (self.b - self.acc_bits);
        } else {
            self.acc_bits = filled;
        }
    }

    /// One slice at one norm — per-element arithmetic and RNG
    /// consumption order identical to [`quantize_slice_append`]; a
    /// zero-norm slice consumes no randomness.
    fn quantize_slice(&mut self, v: &[f32], norm: f32, rng: &mut Xoshiro256pp) {
        if norm == 0.0 {
            for _ in 0..v.len() {
                self.push_mag(0);
            }
            self.elem += v.len();
            return;
        }
        let s = self.mask as f64;
        let inv = 1.0 / norm as f64;
        for &x in v {
            if x < 0.0 {
                self.body[self.elem / 8] |= 1 << (self.elem % 8);
            }
            self.elem += 1;
            let a = (x.abs() as f64 * inv * s).min(s);
            let l = a.floor();
            let p = a - l;
            let code = if rng.next_f64() < p { l + 1.0 } else { l };
            self.push_mag(code.min(s) as u32);
        }
    }

    fn finish(self) {
        if self.acc_bits > 0 {
            let tail = (self.acc_bits as usize).div_ceil(8);
            self.body.extend_from_slice(&self.acc.to_le_bytes()[..tail]);
        }
    }
}

/// Stochastically quantize one slice at one norm, *appending* codes —
/// the shared core of the global and sectioned quantizers. Per-element
/// arithmetic (and RNG consumption order) is unchanged from the
/// pre-sectioning implementation; a zero-norm slice consumes no
/// randomness.
fn quantize_slice_append(
    v: &[f32],
    bits: u8,
    norm: f32,
    rng: &mut Xoshiro256pp,
    mags: &mut Vec<u32>,
    signs: &mut Vec<bool>,
) {
    if norm == 0.0 {
        mags.resize(mags.len() + v.len(), 0);
        signs.resize(signs.len() + v.len(), false);
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let inv = 1.0 / norm as f64;
    for &x in v {
        signs.push(x < 0.0);
        let a = (x.abs() as f64 * inv * s).min(s);
        let l = a.floor();
        let p = a - l;
        let code = if rng.next_f64() < p { l + 1.0 } else { l };
        mags.push(code.min(s) as u32);
    }
}

/// Reconstruct the (unbiased) estimate of `v` (with the section's own
/// norm for sectioned vectors).
pub fn dequantize_into(q: &QsgdVec, out: &mut [f32]) {
    assert_eq!(q.mags.len(), out.len());
    if q.is_sectioned() {
        let mut off = 0usize;
        for &(norm, len) in &q.section_scales {
            let len = len as usize;
            dequantize_slice(
                &q.mags[off..off + len],
                &q.signs[off..off + len],
                q.bits,
                norm,
                &mut out[off..off + len],
            );
            off += len;
        }
        debug_assert_eq!(off, out.len());
        return;
    }
    dequantize_slice(&q.mags, &q.signs, q.bits, q.norm, out);
}

/// Reconstruction of one slice at one norm — shared by the global and
/// sectioned [`dequantize_into`] paths.
fn dequantize_slice(mags: &[u32], signs: &[bool], bits: u8, norm: f32, out: &mut [f32]) {
    if norm == 0.0 {
        out.fill(0.0);
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let scale = norm as f64 / s;
    for i in 0..out.len() {
        let mag = scale * mags[i] as f64;
        out[i] = if signs[i] { -mag } else { mag } as f32;
    }
}

/// Reconstruct `Q(v)` into a fresh vector (allocating reference path).
pub fn dequantize(q: &QsgdVec) -> Vec<f32> {
    let mut out = vec![0.0f32; q.dim()];
    dequantize_into(q, &mut out);
    out
}

/// Fused server-side kernel (§Perf): reconstruct magnitudes
/// `codes.start..codes.end` straight from the packed wire body (sign
/// bitmap + packed magnitude codes) and scatter-add `scale · Q(v)ᵢ`
/// into one contiguous output shard. Mirrors
/// [`crate::quant::midtread::dequantize_scatter_add`]; per-element
/// arithmetic matches [`dequantize_into`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn dequantize_scatter_add(
    signs: &[u8],
    mags: &[u8],
    bits: u8,
    norm: f32,
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    if codes.is_empty() || norm == 0.0 {
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let qscale = norm as f64 / s;
    match targets {
        None => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[i - out_base] += scale * v;
                i += 1;
            });
        }
        Some(idx) => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[idx[i] as usize - out_base] += scale * v;
                i += 1;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath::{norm2_sq, sub};

    #[test]
    fn zero_vector() {
        let mut rng = Xoshiro256pp::seed_from_u64(30);
        let q = quantize(&[0.0; 16], 4, &mut rng);
        assert_eq!(q.norm, 0.0);
        assert_eq!(dequantize(&q), vec![0.0; 16]);
    }

    #[test]
    fn unbiased_in_expectation() {
        let v = [0.3f32, -0.7, 0.05, 0.0, 1.0];
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let trials = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let dq = dequantize(&quantize(&v, 2, &mut rng));
            for (a, x) in acc.iter_mut().zip(&dq) {
                *a += *x as f64;
            }
        }
        for (a, &orig) in acc.iter().zip(&v) {
            let mean = a / trials as f64;
            assert!(
                (mean - orig as f64).abs() < 0.02,
                "biased: {mean} vs {orig}"
            );
        }
    }

    #[test]
    fn codes_fit_in_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let v: Vec<f32> = (0..500).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        for bits in [1u8, 4, 8] {
            let q = quantize(&v, bits, &mut rng);
            let max = (1u64 << bits) - 1;
            assert!(q.mags.iter().all(|&c| (c as u64) <= max));
        }
    }

    #[test]
    fn variance_decreases_with_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let v: Vec<f32> = (0..256).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut errs = Vec::new();
        for bits in [1u8, 4, 8] {
            let mut total = 0.0;
            for _ in 0..50 {
                let dq = dequantize(&quantize(&v, bits, &mut rng));
                let mut e = vec![0.0f32; v.len()];
                sub(&v, &dq, &mut e);
                total += norm2_sq(&e);
            }
            errs.push(total);
        }
        assert!(errs[0] > errs[1]);
        assert!(errs[1] > errs[2]);
    }

    #[test]
    fn scatter_add_matches_dequantize_then_add() {
        use crate::quant::packing::{pack, pack_signs};
        let mut rng = Xoshiro256pp::seed_from_u64(35);
        let d = 203;
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        let q = quantize(&v, 5, &mut rng);
        let signs = pack_signs(&q.signs);
        let mags = pack(&q.mags, 5);
        let mut expect = vec![0.0f32; d];
        let dq = dequantize(&q);
        for (e, x) in expect.iter_mut().zip(&dq) {
            *e += 0.75 * x;
        }
        // Two shards split at 64.
        let mut out = vec![0.0f32; d];
        let (lo, hi) = out.split_at_mut(64);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 0..64, None, 0, 0.75, lo);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 64..d, None, 64, 0.75, hi);
        for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
        }
    }

    #[test]
    fn sectioned_single_section_is_global() {
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(40);
        let v: Vec<f32> = (0..65).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut r1 = Xoshiro256pp::seed_from_u64(41);
        let mut r2 = Xoshiro256pp::seed_from_u64(41);
        let global = quantize(&v, 4, &mut r1);
        let sect = quantize_sections(&v, 4, &Sections::global(v.len()), &mut r2);
        assert_eq!(global, sect);
        assert!(!sect.is_sectioned());
    }

    #[test]
    fn sectioned_norms_follow_sections() {
        use crate::quant::Sections;
        let mut v = vec![0.01f32, -0.01, 0.01, -0.01];
        v.extend_from_slice(&[30.0, -40.0, 0.0, 0.0]);
        let sections = Sections::from_lens([4usize, 4]);
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let q = quantize_sections(&v, 6, &sections, &mut rng);
        assert!(q.is_sectioned());
        assert_eq!(q.section_scales.len(), 2);
        assert_eq!(q.section_scales[0].1, 4);
        assert_eq!(q.section_scales[1], (50.0, 4)); // 3-4-5 triangle
        // Reconstruction error of the small section is bounded by its
        // own norm, not the global one.
        let dq = dequantize(&q);
        let s = crate::quant::code_mask(6) as f64;
        for i in 0..4 {
            let bound = q.section_scales[0].0 as f64 / s + 1e-9;
            assert!(((v[i] - dq[i]).abs() as f64) <= bound, "i={i}");
        }
    }

    #[test]
    fn packed_matches_quantize_then_pack() {
        use crate::quant::packing::{pack_into, pack_signs_into};
        let mut rng = Xoshiro256pp::seed_from_u64(50);
        let d = 517;
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.5)).collect();
        for bits in [1u8, 4, 6, 12, 13, 16] {
            let mut r1 = Xoshiro256pp::seed_from_u64(51);
            let mut r2 = Xoshiro256pp::seed_from_u64(51);
            let q = quantize(&v, bits, &mut r1);
            let mut expect = Vec::new();
            pack_signs_into(&q.signs, &mut expect);
            pack_into(&q.mags, bits, &mut expect);
            let p = quantize_packed(&v, bits, &mut r2);
            assert_eq!(p.body, expect, "bits={bits}");
            assert_eq!(p.scale.to_bits(), q.norm.to_bits());
            assert_eq!(p.dim(), d);
            assert!(!p.is_sectioned());
            // Both paths consumed the same randomness.
            assert_eq!(r1.next_u64(), r2.next_u64(), "bits={bits}");
        }
    }

    #[test]
    fn packed_sections_matches_compose_and_zero_norm_skips_rng() {
        use crate::quant::packing::{pack_into, pack_signs_into};
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let mut v: Vec<f32> = (0..120).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        v.extend(std::iter::repeat(0.0f32).take(64)); // zero-norm section
        v.extend((0..117).map(|_| rng.gaussian_f32(0.0, 3.0)));
        let sections = Sections::from_lens([120usize, 64, 117]);
        let mut r1 = Xoshiro256pp::seed_from_u64(53);
        let mut r2 = Xoshiro256pp::seed_from_u64(53);
        let q = quantize_sections(&v, 5, &sections, &mut r1);
        let mut expect = Vec::new();
        pack_signs_into(&q.signs, &mut expect);
        pack_into(&q.mags, 5, &mut expect);
        let p = quantize_sections_packed_buf(&v, 5, &sections, &mut r2, Vec::new());
        assert_eq!(p.body, expect);
        assert_eq!(p.section_scales, q.section_scales);
        assert_eq!(p.scale.to_bits(), q.norm.to_bits());
        assert_eq!(r1.next_u64(), r2.next_u64());
        // Single-section partitions delegate to the global form.
        let mut r3 = Xoshiro256pp::seed_from_u64(53);
        let g = quantize_sections_packed_buf(&v, 5, &Sections::global(v.len()), &mut r3, Vec::new());
        assert!(!g.is_sectioned());
    }

    #[test]
    fn packed_buf_reuses_capacity_without_stale_bytes() {
        let mut rng = Xoshiro256pp::seed_from_u64(54);
        let v: Vec<f32> = (0..300).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut r = Xoshiro256pp::seed_from_u64(55);
        let p = quantize_packed_buf(&v, 4, &mut r, Vec::with_capacity(4096));
        let ptr = p.body.as_ptr();
        // Poison the buffer, re-quantize a shorter vector: stale bytes
        // must not leak into the sign bitmap or the packed magnitudes.
        let mut body = p.body;
        body.resize(4096, 0xFF);
        let mut r1 = Xoshiro256pp::seed_from_u64(56);
        let mut r2 = Xoshiro256pp::seed_from_u64(56);
        let p2 = quantize_packed_buf(&v[..100], 4, &mut r1, body);
        let fresh = quantize_packed_buf(&v[..100], 4, &mut r2, Vec::new());
        assert_eq!(p2.body, fresh.body);
        assert_eq!(p2.body.as_ptr(), ptr);
    }

    #[test]
    fn max_element_exact_at_full_prob() {
        // |v_i| = ‖v‖₂ for a one-hot vector: a = s exactly, code = s,
        // reconstruction exact regardless of rng.
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let mut v = vec![0.0f32; 32];
        v[5] = -2.5;
        let q = quantize(&v, 3, &mut rng);
        let dq = dequantize(&q);
        assert!((dq[5] + 2.5).abs() < 1e-6);
    }
}
