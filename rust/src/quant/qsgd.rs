//! QSGD stochastic quantizer (Alistarh et al., 2017) — the fixed-level
//! stochastic baseline column of Tables II/III.
//!
//! For a vector `v` and `s = 2^b − 1` levels:
//!
//! ```text
//! Q(vᵢ) = ‖v‖₂ · sign(vᵢ) · ξᵢ,     ξᵢ ∈ {l/s, (l+1)/s}
//! ```
//!
//! where `l = floor(|vᵢ|/‖v‖₂ · s)` and `ξᵢ = (l+1)/s` with probability
//! `|vᵢ|/‖v‖₂·s − l` (stochastic rounding — unbiased: `E[Q(v)] = v`).
//!
//! Wire format: `‖v‖₂` (f32) + 1 sign bit + `b` magnitude bits per
//! element.

use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::norm2;

/// A QSGD-quantized vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QsgdVec {
    /// Magnitude bits per element.
    pub bits: u8,
    /// `‖v‖₂` scale. For sectioned vectors this is the max section
    /// norm, kept for metrics; reconstruction uses `section_scales`.
    pub norm: f32,
    /// Magnitude codes in `[0, 2^b − 1]`.
    pub mags: Vec<u32>,
    /// Sign bits (true = negative).
    pub signs: Vec<bool>,
    /// Per-section `(‖v_s‖₂, len)` pairs (`crate::quant::sections`;
    /// serialized as the wire v2 section table). Empty = single global
    /// `norm` — the v1 wire form.
    pub section_scales: Vec<(f32, u32)>,
}

impl QsgdVec {
    /// Element count `d`.
    pub fn dim(&self) -> usize {
        self.mags.len()
    }

    /// Whether this vector carries per-section norms (wire v2).
    pub fn is_sectioned(&self) -> bool {
        !self.section_scales.is_empty()
    }
}

/// Stochastically quantize `v` at `bits` magnitude bits.
pub fn quantize(v: &[f32], bits: u8, rng: &mut Xoshiro256pp) -> QsgdVec {
    quantize_buf(v, bits, rng, Vec::new(), Vec::new())
}

/// Buffer-reusing form of [`quantize`]: `mags`/`signs` are cleared and
/// refilled keeping their capacity, then owned by the returned
/// [`QsgdVec`] (the coordinator recycles them per device — §Perf).
pub fn quantize_buf(
    v: &[f32],
    bits: u8,
    rng: &mut Xoshiro256pp,
    mut mags: Vec<u32>,
    mut signs: Vec<bool>,
) -> QsgdVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    let norm = norm2(v) as f32;
    mags.clear();
    mags.reserve(v.len());
    signs.clear();
    signs.reserve(v.len());
    quantize_slice_append(v, bits, norm, rng, &mut mags, &mut signs);
    QsgdVec {
        bits,
        norm,
        mags,
        signs,
        section_scales: Vec::new(),
    }
}

/// Section-aware [`quantize`]: one norm `‖v_s‖₂` per section of
/// `sections`. A single-section partition produces the plain global
/// form — byte-identical on the wire to [`quantize`].
pub fn quantize_sections(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    rng: &mut Xoshiro256pp,
) -> QsgdVec {
    quantize_sections_buf(v, bits, sections, rng, Vec::new(), Vec::new())
}

/// Buffer-reusing form of [`quantize_sections`] (see [`quantize_buf`]
/// for the recycling contract).
pub fn quantize_sections_buf(
    v: &[f32],
    bits: u8,
    sections: &crate::quant::Sections,
    rng: &mut Xoshiro256pp,
    mut mags: Vec<u32>,
    mut signs: Vec<bool>,
) -> QsgdVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    assert_eq!(sections.total(), v.len(), "sections must cover the vector");
    if sections.is_global() {
        return quantize_buf(v, bits, rng, mags, signs);
    }
    mags.clear();
    mags.reserve(v.len());
    signs.clear();
    signs.reserve(v.len());
    let mut scales = Vec::with_capacity(sections.count());
    let mut norm = 0.0f32;
    for r in sections.iter() {
        let slice = &v[r.clone()];
        let ns = norm2(slice) as f32;
        quantize_slice_append(slice, bits, ns, rng, &mut mags, &mut signs);
        scales.push((ns, r.len() as u32));
        norm = norm.max(ns);
    }
    QsgdVec {
        bits,
        norm,
        mags,
        signs,
        section_scales: scales,
    }
}

/// Stochastically quantize one slice at one norm, *appending* codes —
/// the shared core of the global and sectioned quantizers. Per-element
/// arithmetic (and RNG consumption order) is unchanged from the
/// pre-sectioning implementation; a zero-norm slice consumes no
/// randomness.
fn quantize_slice_append(
    v: &[f32],
    bits: u8,
    norm: f32,
    rng: &mut Xoshiro256pp,
    mags: &mut Vec<u32>,
    signs: &mut Vec<bool>,
) {
    if norm == 0.0 {
        mags.resize(mags.len() + v.len(), 0);
        signs.resize(signs.len() + v.len(), false);
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let inv = 1.0 / norm as f64;
    for &x in v {
        signs.push(x < 0.0);
        let a = (x.abs() as f64 * inv * s).min(s);
        let l = a.floor();
        let p = a - l;
        let code = if rng.next_f64() < p { l + 1.0 } else { l };
        mags.push(code.min(s) as u32);
    }
}

/// Reconstruct the (unbiased) estimate of `v` (with the section's own
/// norm for sectioned vectors).
pub fn dequantize_into(q: &QsgdVec, out: &mut [f32]) {
    assert_eq!(q.mags.len(), out.len());
    if q.is_sectioned() {
        let mut off = 0usize;
        for &(norm, len) in &q.section_scales {
            let len = len as usize;
            dequantize_slice(
                &q.mags[off..off + len],
                &q.signs[off..off + len],
                q.bits,
                norm,
                &mut out[off..off + len],
            );
            off += len;
        }
        debug_assert_eq!(off, out.len());
        return;
    }
    dequantize_slice(&q.mags, &q.signs, q.bits, q.norm, out);
}

/// Reconstruction of one slice at one norm — shared by the global and
/// sectioned [`dequantize_into`] paths.
fn dequantize_slice(mags: &[u32], signs: &[bool], bits: u8, norm: f32, out: &mut [f32]) {
    if norm == 0.0 {
        out.fill(0.0);
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let scale = norm as f64 / s;
    for i in 0..out.len() {
        let mag = scale * mags[i] as f64;
        out[i] = if signs[i] { -mag } else { mag } as f32;
    }
}

/// Reconstruct `Q(v)` into a fresh vector (allocating reference path).
pub fn dequantize(q: &QsgdVec) -> Vec<f32> {
    let mut out = vec![0.0f32; q.dim()];
    dequantize_into(q, &mut out);
    out
}

/// Fused server-side kernel (§Perf): reconstruct magnitudes
/// `codes.start..codes.end` straight from the packed wire body (sign
/// bitmap + packed magnitude codes) and scatter-add `scale · Q(v)ᵢ`
/// into one contiguous output shard. Mirrors
/// [`crate::quant::midtread::dequantize_scatter_add`]; per-element
/// arithmetic matches [`dequantize_into`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn dequantize_scatter_add(
    signs: &[u8],
    mags: &[u8],
    bits: u8,
    norm: f32,
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    if codes.is_empty() || norm == 0.0 {
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let qscale = norm as f64 / s;
    match targets {
        None => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[i - out_base] += scale * v;
                i += 1;
            });
        }
        Some(idx) => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[idx[i] as usize - out_base] += scale * v;
                i += 1;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath::{norm2_sq, sub};

    #[test]
    fn zero_vector() {
        let mut rng = Xoshiro256pp::seed_from_u64(30);
        let q = quantize(&[0.0; 16], 4, &mut rng);
        assert_eq!(q.norm, 0.0);
        assert_eq!(dequantize(&q), vec![0.0; 16]);
    }

    #[test]
    fn unbiased_in_expectation() {
        let v = [0.3f32, -0.7, 0.05, 0.0, 1.0];
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let trials = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let dq = dequantize(&quantize(&v, 2, &mut rng));
            for (a, x) in acc.iter_mut().zip(&dq) {
                *a += *x as f64;
            }
        }
        for (a, &orig) in acc.iter().zip(&v) {
            let mean = a / trials as f64;
            assert!(
                (mean - orig as f64).abs() < 0.02,
                "biased: {mean} vs {orig}"
            );
        }
    }

    #[test]
    fn codes_fit_in_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let v: Vec<f32> = (0..500).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        for bits in [1u8, 4, 8] {
            let q = quantize(&v, bits, &mut rng);
            let max = (1u64 << bits) - 1;
            assert!(q.mags.iter().all(|&c| (c as u64) <= max));
        }
    }

    #[test]
    fn variance_decreases_with_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let v: Vec<f32> = (0..256).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut errs = Vec::new();
        for bits in [1u8, 4, 8] {
            let mut total = 0.0;
            for _ in 0..50 {
                let dq = dequantize(&quantize(&v, bits, &mut rng));
                let mut e = vec![0.0f32; v.len()];
                sub(&v, &dq, &mut e);
                total += norm2_sq(&e);
            }
            errs.push(total);
        }
        assert!(errs[0] > errs[1]);
        assert!(errs[1] > errs[2]);
    }

    #[test]
    fn scatter_add_matches_dequantize_then_add() {
        use crate::quant::packing::{pack, pack_signs};
        let mut rng = Xoshiro256pp::seed_from_u64(35);
        let d = 203;
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        let q = quantize(&v, 5, &mut rng);
        let signs = pack_signs(&q.signs);
        let mags = pack(&q.mags, 5);
        let mut expect = vec![0.0f32; d];
        let dq = dequantize(&q);
        for (e, x) in expect.iter_mut().zip(&dq) {
            *e += 0.75 * x;
        }
        // Two shards split at 64.
        let mut out = vec![0.0f32; d];
        let (lo, hi) = out.split_at_mut(64);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 0..64, None, 0, 0.75, lo);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 64..d, None, 64, 0.75, hi);
        for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
        }
    }

    #[test]
    fn sectioned_single_section_is_global() {
        use crate::quant::Sections;
        let mut rng = Xoshiro256pp::seed_from_u64(40);
        let v: Vec<f32> = (0..65).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut r1 = Xoshiro256pp::seed_from_u64(41);
        let mut r2 = Xoshiro256pp::seed_from_u64(41);
        let global = quantize(&v, 4, &mut r1);
        let sect = quantize_sections(&v, 4, &Sections::global(v.len()), &mut r2);
        assert_eq!(global, sect);
        assert!(!sect.is_sectioned());
    }

    #[test]
    fn sectioned_norms_follow_sections() {
        use crate::quant::Sections;
        let mut v = vec![0.01f32, -0.01, 0.01, -0.01];
        v.extend_from_slice(&[30.0, -40.0, 0.0, 0.0]);
        let sections = Sections::from_lens([4usize, 4]);
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let q = quantize_sections(&v, 6, &sections, &mut rng);
        assert!(q.is_sectioned());
        assert_eq!(q.section_scales.len(), 2);
        assert_eq!(q.section_scales[0].1, 4);
        assert_eq!(q.section_scales[1], (50.0, 4)); // 3-4-5 triangle
        // Reconstruction error of the small section is bounded by its
        // own norm, not the global one.
        let dq = dequantize(&q);
        let s = crate::quant::code_mask(6) as f64;
        for i in 0..4 {
            let bound = q.section_scales[0].0 as f64 / s + 1e-9;
            assert!(((v[i] - dq[i]).abs() as f64) <= bound, "i={i}");
        }
    }

    #[test]
    fn max_element_exact_at_full_prob() {
        // |v_i| = ‖v‖₂ for a one-hot vector: a = s exactly, code = s,
        // reconstruction exact regardless of rng.
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let mut v = vec![0.0f32; 32];
        v[5] = -2.5;
        let q = quantize(&v, 3, &mut rng);
        let dq = dequantize(&q);
        assert!((dq[5] + 2.5).abs() < 1e-6);
    }
}
