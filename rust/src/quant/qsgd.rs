//! QSGD stochastic quantizer (Alistarh et al., 2017) — the fixed-level
//! stochastic baseline column of Tables II/III.
//!
//! For a vector `v` and `s = 2^b − 1` levels:
//!
//! ```text
//! Q(vᵢ) = ‖v‖₂ · sign(vᵢ) · ξᵢ,     ξᵢ ∈ {l/s, (l+1)/s}
//! ```
//!
//! where `l = floor(|vᵢ|/‖v‖₂ · s)` and `ξᵢ = (l+1)/s` with probability
//! `|vᵢ|/‖v‖₂·s − l` (stochastic rounding — unbiased: `E[Q(v)] = v`).
//!
//! Wire format: `‖v‖₂` (f32) + 1 sign bit + `b` magnitude bits per
//! element.

use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::norm2;

/// A QSGD-quantized vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QsgdVec {
    /// Magnitude bits per element.
    pub bits: u8,
    /// `‖v‖₂` scale.
    pub norm: f32,
    /// Magnitude codes in `[0, 2^b − 1]`.
    pub mags: Vec<u32>,
    /// Sign bits (true = negative).
    pub signs: Vec<bool>,
}

impl QsgdVec {
    /// Element count `d`.
    pub fn dim(&self) -> usize {
        self.mags.len()
    }
}

/// Stochastically quantize `v` at `bits` magnitude bits.
pub fn quantize(v: &[f32], bits: u8, rng: &mut Xoshiro256pp) -> QsgdVec {
    quantize_buf(v, bits, rng, Vec::new(), Vec::new())
}

/// Buffer-reusing form of [`quantize`]: `mags`/`signs` are cleared and
/// refilled keeping their capacity, then owned by the returned
/// [`QsgdVec`] (the coordinator recycles them per device — §Perf).
pub fn quantize_buf(
    v: &[f32],
    bits: u8,
    rng: &mut Xoshiro256pp,
    mut mags: Vec<u32>,
    mut signs: Vec<bool>,
) -> QsgdVec {
    assert!((1..=31).contains(&bits), "qsgd bits must be in 1..=31");
    let norm = norm2(v) as f32;
    let s = crate::quant::code_mask(bits) as f64;
    mags.clear();
    mags.reserve(v.len());
    signs.clear();
    signs.reserve(v.len());
    if norm == 0.0 {
        mags.resize(v.len(), 0);
        signs.resize(v.len(), false);
        return QsgdVec {
            bits,
            norm,
            mags,
            signs,
        };
    }
    let inv = 1.0 / norm as f64;
    for &x in v {
        signs.push(x < 0.0);
        let a = (x.abs() as f64 * inv * s).min(s);
        let l = a.floor();
        let p = a - l;
        let code = if rng.next_f64() < p { l + 1.0 } else { l };
        mags.push(code.min(s) as u32);
    }
    QsgdVec {
        bits,
        norm,
        mags,
        signs,
    }
}

/// Reconstruct the (unbiased) estimate of `v`.
pub fn dequantize_into(q: &QsgdVec, out: &mut [f32]) {
    assert_eq!(q.mags.len(), out.len());
    if q.norm == 0.0 {
        out.fill(0.0);
        return;
    }
    let s = crate::quant::code_mask(q.bits) as f64;
    let scale = q.norm as f64 / s;
    for i in 0..out.len() {
        let mag = scale * q.mags[i] as f64;
        out[i] = if q.signs[i] { -mag } else { mag } as f32;
    }
}

/// Reconstruct `Q(v)` into a fresh vector (allocating reference path).
pub fn dequantize(q: &QsgdVec) -> Vec<f32> {
    let mut out = vec![0.0f32; q.dim()];
    dequantize_into(q, &mut out);
    out
}

/// Fused server-side kernel (§Perf): reconstruct magnitudes
/// `codes.start..codes.end` straight from the packed wire body (sign
/// bitmap + packed magnitude codes) and scatter-add `scale · Q(v)ᵢ`
/// into one contiguous output shard. Mirrors
/// [`crate::quant::midtread::dequantize_scatter_add`]; per-element
/// arithmetic matches [`dequantize_into`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn dequantize_scatter_add(
    signs: &[u8],
    mags: &[u8],
    bits: u8,
    norm: f32,
    codes: std::ops::Range<usize>,
    targets: Option<&[u32]>,
    out_base: usize,
    scale: f32,
    out: &mut [f32],
) {
    if codes.is_empty() || norm == 0.0 {
        return;
    }
    let s = crate::quant::code_mask(bits) as f64;
    let qscale = norm as f64 / s;
    match targets {
        None => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[i - out_base] += scale * v;
                i += 1;
            });
        }
        Some(idx) => {
            let mut i = codes.start;
            crate::quant::packing::for_each_code(mags, bits, codes.start, codes.end, |c| {
                let mag = qscale * c as f64;
                let v = (if crate::quant::packing::sign_at(signs, i) { -mag } else { mag }) as f32;
                out[idx[i] as usize - out_base] += scale * v;
                i += 1;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath::{norm2_sq, sub};

    #[test]
    fn zero_vector() {
        let mut rng = Xoshiro256pp::seed_from_u64(30);
        let q = quantize(&[0.0; 16], 4, &mut rng);
        assert_eq!(q.norm, 0.0);
        assert_eq!(dequantize(&q), vec![0.0; 16]);
    }

    #[test]
    fn unbiased_in_expectation() {
        let v = [0.3f32, -0.7, 0.05, 0.0, 1.0];
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let trials = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..trials {
            let dq = dequantize(&quantize(&v, 2, &mut rng));
            for (a, x) in acc.iter_mut().zip(&dq) {
                *a += *x as f64;
            }
        }
        for (a, &orig) in acc.iter().zip(&v) {
            let mean = a / trials as f64;
            assert!(
                (mean - orig as f64).abs() < 0.02,
                "biased: {mean} vs {orig}"
            );
        }
    }

    #[test]
    fn codes_fit_in_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let v: Vec<f32> = (0..500).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        for bits in [1u8, 4, 8] {
            let q = quantize(&v, bits, &mut rng);
            let max = (1u64 << bits) - 1;
            assert!(q.mags.iter().all(|&c| (c as u64) <= max));
        }
    }

    #[test]
    fn variance_decreases_with_bits() {
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let v: Vec<f32> = (0..256).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let mut errs = Vec::new();
        for bits in [1u8, 4, 8] {
            let mut total = 0.0;
            for _ in 0..50 {
                let dq = dequantize(&quantize(&v, bits, &mut rng));
                let mut e = vec![0.0f32; v.len()];
                sub(&v, &dq, &mut e);
                total += norm2_sq(&e);
            }
            errs.push(total);
        }
        assert!(errs[0] > errs[1]);
        assert!(errs[1] > errs[2]);
    }

    #[test]
    fn scatter_add_matches_dequantize_then_add() {
        use crate::quant::packing::{pack, pack_signs};
        let mut rng = Xoshiro256pp::seed_from_u64(35);
        let d = 203;
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
        let q = quantize(&v, 5, &mut rng);
        let signs = pack_signs(&q.signs);
        let mags = pack(&q.mags, 5);
        let mut expect = vec![0.0f32; d];
        let dq = dequantize(&q);
        for (e, x) in expect.iter_mut().zip(&dq) {
            *e += 0.75 * x;
        }
        // Two shards split at 64.
        let mut out = vec![0.0f32; d];
        let (lo, hi) = out.split_at_mut(64);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 0..64, None, 0, 0.75, lo);
        dequantize_scatter_add(&signs, &mags, 5, q.norm, 64..d, None, 64, 0.75, hi);
        for (i, (a, b)) in out.iter().zip(&expect).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "i={i}");
        }
    }

    #[test]
    fn max_element_exact_at_full_prob() {
        // |v_i| = ‖v‖₂ for a one-hot vector: a = s exactly, code = s,
        // reconstruction exact regardless of rng.
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let mut v = vec![0.0f32; 32];
        v[5] = -2.5;
        let q = quantize(&v, 3, &mut rng);
        let dq = dequantize(&q);
        assert!((dq[5] + 2.5).abs() < 1e-6);
    }
}
