//! Criterion-style micro-benchmark harness (the offline registry has no
//! `criterion`; see DESIGN.md §6).
//!
//! Provides warmup + timed sampling, robust statistics (mean / median /
//! std / min), throughput reporting, a black-box sink, and
//! machine-readable output: `--json <path>` (or `AQUILA_BENCH_JSON`)
//! makes [`Bench::finish`] write a `{commit, generated_at, cases}`
//! report — one `{name, mean_ns, median_ns, min_ns, elements,
//! elem_per_s, bytes, gb_per_s}` record per case (throughput fields
//! derived from the mean; `Null` when the case declared no element or
//! byte volume), stamped with the git commit hash and an ISO-8601 UTC
//! timestamp so the committed `BENCH_*.json` trajectory in the repo
//! root stays attributable across PRs. All `rust/benches/*.rs`
//! binaries are built on this.

use crate::util::json::{obj, Json};
use std::hint::black_box as bb;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Re-exported opaque sink preventing the optimizer from deleting the
/// benchmarked computation.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Case name.
    pub name: String,
    /// Number of timed samples collected.
    pub samples: usize,
    /// Mean sample duration.
    pub mean: Duration,
    /// Median sample duration.
    pub median: Duration,
    /// Sample standard deviation.
    pub std_dev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Optional elements-per-iteration for throughput displays.
    pub elements: Option<u64>,
    /// Optional bytes-per-iteration for bandwidth (GB/s) displays —
    /// the bytes the case actually moves (reads + writes), so
    /// bandwidth-bound kernels report against the memory wall.
    pub bytes: Option<u64>,
}

impl Stats {
    /// Throughput in elements/second (when `elements` is set).
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.mean.as_secs_f64())
            .filter(|t| t.is_finite())
    }

    /// Bandwidth in GB/s (when `bytes` is set), from the mean sample.
    pub fn gb_per_s(&self) -> Option<f64> {
        self.bytes
            .map(|b| b as f64 / self.mean.as_secs_f64() / 1e9)
            .filter(|g| g.is_finite())
    }

    /// One human-readable summary line (mean/median/σ/min + throughput).
    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:>8.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:>8.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:>8.0} elem/s", t),
            None => String::new(),
        };
        let bw = match self.gb_per_s() {
            Some(g) => format!("  {g:>7.2} GB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>12?}  median {:>12?}  σ {:>10?}  min {:>12?}{tp}{bw}",
            self.name, self.mean, self.median, self.std_dev, self.min
        )
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Warmup duration before sampling.
    pub warmup: Duration,
    /// Sampling budget.
    pub budget: Duration,
    /// Max samples.
    pub max_samples: usize,
    /// Where to write the JSON report at [`Bench::finish`], if anywhere.
    pub json_path: Option<PathBuf>,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Runner with the default budgets (shrunk under `AQUILA_BENCH_FAST=1`).
    pub fn new() -> Self {
        // AQUILA_BENCH_FAST=1 shrinks budgets (CI smoke).
        let fast = std::env::var("AQUILA_BENCH_FAST").is_ok();
        Self {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(200)
            },
            budget: if fast {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(1)
            },
            max_samples: 1000,
            json_path: None,
            results: Vec::new(),
        }
    }

    /// [`Bench::new`] plus CLI/env configuration: `--json <path>` on
    /// the bench binary's argv (or the `AQUILA_BENCH_JSON` env var)
    /// selects the JSON report path. Every bench binary constructs its
    /// runner through this.
    pub fn from_env_args() -> Self {
        let mut bench = Self::new();
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--json" {
                match args.next() {
                    Some(p) => bench.json_path = Some(PathBuf::from(p)),
                    None => eprintln!("--json requires a path argument"),
                }
            }
        }
        if bench.json_path.is_none() {
            if let Ok(p) = std::env::var("AQUILA_BENCH_JSON") {
                if !p.is_empty() {
                    bench.json_path = Some(PathBuf::from(p));
                }
            }
        }
        bench
    }

    /// Time `f` repeatedly; one sample = one call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Stats {
        self.bench_elements(name, None, None, &mut f)
    }

    /// Time `f`, reporting throughput as `elements` per call.
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, elements: u64, mut f: F) -> &Stats {
        self.bench_elements(name, Some(elements), None, &mut f)
    }

    /// Time `f`, reporting element throughput *and* memory bandwidth:
    /// `bytes` is the traffic one call moves (reads + writes), so the
    /// JSON report carries a `gb_per_s` figure comparable against the
    /// machine's memory bandwidth.
    pub fn bench_gbps<F: FnMut()>(
        &mut self,
        name: &str,
        elements: u64,
        bytes: u64,
        mut f: F,
    ) -> &Stats {
        self.bench_elements(name, Some(elements), Some(bytes), &mut f)
    }

    fn bench_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &Stats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Sample.
        let mut times = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && times.len() < self.max_samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        if times.is_empty() {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let n = times.len();
        let total: Duration = times.iter().sum();
        let mean = total / n as u32;
        let median = times[n / 2];
        let min = times[0];
        let mean_s = mean.as_secs_f64();
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean_s;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        let stats = Stats {
            name: name.to_string(),
            samples: n,
            mean,
            median,
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min,
            elements,
            bytes,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All collected stats.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// The JSON report: `{commit, generated_at, cases}` — the
    /// provenance stamp makes every committed `BENCH_*.json`
    /// attributable to the exact tree that produced it.
    pub fn to_json(&self) -> Json {
        let cases = Json::Arr(
            self.results
                .iter()
                .map(|s| {
                    obj(vec![
                        ("name", Json::Str(s.name.clone())),
                        ("mean_ns", Json::Num(s.mean.as_nanos() as f64)),
                        ("median_ns", Json::Num(s.median.as_nanos() as f64)),
                        ("min_ns", Json::Num(s.min.as_nanos() as f64)),
                        (
                            "elements",
                            match s.elements {
                                Some(e) => Json::Num(e as f64),
                                None => Json::Null,
                            },
                        ),
                        (
                            "elem_per_s",
                            match s.throughput() {
                                Some(t) => Json::Num(t),
                                None => Json::Null,
                            },
                        ),
                        (
                            "bytes",
                            match s.bytes {
                                Some(b) => Json::Num(b as f64),
                                None => Json::Null,
                            },
                        ),
                        (
                            "gb_per_s",
                            match s.gb_per_s() {
                                Some(g) => Json::Num(g),
                                None => Json::Null,
                            },
                        ),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("commit", Json::Str(git_commit())),
            ("generated_at", Json::Str(iso8601_utc_now())),
            ("cases", cases),
        ])
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// Print a closing summary (and return it for tests); writes the
    /// JSON report when a path was configured.
    pub fn finish(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} benchmark cases ===\n", self.results.len()));
        if let Some(path) = &self.json_path {
            match self.write_json(path) {
                Ok(()) => out.push_str(&format!("wrote {}\n", path.display())),
                Err(e) => out.push_str(&format!("failed to write {}: {e}\n", path.display())),
            }
        }
        print!("{out}");
        out
    }
}

/// The commit hash stamped into bench reports: `AQUILA_GIT_COMMIT` if
/// set and non-blank (CI can inject it without a checkout), else
/// `git rev-parse HEAD`, else `"unknown"`.
fn git_commit() -> String {
    std::env::var("AQUILA_GIT_COMMIT")
        .ok()
        .as_deref()
        .and_then(nonempty_trimmed)
        .or_else(git_head)
        .unwrap_or_else(|| "unknown".to_string())
}

/// Trimmed copy of `s`, or `None` if blank — the override-acceptance
/// rule of [`git_commit`], kept pure so tests cover it without
/// mutating the process environment (which races with parallel tests
/// spawning subprocesses).
fn nonempty_trimmed(s: &str) -> Option<String> {
    let s = s.trim();
    (!s.is_empty()).then(|| s.to_string())
}

/// `git rev-parse HEAD` of the working directory, if available.
fn git_head() -> Option<String> {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .as_deref()
        .and_then(nonempty_trimmed)
}

/// Current UTC time as `YYYY-MM-DDTHH:MM:SSZ` (no chrono in the
/// offline registry; the civil-from-days conversion below is Howard
/// Hinnant's date algorithm).
fn iso8601_utc_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    iso8601_utc(secs)
}

/// Format seconds-since-epoch as ISO-8601 UTC.
fn iso8601_utc(secs: u64) -> String {
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (hh, mm, ss) = (rem / 3600, (rem % 3600) / 60, rem % 60);
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}T{hh:02}:{mm:02}:{ss:02}Z")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_bench() -> Bench {
        Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(10),
            max_samples: 50,
            json_path: None,
            results: Vec::new(),
        }
    }

    #[test]
    fn collects_samples_and_stats() {
        let mut b = fast_bench();
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let s = &b.results()[0];
        assert!(s.samples >= 1);
        assert!(s.min <= s.median && s.median <= s.mean * 10);
    }

    #[test]
    fn throughput_reported() {
        let mut b = fast_bench();
        let data = vec![1.0f32; 4096];
        b.bench_throughput("sum4096", 4096, || {
            black_box(data.iter().sum::<f32>());
        });
        let tp = b.results()[0].throughput().unwrap();
        assert!(tp > 1e6, "suspiciously slow: {tp}");
    }

    #[test]
    fn multiple_cases_accumulate() {
        let mut b = fast_bench();
        b.bench("a", || {});
        b.bench("b", || {});
        assert_eq!(b.results().len(), 2);
        assert!(b.finish().contains("2 benchmark cases"));
    }

    #[test]
    fn json_report_schema() {
        use crate::util::json::Json;
        let mut b = fast_bench();
        b.bench_throughput("tp", 128, || {});
        b.bench("plain", || {});
        b.bench_gbps("bw", 256, 1024, || {});
        let j = b.to_json();
        // Provenance stamp: commit + ISO-8601 UTC timestamp.
        let commit = j.get("commit").as_str().expect("commit present");
        assert!(!commit.is_empty());
        let ts = j.get("generated_at").as_str().expect("timestamp present");
        assert_eq!(ts.len(), 20, "not ISO-8601: {ts}");
        assert!(ts.ends_with('Z') && ts.as_bytes()[10] == b'T', "{ts}");
        let arr = j.get("cases").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("name").as_str(), Some("tp"));
        assert_eq!(arr[0].get("elements").as_f64(), Some(128.0));
        assert!(arr[0].get("mean_ns").as_f64().is_some());
        assert!(arr[0].get("median_ns").as_f64().is_some());
        assert!(arr[0].get("min_ns").as_f64().is_some());
        // Element throughput derives from mean; no byte volume ⇒ no
        // bandwidth figure.
        assert!(arr[0].get("elem_per_s").as_f64().unwrap() > 0.0);
        assert_eq!(arr[0].get("bytes"), &Json::Null);
        assert_eq!(arr[0].get("gb_per_s"), &Json::Null);
        assert_eq!(arr[1].get("elements"), &Json::Null);
        assert_eq!(arr[1].get("elem_per_s"), &Json::Null);
        // Byte-throughput case carries all four volume fields.
        assert_eq!(arr[2].get("elements").as_f64(), Some(256.0));
        assert_eq!(arr[2].get("bytes").as_f64(), Some(1024.0));
        assert!(arr[2].get("gb_per_s").as_f64().unwrap() > 0.0);
        // Round-trips through the parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn finish_writes_json_file() {
        let dir = std::env::temp_dir().join("aquila_benchkit_json");
        let path = dir.join("out.json");
        let mut b = fast_bench();
        b.json_path = Some(path.clone());
        b.bench("case", || {});
        b.finish();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(j.get("cases").as_arr().unwrap().len(), 1);
        assert!(j.get("commit").as_str().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn iso8601_known_instants() {
        assert_eq!(iso8601_utc(0), "1970-01-01T00:00:00Z");
        // 2000-02-29 (leap day) 12:34:56 UTC.
        assert_eq!(iso8601_utc(951_827_696), "2000-02-29T12:34:56Z");
        // 2023-01-01 00:00:00 UTC.
        assert_eq!(iso8601_utc(1_672_531_200), "2023-01-01T00:00:00Z");
    }

    #[test]
    fn commit_stamp_override_rule_and_fallback() {
        // The override-acceptance rule (pure — no env mutation, which
        // would race with parallel tests spawning subprocesses).
        assert_eq!(
            nonempty_trimmed(" deadbeefcafe \n").as_deref(),
            Some("deadbeefcafe")
        );
        assert_eq!(nonempty_trimmed("   "), None);
        assert_eq!(nonempty_trimmed(""), None);
        // The composed stamp is always non-empty and trimmed, whether
        // it came from the env, `git rev-parse`, or the sentinel.
        let c = git_commit();
        assert!(!c.is_empty());
        assert_eq!(c, c.trim());
    }
}
