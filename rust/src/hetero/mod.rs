//! HeteroFL-style heterogeneous model capacities (paper Section V-C).
//!
//! In HeteroFL [27] a device with capacity ratio `r_m` trains the
//! submodel `θ[: r_m·w, : r_m·h]` of every weight matrix (so about
//! `r_m²·d` parameters move on the wire). The paper's heterogeneous
//! experiments use a 100%–50% split: half the devices hold the full
//! model, half hold `r = 0.5`.
//!
//! We realize capacities as **index masks over the flat parameter
//! vector** computed from the model's [`ParamLayout`]: for each 2-D
//! tensor the leading `ceil(r·rows) × ceil(r·cols)` block, for each 1-D
//! tensor the leading `ceil(r·n)` prefix. Devices gather their support
//! before quantization and the server scatter-adds after decoding — so
//! the transmitted byte counts shrink by exactly the submodel ratio, as
//! in the paper. (Deviation from true HeteroFL — the full-model forward
//! still uses all coordinates; the gradient is masked — is documented in
//! DESIGN.md §3.)

use crate::problems::ParamLayout;
use std::sync::Arc;

/// A device's trainable-parameter support set.
#[derive(Clone, Debug, PartialEq)]
pub struct CapacityMask {
    /// Capacity ratio `r_m ∈ (0, 1]` this mask was built from.
    pub ratio: f32,
    /// Sorted flat indices the device trains/transmits.
    pub indices: Vec<u32>,
    /// Full model dimension.
    pub full_dim: usize,
}

impl CapacityMask {
    /// Identity mask (full-capacity device).
    pub fn full(d: usize) -> Self {
        Self {
            ratio: 1.0,
            indices: (0..d as u32).collect(),
            full_dim: d,
        }
    }

    /// Whether this mask is the identity.
    pub fn is_full(&self) -> bool {
        self.indices.len() == self.full_dim
    }

    /// Support size `|S_m|`.
    pub fn support(&self) -> usize {
        self.indices.len()
    }

    /// Build the HeteroFL mask at `ratio` from a layout.
    pub fn from_layout(layout: &ParamLayout, ratio: f32) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0, "ratio must be in (0, 1]");
        let full_dim = layout.dim();
        if ratio >= 1.0 {
            return Self::full(full_dim);
        }
        let mut indices = Vec::new();
        for e in &layout.entries {
            match e.shape.as_slice() {
                [n] => {
                    let take = ((*n as f32 * ratio).ceil() as usize).clamp(1, *n);
                    indices.extend((0..take as u32).map(|i| e.offset as u32 + i));
                }
                [rows, cols] => {
                    let tr = ((*rows as f32 * ratio).ceil() as usize).clamp(1, *rows);
                    let tc = ((*cols as f32 * ratio).ceil() as usize).clamp(1, *cols);
                    for r in 0..tr {
                        let base = e.offset + r * cols;
                        indices.extend((0..tc as u32).map(|c| base as u32 + c));
                    }
                }
                shape => {
                    // Higher-rank tensors: scale the leading dim only
                    // (matches HeteroFL's conv-channel slicing).
                    let lead = shape[0];
                    let rest: usize = shape[1..].iter().product();
                    let take = ((lead as f32 * ratio).ceil() as usize).clamp(1, lead);
                    let start = e.offset as u32;
                    indices.extend((0..(take * rest) as u32).map(|i| start + i));
                }
            }
        }
        indices.sort_unstable();
        indices.dedup();
        Self {
            ratio,
            indices,
            full_dim,
        }
    }

    /// Number of support indices falling in the flat index range
    /// `[lo, hi)` — how many elements of that slice of the full model
    /// this device actually trains/transmits. Used to resolve
    /// layout-aware quantization sections over the masked support
    /// (`crate::quant::sections`).
    pub fn support_in_range(&self, lo: usize, hi: usize) -> usize {
        if self.is_full() {
            hi.min(self.full_dim).saturating_sub(lo.min(self.full_dim))
        } else {
            let p0 = self.indices.partition_point(|&i| (i as usize) < lo);
            let p1 = self.indices.partition_point(|&i| (i as usize) < hi);
            p1 - p0
        }
    }

    /// Gather `src[full_dim] -> out[support]`.
    pub fn gather(&self, src: &[f32], out: &mut Vec<f32>) {
        assert_eq!(src.len(), self.full_dim);
        out.clear();
        out.extend(self.indices.iter().map(|&i| src[i as usize]));
    }

    /// Scatter-add `src[support] * scale` into `dst[full_dim]`.
    pub fn scatter_add(&self, src: &[f32], scale: f32, dst: &mut [f32]) {
        assert_eq!(src.len(), self.indices.len());
        assert_eq!(dst.len(), self.full_dim);
        for (k, &i) in self.indices.iter().enumerate() {
            dst[i as usize] += scale * src[k];
        }
    }
}

/// Build the paper's 100%–50% split: the first half of devices get the
/// full model, the second half capacity `ratio` (default 0.5).
pub fn half_half_masks(layout: &ParamLayout, m: usize, ratio: f32) -> Vec<Arc<CapacityMask>> {
    let full = Arc::new(CapacityMask::full(layout.dim()));
    let reduced = Arc::new(CapacityMask::from_layout(layout, ratio));
    (0..m)
        .map(|i| {
            if i < m / 2 {
                full.clone()
            } else {
                reduced.clone()
            }
        })
        .collect()
}

/// Per-device capacity assignment for an arbitrarily large population.
///
/// The dense `Vec<Arc<CapacityMask>>` form costs O(population) memory
/// even when every device shares one mask — the exact overhead the
/// million-device population spec (DESIGN.md §Population) removes. A
/// `MaskTable` answers "which mask does device `i` hold?" from O(1)
/// state for the shared-mask populations, while still admitting the
/// fully explicit per-device form for small heterogeneous fleets.
///
/// The mapping is positional and deterministic, so the coordinator and
/// a served [`crate::protocol::DeviceClient`] derive identical masks
/// from the same table description.
#[derive(Clone, Debug)]
pub enum MaskTable {
    /// Every device shares one mask (O(1) memory at any population).
    Uniform {
        /// The shared mask.
        mask: Arc<CapacityMask>,
        /// Population size.
        m: usize,
    },
    /// The paper's 100%–50% split derived positionally: devices
    /// `0..m/2` hold the full model, the rest the reduced mask —
    /// O(1) memory at any population size.
    HalfHalf {
        /// Mask of the full-capacity half (`0..m/2`).
        full: Arc<CapacityMask>,
        /// Mask of the reduced-capacity half (`m/2..m`).
        reduced: Arc<CapacityMask>,
        /// Population size.
        m: usize,
    },
    /// One explicit mask per device (the dense legacy form).
    PerDevice(Vec<Arc<CapacityMask>>),
}

impl MaskTable {
    /// The uniform full-capacity table — every device trains the whole
    /// `d`-dimensional model.
    pub fn uniform_full(d: usize, m: usize) -> Self {
        Self::Uniform {
            mask: Arc::new(CapacityMask::full(d)),
            m,
        }
    }

    /// The paper's 100%–50% split as an O(1) table (the spec-derived
    /// counterpart of [`half_half_masks`]): devices `0..m/2` full,
    /// `m/2..m` at `ratio`.
    pub fn half_half(layout: &ParamLayout, m: usize, ratio: f32) -> Self {
        Self::HalfHalf {
            full: Arc::new(CapacityMask::full(layout.dim())),
            reduced: Arc::new(CapacityMask::from_layout(layout, ratio)),
            m,
        }
    }

    /// Population size this table covers.
    pub fn num_devices(&self) -> usize {
        match self {
            Self::Uniform { m, .. } | Self::HalfHalf { m, .. } => *m,
            Self::PerDevice(v) => v.len(),
        }
    }

    /// The mask device `device` holds. Panics when out of range.
    pub fn get(&self, device: usize) -> &Arc<CapacityMask> {
        match self {
            Self::Uniform { mask, m } => {
                assert!(device < *m, "device {device} out of range (m = {m})");
                mask
            }
            Self::HalfHalf { full, reduced, m } => {
                assert!(device < *m, "device {device} out of range (m = {m})");
                if device < m / 2 {
                    full
                } else {
                    reduced
                }
            }
            Self::PerDevice(v) => &v[device],
        }
    }

    /// The distinct masks in this table (deduplicated by allocation for
    /// the dense form) — what section resolution iterates instead of
    /// the population.
    pub fn distinct_masks(&self) -> Vec<Arc<CapacityMask>> {
        match self {
            Self::Uniform { mask, .. } => vec![mask.clone()],
            Self::HalfHalf { full, reduced, .. } => vec![full.clone(), reduced.clone()],
            Self::PerDevice(v) => {
                let mut out: Vec<Arc<CapacityMask>> = Vec::new();
                for m in v {
                    if !out.iter().any(|o| Arc::ptr_eq(o, m)) {
                        out.push(m.clone());
                    }
                }
                out
            }
        }
    }
}

impl From<Vec<Arc<CapacityMask>>> for MaskTable {
    fn from(v: Vec<Arc<CapacityMask>>) -> Self {
        Self::PerDevice(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp_layout() -> ParamLayout {
        ParamLayout::contiguous(&[
            ("w1", vec![8, 6]),
            ("b1", vec![8]),
            ("w2", vec![4, 8]),
            ("b2", vec![4]),
        ])
    }

    #[test]
    fn full_mask_is_identity() {
        let m = CapacityMask::full(10);
        assert!(m.is_full());
        assert_eq!(m.support(), 10);
        let src: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut g = Vec::new();
        m.gather(&src, &mut g);
        assert_eq!(g, src);
    }

    #[test]
    fn half_ratio_takes_leading_blocks() {
        let layout = mlp_layout();
        let m = CapacityMask::from_layout(&layout, 0.5);
        // w1: 4×3 block of 8×6 = 12; b1: 4 of 8; w2: 2×4 of 4×8 = 8;
        // b2: 2 of 4. total 26.
        assert_eq!(m.support(), 12 + 4 + 8 + 2);
        // w1 row 0 cols 0..3 = indices 0,1,2; row 1 starts at 6.
        assert!(m.indices.starts_with(&[0, 1, 2, 6, 7, 8]));
        // b1 leading 4: offset 48.
        assert!(m.indices.contains(&48) && m.indices.contains(&51));
        assert!(!m.indices.contains(&52));
    }

    #[test]
    fn support_close_to_r_squared_for_matrices() {
        let layout = ParamLayout::contiguous(&[("w", vec![100, 100])]);
        let m = CapacityMask::from_layout(&layout, 0.5);
        assert_eq!(m.support(), 2500); // (0.5·100)² exactly
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let layout = mlp_layout();
        let mask = CapacityMask::from_layout(&layout, 0.5);
        let src: Vec<f32> = (0..layout.dim()).map(|i| (i as f32) * 0.5).collect();
        let mut gathered = Vec::new();
        mask.gather(&src, &mut gathered);
        assert_eq!(gathered.len(), mask.support());
        let mut dst = vec![0.0f32; layout.dim()];
        mask.scatter_add(&gathered, 2.0, &mut dst);
        for (i, &x) in dst.iter().enumerate() {
            if mask.indices.contains(&(i as u32)) {
                assert_eq!(x, src[i] * 2.0);
            } else {
                assert_eq!(x, 0.0, "leak outside mask at {i}");
            }
        }
    }

    #[test]
    fn masks_sorted_unique_in_range() {
        let layout = mlp_layout();
        for ratio in [0.25f32, 0.5, 0.75, 1.0] {
            let m = CapacityMask::from_layout(&layout, ratio);
            assert!(m.indices.windows(2).all(|w| w[0] < w[1]));
            assert!(m.indices.iter().all(|&i| (i as usize) < layout.dim()));
        }
    }

    #[test]
    fn half_half_split() {
        let layout = mlp_layout();
        let masks = half_half_masks(&layout, 10, 0.5);
        assert_eq!(masks.len(), 10);
        assert!(masks[..5].iter().all(|m| m.is_full()));
        assert!(masks[5..].iter().all(|m| !m.is_full() && m.ratio == 0.5));
    }

    #[test]
    fn support_in_range_counts_mask_hits() {
        let layout = mlp_layout();
        let half = CapacityMask::from_layout(&layout, 0.5);
        // w1 occupies flat [0, 48): the 0.5 mask keeps 4×3 = 12 of it.
        assert_eq!(half.support_in_range(0, 48), 12);
        // b1 occupies [48, 56): 4 kept.
        assert_eq!(half.support_in_range(48, 56), 4);
        // Whole vector: the full support.
        assert_eq!(half.support_in_range(0, layout.dim()), half.support());
        let full = CapacityMask::full(10);
        assert_eq!(full.support_in_range(3, 7), 4);
        assert_eq!(full.support_in_range(8, 99), 2);
        assert_eq!(full.support_in_range(7, 3), 0);
    }

    #[test]
    fn rank3_mask_scales_leading_dim() {
        let layout = ParamLayout::contiguous(&[("conv", vec![8, 3, 3])]);
        let m = CapacityMask::from_layout(&layout, 0.5);
        assert_eq!(m.support(), 4 * 9);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_ratio() {
        CapacityMask::from_layout(&mlp_layout(), 0.0);
    }

    #[test]
    fn mask_table_matches_dense_forms() {
        let layout = mlp_layout();
        // Half-half: positional table ≡ the dense helper, at any m.
        for m in [1usize, 2, 9, 10] {
            let dense = half_half_masks(&layout, m, 0.5);
            let table = MaskTable::half_half(&layout, m, 0.5);
            assert_eq!(table.num_devices(), m);
            for (i, want) in dense.iter().enumerate() {
                assert_eq!(table.get(i).indices, want.indices, "m={m} i={i}");
            }
            assert_eq!(table.distinct_masks().len(), 2);
        }
        // Uniform-full: every device sees the identity mask.
        let t = MaskTable::uniform_full(layout.dim(), 1_000_000);
        assert_eq!(t.num_devices(), 1_000_000);
        assert!(t.get(999_999).is_full());
        assert_eq!(t.distinct_masks().len(), 1);
        // Dense round-trip dedupes shared allocations.
        let dense = half_half_masks(&layout, 6, 0.5);
        let t = MaskTable::from(dense.clone());
        for (i, want) in dense.iter().enumerate() {
            assert!(Arc::ptr_eq(t.get(i), want));
        }
        assert_eq!(t.distinct_masks().len(), 2);
    }

    #[test]
    #[should_panic]
    fn mask_table_uniform_rejects_out_of_range() {
        MaskTable::uniform_full(4, 8).get(8);
    }
}
