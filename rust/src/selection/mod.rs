//! Pluggable device-selection strategies — the paper's eq. 8 context
//! made a first-class, injectable policy.
//!
//! AQUILA's headline contribution is an adaptive *device selection
//! strategy*; production FL coordinators (xaynet's
//! `Controller`/`RandomController` split, DAdaQuant's random-K cohorts)
//! likewise treat participant selection as a policy object rather than
//! a hardcoded `Option<Vec<usize>>`. A [`SelectionStrategy`] decides
//! each round's participant set from the round index, per-device upload
//! statistics, and the global loss history; the coordinator engine
//! (`crate::coordinator`) sorts the result and exposes it to algorithms
//! through `RoundCtx::selected`.
//!
//! Shipped strategies:
//!
//! | spec string | type | behaviour |
//! |---|---|---|
//! | `full` | [`FullParticipation`] | every device, every round |
//! | `random-k:K` | [`RandomK`] | uniform K-cohort (DAdaQuant-style) |
//! | `round-robin[:K]` | [`RoundRobin`] | deterministic rotating K-cohort |
//! | `loss-weighted:K` | [`LossWeighted`] | K-cohort sampled ∝ last local loss |
//! | `availability:P,D[,K]` | [`AvailabilityAware`] | per-device up/down duty cycles |
//!
//! Strategies are deterministic given the run seed **and the round
//! index**: stochastic strategies derive an independent
//! [`Xoshiro256pp`] stream from `(seed, round)` for every round rather
//! than consuming one sequential stream, so traces stay
//! bit-reproducible across runs and thread counts *and* a
//! checkpoint-resumed run selects exactly the cohorts the uninterrupted
//! run would have (no strategy state needs checkpointing).
//!
//! Since the population-virtualization redesign (DESIGN.md
//! §Population) strategies read per-device statistics through a
//! *sparse* [`DeviceStats`] map instead of a dense `&[DeviceView]`:
//! never-selected devices take the documented
//! [`DeviceView::default()`] (zero uploads/skips, no recorded loss), so
//! a million-device population costs O(devices touched) — not
//! O(population) — per round. The stochastic cohort samplers are O(K)
//! too: [`RandomK`] draws via Floyd's algorithm
//! ([`Xoshiro256pp::sample_floyd`]) and [`LossWeighted`] samples the
//! unobserved mass in closed form instead of materializing a weight
//! per device.

use crate::util::rng::Xoshiro256pp;
use std::collections::BTreeMap;

/// Derive the per-round RNG stream of a stochastic strategy: a fresh
/// stream keyed by `(seed, tag, round)`. Round-keying (rather than one
/// long-lived stream) is what makes checkpoint resume select-equivalent.
fn round_stream(seed: u64, tag: u64, round: usize) -> Xoshiro256pp {
    Xoshiro256pp::stream(
        seed,
        tag ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// Per-device statistics the coordinator exposes to strategies.
#[derive(Clone, Debug, Default)]
pub struct DeviceView {
    /// Rounds in which this device uploaded a payload.
    pub uploads: u64,
    /// Rounds in which this device participated but skipped (lazy
    /// algorithms).
    pub skips: u64,
    /// Most recent local training loss (`None` until the device first
    /// participates).
    pub last_loss: Option<f64>,
}

/// Sparse per-device statistics: the coordinator records a
/// [`DeviceView`] only for devices that have participated at least
/// once.
///
/// **Default for unseen devices**: a device with no entry reads as
/// [`DeviceView::default()`] — zero uploads, zero skips, `last_loss =
/// None` — exactly what a dense per-device vector held for it before
/// the population redesign, so strategies behave identically over the
/// sparse map and its dense reconstruction (pinned by
/// `tests/prop_population.rs`). Backed by a `BTreeMap` so iteration is
/// in ascending device id — selection must stay deterministic, and
/// hash-map iteration order is not.
#[derive(Clone, Debug, Default)]
pub struct DeviceStats {
    observed: BTreeMap<usize, DeviceView>,
}

impl DeviceStats {
    /// Empty map: every device reads as the documented default.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a dense per-device vector (index = device id) — the
    /// legacy representation, used by tests and the dense-path
    /// regression suite.
    pub fn from_dense(views: &[DeviceView]) -> Self {
        Self {
            observed: views.iter().cloned().enumerate().collect(),
        }
    }

    /// The statistics of `device`: its recorded entry, or the
    /// documented default when it has never been touched.
    pub fn get(&self, device: usize) -> DeviceView {
        self.observed.get(&device).cloned().unwrap_or_default()
    }

    /// Mutable entry for `device`, inserting the default on first
    /// touch. Only the coordinator calls this — a device gets an entry
    /// exactly when it first participates.
    pub fn entry(&mut self, device: usize) -> &mut DeviceView {
        self.observed.entry(device).or_default()
    }

    /// Iterate the recorded entries in ascending device id.
    pub fn observed(&self) -> impl Iterator<Item = (usize, &DeviceView)> {
        self.observed.iter().map(|(&id, v)| (id, v))
    }

    /// Number of devices with a recorded entry.
    pub fn observed_len(&self) -> usize {
        self.observed.len()
    }

    /// Replace the entry for `device` wholesale (checkpoint restore).
    pub fn insert(&mut self, device: usize, view: DeviceView) {
        self.observed.insert(device, view);
    }

    /// Drop every entry (checkpoint restore into a fresh run).
    pub fn clear(&mut self) {
        self.observed.clear();
    }
}

/// Read-only snapshot of the run state a strategy may consult when
/// choosing a cohort.
#[derive(Clone, Debug)]
pub struct SelectionView<'a> {
    /// Communication round `k` (0-based).
    pub round: usize,
    /// Total device count `M`.
    pub num_devices: usize,
    /// Sparse per-device statistics; devices without an entry read as
    /// the documented [`DeviceView::default()`].
    pub stats: &'a DeviceStats,
    /// `f(θ⁰)` estimate (NaN before round 0 completes).
    pub init_loss: f64,
    /// `f(θ^{k−1})` estimate (NaN before round 0 completes).
    pub prev_loss: f64,
    /// Recent global training losses, most recent first (bounded by the
    /// run's `history_depth`).
    pub loss_history: &'a [f64],
}

/// A strategy's verdict for one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Selection {
    /// Every device participates (no cohort restriction).
    All,
    /// Exactly these devices participate. The engine sorts, dedups,
    /// and range-checks before use; order and duplicates don't matter.
    Devices(Vec<usize>),
}

/// Decides each round's participant set. Implementations may be
/// stateful (cursors, RNG streams) — the coordinator calls `select`
/// exactly once per round, in round order.
pub trait SelectionStrategy: Send {
    /// Short name for banners/metrics (matches the spec-string head).
    fn name(&self) -> &'static str;

    /// Choose the participant set for `view.round`.
    fn select(&mut self, view: &SelectionView) -> Selection;
}

/// Every device participates every round — the setting of every
/// non-sampling algorithm in the paper's tables.
#[derive(Clone, Debug, Default)]
pub struct FullParticipation;

impl SelectionStrategy for FullParticipation {
    fn name(&self) -> &'static str {
        "full"
    }

    fn select(&mut self, _view: &SelectionView) -> Selection {
        Selection::All
    }
}

/// Uniform random K-cohort per round (DAdaQuant's client sampling; the
/// old `RunConfig::sample_k` behaviour).
#[derive(Clone, Debug)]
pub struct RandomK {
    k: usize,
    seed: u64,
}

impl RandomK {
    /// Uniform `k`-cohorts drawn from round-keyed streams of `seed`.
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1, "random-k cohort must be non-empty");
        Self { k, seed }
    }
}

impl SelectionStrategy for RandomK {
    fn name(&self) -> &'static str {
        "random-k"
    }

    fn select(&mut self, view: &SelectionView) -> Selection {
        let k = self.k.min(view.num_devices);
        let mut rng = round_stream(self.seed, 0x5E1E_C715, view.round);
        // Floyd's algorithm: O(k) memory at any population size. One
        // sampler for every N is what keeps the lazy million-device
        // path and the eager path cohort-identical. (Draw sequence
        // differs from the pre-population partial Fisher–Yates, so
        // seeded random-k traces shifted once at that redesign — same
        // licence as the round-keying change in PR 2.)
        Selection::Devices(rng.sample_floyd(view.num_devices, k))
    }
}

/// Deterministic rotating K-cohort: round `r` selects devices
/// `r·K..r·K+K (mod M)`, so every device is selected once per `⌈M/K⌉`
/// rounds. Stateless — the cohort is derived from the round index, so
/// checkpoint-resumed runs continue the rotation exactly.
#[derive(Clone, Debug)]
pub struct RoundRobin {
    k: usize,
}

impl RoundRobin {
    /// Rotating `k`-cohorts.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "round-robin cohort must be non-empty");
        Self { k }
    }
}

impl SelectionStrategy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(&mut self, view: &SelectionView) -> Selection {
        let m = view.num_devices.max(1);
        let k = self.k.min(m);
        let start = (view.round * k) % m;
        let ids = (0..k).map(|i| (start + i) % m).collect();
        Selection::Devices(ids)
    }
}

/// K-cohort sampled without replacement with probability proportional
/// to each device's most recent local loss — high-loss (straggling)
/// devices are heard from more often. Devices never yet observed get
/// the maximum weight so everyone is eventually explored.
#[derive(Clone, Debug)]
pub struct LossWeighted {
    k: usize,
    seed: u64,
}

impl LossWeighted {
    /// Loss-proportional `k`-cohorts drawn from round-keyed streams of
    /// `seed`.
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 1, "loss-weighted cohort must be non-empty");
        Self { k, seed }
    }
}

impl SelectionStrategy for LossWeighted {
    fn name(&self) -> &'static str {
        "loss-weighted"
    }

    fn select(&mut self, view: &SelectionView) -> Selection {
        let m = view.num_devices;
        let k = self.k.min(m);
        // Observed = devices with a finite recorded loss. Every other
        // device — never selected, or no finite loss yet — takes the
        // *default weight*: the worst observed loss (1.0 before any
        // observation), so unexplored devices are sampled at least as
        // often as the worst straggler and everyone is eventually
        // heard from. The unobserved mass is handled in closed form
        // (`unseen · default_w` plus a rank lookup), so a round costs
        // O(observed + k²), never O(population).
        let mut obs: Vec<(usize, f64)> = view
            .stats
            .observed()
            .filter_map(|(id, d)| {
                d.last_loss
                    .filter(|l| l.is_finite())
                    .map(|l| (id, l.max(1e-12)))
            })
            .collect();
        let default_w = obs
            .iter()
            .map(|&(_, w)| w)
            .fold(f64::NEG_INFINITY, f64::max);
        let default_w = if default_w.is_finite() { default_w } else { 1.0 };
        // `excluded` = ids absent from the unseen pool: every observed
        // id plus any unseen id already chosen. Kept sorted so the
        // rank → id mapping below is a single ascending scan.
        let mut excluded: Vec<usize> = obs.iter().map(|&(id, _)| id).collect();
        let mut obs_total: f64 = obs.iter().map(|&(_, w)| w).sum();
        let mut unseen = m - excluded.len();
        let mut rng = round_stream(self.seed, 0x1055_3E1E, view.round);
        let mut chosen = Vec::with_capacity(k);
        for _ in 0..k {
            let total = obs_total + unseen as f64 * default_w;
            let t = rng.next_f64() * total;
            if (t < obs_total || unseen == 0) && !obs.is_empty() {
                // Subtraction scan over the observed list in ascending
                // id order (floating-point slack lands on the last
                // observed entry).
                let mut acc = t.min(obs_total);
                let mut pos = obs.len() - 1;
                for (p, &(_, w)) in obs.iter().enumerate() {
                    acc -= w;
                    if acc <= 0.0 {
                        pos = p;
                        break;
                    }
                }
                let (id, w) = obs.remove(pos);
                obs_total -= w;
                chosen.push(id);
            } else {
                // The draw landed in the unobserved mass: map its rank
                // to the rank-th id not in `excluded`.
                let rank = (((t - obs_total) / default_w) as usize).min(unseen - 1);
                let mut id = rank;
                for &e in &excluded {
                    if e <= id {
                        id += 1;
                    } else {
                        break;
                    }
                }
                let ins = excluded.partition_point(|&e| e < id);
                excluded.insert(ins, id);
                unseen -= 1;
                chosen.push(id);
            }
        }
        Selection::Devices(chosen)
    }
}

/// Per-device periodic up/down schedule: device `m` is reachable in
/// round `r` iff `(r + phase_m) mod period < duty`. Models the
/// non-uniform participation the paper criticizes fixed-cohort
/// baselines for assuming away.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AvailabilitySchedule {
    /// Cycle length in rounds.
    pub period: usize,
    /// Rounds per cycle the device is up (`1..=period`).
    pub duty: usize,
    /// Per-device phase offsets.
    pub phases: Vec<usize>,
}

impl AvailabilitySchedule {
    /// Random per-device phases derived deterministically from `seed`.
    pub fn periodic(period: usize, duty: usize, num_devices: usize, seed: u64) -> Self {
        assert!(period >= 1, "period must be >= 1");
        assert!(
            (1..=period).contains(&duty),
            "duty must be in 1..=period (got {duty}/{period})"
        );
        let mut rng = Xoshiro256pp::stream(seed, 0xA7A1_1AB1);
        let phases = (0..num_devices)
            .map(|_| rng.next_bounded(period as u64) as usize)
            .collect();
        Self {
            period,
            duty,
            phases,
        }
    }

    /// Is `device` reachable in `round`?
    pub fn is_up(&self, device: usize, round: usize) -> bool {
        let phase = self.phases.get(device).copied().unwrap_or(0);
        (round + phase) % self.period < self.duty
    }
}

/// Selects among currently-available devices (per an
/// [`AvailabilitySchedule`]), optionally capped at a random `K`-subset
/// of them — the new availability scenario class.
#[derive(Clone, Debug)]
pub struct AvailabilityAware {
    schedule: AvailabilitySchedule,
    cap: Option<usize>,
    seed: u64,
}

impl AvailabilityAware {
    /// Availability-gated selection, optionally capped at `cap` devices.
    pub fn new(schedule: AvailabilitySchedule, cap: Option<usize>, seed: u64) -> Self {
        if let Some(k) = cap {
            assert!(k >= 1, "availability cap must be non-empty");
        }
        Self {
            schedule,
            cap,
            seed,
        }
    }

    /// The schedule this strategy follows.
    pub fn schedule(&self) -> &AvailabilitySchedule {
        &self.schedule
    }
}

impl SelectionStrategy for AvailabilityAware {
    fn name(&self) -> &'static str {
        "availability"
    }

    fn select(&mut self, view: &SelectionView) -> Selection {
        let up: Vec<usize> = (0..view.num_devices)
            .filter(|&i| self.schedule.is_up(i, view.round))
            .collect();
        match self.cap {
            Some(k) if up.len() > k => {
                let mut rng = round_stream(self.seed, 0xAB1E_CA90, view.round);
                let picks = rng.sample_indices(up.len(), k);
                Selection::Devices(picks.into_iter().map(|p| up[p]).collect())
            }
            _ => Selection::Devices(up),
        }
    }
}

/// Config-parseable description of a selection strategy — the
/// `--select` CLI flag and the `selection = "..."` TOML key.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum SelectionSpec {
    /// Every device, every round.
    #[default]
    Full,
    /// Uniform random `K`-cohort per round.
    RandomK(usize),
    /// Deterministic rotating `K`-cohort.
    RoundRobin(usize),
    /// `K`-cohort sampled proportional to last local loss.
    LossWeighted(usize),
    /// Periodic per-device availability windows, optionally capped.
    Availability {
        period: usize,
        duty: usize,
        cap: Option<usize>,
    },
}

impl SelectionSpec {
    /// Accepted spec syntax, for error messages and help text.
    pub const SYNTAX: &'static str =
        "full | random-k:K | round-robin[:K] | loss-weighted:K | availability:PERIOD,DUTY[,K]";

    /// Parse a spec string: `full`, `random-k:K`, `round-robin[:K]`,
    /// `loss-weighted:K`, `availability:PERIOD,DUTY[,K]`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        let (head, tail) = match s.split_once(':') {
            Some((h, t)) => (h, Some(t)),
            None => (s, None),
        };
        let positive = |t: &str| t.parse::<usize>().ok().filter(|&k| k >= 1);
        match head.to_ascii_lowercase().as_str() {
            "full" | "all" => Some(Self::Full),
            "random-k" | "randomk" | "random" => tail.and_then(positive).map(Self::RandomK),
            "round-robin" | "roundrobin" | "rr" => match tail {
                Some(t) => positive(t).map(Self::RoundRobin),
                None => Some(Self::RoundRobin(1)),
            },
            "loss-weighted" | "lossweighted" | "lw" => {
                tail.and_then(positive).map(Self::LossWeighted)
            }
            "availability" | "avail" => {
                let parts: Vec<&str> = tail?.split(',').collect();
                if parts.len() < 2 || parts.len() > 3 {
                    return None;
                }
                let period = positive(parts[0])?;
                let duty = positive(parts[1])?;
                if duty > period {
                    return None;
                }
                let cap = match parts.get(2) {
                    Some(p) => Some(positive(p)?),
                    None => None,
                };
                Some(Self::Availability { period, duty, cap })
            }
            _ => None,
        }
    }

    /// Instantiate the strategy for a system of `num_devices` devices,
    /// deriving RNG streams from `seed`.
    pub fn build(&self, num_devices: usize, seed: u64) -> Box<dyn SelectionStrategy> {
        match *self {
            Self::Full => Box::new(FullParticipation),
            Self::RandomK(k) => Box::new(RandomK::new(k, seed)),
            Self::RoundRobin(k) => Box::new(RoundRobin::new(k)),
            Self::LossWeighted(k) => Box::new(LossWeighted::new(k, seed)),
            Self::Availability { period, duty, cap } => Box::new(AvailabilityAware::new(
                AvailabilitySchedule::periodic(period, duty, num_devices, seed),
                cap,
                seed,
            )),
        }
    }

    /// Upper bound on the cohort size, if the spec implies one.
    pub fn cohort_cap(&self) -> Option<usize> {
        match *self {
            Self::Full => None,
            Self::RandomK(k) | Self::RoundRobin(k) | Self::LossWeighted(k) => Some(k),
            Self::Availability { cap, .. } => cap,
        }
    }
}

impl std::fmt::Display for SelectionSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Self::Full => write!(f, "full"),
            Self::RandomK(k) => write!(f, "random-k:{k}"),
            Self::RoundRobin(k) => write!(f, "round-robin:{k}"),
            Self::LossWeighted(k) => write!(f, "loss-weighted:{k}"),
            Self::Availability { period, duty, cap } => match cap {
                Some(k) => write!(f, "availability:{period},{duty},{k}"),
                None => write!(f, "availability:{period},{duty}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(round: usize, m: usize, stats: &DeviceStats) -> SelectionView<'_> {
        SelectionView {
            round,
            num_devices: m,
            stats,
            init_loss: 1.0,
            prev_loss: 1.0,
            loss_history: &[],
        }
    }

    #[test]
    fn full_selects_all() {
        let stats = DeviceStats::new();
        let mut s = FullParticipation;
        assert_eq!(s.select(&view(0, 4, &stats)), Selection::All);
    }

    #[test]
    fn random_k_bounds_and_determinism() {
        let stats = DeviceStats::new();
        let mut a = RandomK::new(3, 7);
        let mut b = RandomK::new(3, 7);
        for r in 0..20 {
            let sa = a.select(&view(r, 10, &stats));
            let sb = b.select(&view(r, 10, &stats));
            assert_eq!(sa, sb, "round {r}");
            let Selection::Devices(ids) = sa else {
                panic!("random-k must return an explicit cohort");
            };
            assert_eq!(ids.len(), 3);
            assert!(ids.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn round_robin_covers_everyone() {
        let stats = DeviceStats::new();
        let mut s = RoundRobin::new(2);
        let mut hit = vec![false; 7];
        for r in 0..7 {
            let Selection::Devices(ids) = s.select(&view(r, 7, &stats)) else {
                panic!("round-robin returns cohorts");
            };
            assert_eq!(ids.len(), 2);
            for i in ids {
                hit[i] = true;
            }
        }
        assert!(hit.iter().all(|&h| h), "coverage {hit:?}");
    }

    #[test]
    fn loss_weighted_prefers_lossy_devices() {
        let mut devs = vec![DeviceView::default(); 4];
        devs[2].last_loss = Some(100.0);
        for (i, d) in devs.iter_mut().enumerate() {
            if i != 2 && d.last_loss.is_none() {
                d.last_loss = Some(0.01);
            }
        }
        let stats = DeviceStats::from_dense(&devs);
        let mut s = LossWeighted::new(1, 3);
        let mut count2 = 0;
        for r in 0..200 {
            let Selection::Devices(ids) = s.select(&view(r, 4, &stats)) else {
                panic!()
            };
            assert_eq!(ids.len(), 1);
            if ids[0] == 2 {
                count2 += 1;
            }
        }
        assert!(count2 > 150, "device 2 picked only {count2}/200 times");
    }

    #[test]
    fn loss_weighted_cohort_distinct_in_range() {
        // Mixed observed/unseen pool: cohorts must stay distinct and
        // in range whichever branch each pick lands in.
        let mut stats = DeviceStats::new();
        for (id, loss) in [(1usize, 5.0f64), (4, 0.5), (7, 2.0)] {
            stats.entry(id).last_loss = Some(loss);
        }
        let mut s = LossWeighted::new(6, 11);
        for r in 0..50 {
            let Selection::Devices(ids) = s.select(&view(r, 9, &stats)) else {
                panic!()
            };
            assert_eq!(ids.len(), 6, "round {r}");
            assert!(ids.iter().all(|&i| i < 9), "round {r}: {ids:?}");
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6, "round {r}: duplicate in {ids:?}");
        }
        // All-observed pool: closed-form unseen mass is empty.
        let mut s = LossWeighted::new(3, 11);
        let all = DeviceStats::from_dense(&[
            DeviceView {
                last_loss: Some(1.0),
                ..DeviceView::default()
            },
            DeviceView {
                last_loss: Some(2.0),
                ..DeviceView::default()
            },
            DeviceView {
                last_loss: Some(3.0),
                ..DeviceView::default()
            },
        ]);
        for r in 0..20 {
            let Selection::Devices(mut ids) = s.select(&view(r, 3, &all)) else {
                panic!()
            };
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2], "round {r}");
        }
    }

    #[test]
    fn device_stats_default_for_unseen() {
        let mut stats = DeviceStats::new();
        stats.entry(3).uploads = 7;
        // Unseen device reads as the documented default.
        let d = stats.get(999);
        assert_eq!(d.uploads, 0);
        assert_eq!(d.skips, 0);
        assert!(d.last_loss.is_none());
        assert_eq!(stats.get(3).uploads, 7);
        assert_eq!(stats.observed_len(), 1);
        // Dense reconstruction round-trips.
        let dense = vec![
            DeviceView {
                uploads: 1,
                skips: 2,
                last_loss: Some(0.5),
            },
            DeviceView::default(),
        ];
        let s = DeviceStats::from_dense(&dense);
        assert_eq!(s.get(0).uploads, 1);
        assert_eq!(s.get(1).uploads, 0);
    }

    #[test]
    fn availability_respects_schedule() {
        let sched = AvailabilitySchedule {
            period: 4,
            duty: 2,
            phases: vec![0, 1, 2, 3],
        };
        let mut s = AvailabilityAware::new(sched.clone(), None, 5);
        let stats = DeviceStats::new();
        for r in 0..8 {
            let Selection::Devices(ids) = s.select(&view(r, 4, &stats)) else {
                panic!()
            };
            for i in 0..4 {
                assert_eq!(ids.contains(&i), sched.is_up(i, r), "round {r} dev {i}");
            }
        }
    }

    #[test]
    fn availability_cap_limits_cohort() {
        let sched = AvailabilitySchedule::periodic(2, 2, 8, 1); // always up
        let mut s = AvailabilityAware::new(sched, Some(3), 5);
        let stats = DeviceStats::new();
        for r in 0..10 {
            let Selection::Devices(ids) = s.select(&view(r, 8, &stats)) else {
                panic!()
            };
            assert_eq!(ids.len(), 3);
        }
    }

    #[test]
    fn spec_parse_roundtrip() {
        for (text, spec) in [
            ("full", SelectionSpec::Full),
            ("random-k:3", SelectionSpec::RandomK(3)),
            ("round-robin", SelectionSpec::RoundRobin(1)),
            ("round-robin:2", SelectionSpec::RoundRobin(2)),
            ("loss-weighted:4", SelectionSpec::LossWeighted(4)),
            (
                "availability:8,5",
                SelectionSpec::Availability {
                    period: 8,
                    duty: 5,
                    cap: None,
                },
            ),
            (
                "availability:8,5,3",
                SelectionSpec::Availability {
                    period: 8,
                    duty: 5,
                    cap: Some(3),
                },
            ),
        ] {
            assert_eq!(SelectionSpec::parse(text), Some(spec.clone()), "{text}");
            // Display output parses back to the same spec.
            assert_eq!(SelectionSpec::parse(&spec.to_string()), Some(spec));
        }
        for bad in [
            "random-k",
            "random-k:0",
            "availability:4",
            "availability:4,9",
            "availability:0,0",
            "martian",
        ] {
            assert_eq!(SelectionSpec::parse(bad), None, "{bad}");
        }
    }
}
