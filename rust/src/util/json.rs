//! Minimal JSON parser and writer.
//!
//! The offline environment has no `serde`/`serde_json`, and the runtime
//! must read `artifacts/manifest.json` written by `python/compile/aot.py`
//! (as well as emit machine-readable metrics). This is a small,
//! dependency-free recursive-descent implementation of RFC 8259 JSON:
//! objects, arrays, strings (with escapes incl. `\uXXXX`), numbers, bools,
//! null. Not streaming; documents here are tiny (< 1 MiB).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key-sorted).
    Obj(BTreeMap<String, Json>),
}

/// Error produced while parsing JSON text.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// The object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array index lookup; returns `Json::Null` out of bounds.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// -- serialization ----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builder for JSON objects in metrics code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").at(2).get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("a").at(0).as_f64(), Some(1.0));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo ≤ wörld\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ≤ wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn missing_key_is_null() {
        let j = Json::parse("{}").unwrap();
        assert_eq!(j.get("nope"), &Json::Null);
        assert_eq!(j.get("nope").as_usize(), None);
    }
}
