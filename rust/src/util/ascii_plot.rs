//! Terminal line plots for loss curves and bit traces (used by the
//! examples and the e2e driver so runs are inspectable without leaving
//! the terminal).

/// Render `series` as an ASCII plot of the given size. Each series is a
/// `(label, points)` pair; points are `(x, y)`. Distinct marker glyphs
/// per series; linear axes; NaN/∞ points skipped.
pub fn plot(series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    const MARKS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];
    let width = width.max(16);
    let height = height.max(4);
    let finite = |v: f64| v.is_finite();
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (_, pts) in series {
        for &(x, y) in pts.iter() {
            if finite(x) && finite(y) {
                xmin = xmin.min(x);
                xmax = xmax.max(x);
                ymin = ymin.min(y);
                ymax = ymax.max(y);
            }
        }
    }
    if !xmin.is_finite() || !ymin.is_finite() {
        return String::from("(no finite points)\n");
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in pts.iter() {
            if !finite(x) || !finite(y) {
                continue;
            }
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.4} |")
        } else if i == height - 1 {
            format!("{ymin:>10.4} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>11}+{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>12}{:<width$}\n",
        "",
        format!("{xmin:.3} .. {xmax:.3}"),
        width = width
    ));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {label}\n", MARKS[si % MARKS.len()]));
    }
    out
}

/// Convenience: plot a single y-series against its index.
pub fn plot_curve(label: &str, ys: &[f64], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = ys.iter().enumerate().map(|(i, &y)| (i as f64, y)).collect();
    plot(&[(label, &pts)], width, height)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_descending_curve() {
        let ys: Vec<f64> = (0..50).map(|i| 100.0 / (1.0 + i as f64)).collect();
        let s = plot_curve("loss", &ys, 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("loss"));
        // First grid row (max label) contains the max value.
        assert!(s.starts_with(&format!("{:>10.4} |", 100.0)));
        assert_eq!(s.lines().count(), 10 + 2 + 1);
    }

    #[test]
    fn multiple_series_get_distinct_marks() {
        let a: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, i as f64)).collect();
        let b: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, (10 - i) as f64)).collect();
        let s = plot(&[("up", &a), ("down", &b)], 30, 8);
        assert!(s.contains('*') && s.contains('+'));
        assert!(s.contains("up") && s.contains("down"));
    }

    #[test]
    fn handles_degenerate_inputs() {
        assert!(plot(&[("e", &[] as &[(f64, f64)])], 20, 5).contains("no finite"));
        let nanpts = [(0.0, f64::NAN), (1.0, f64::INFINITY)];
        assert!(plot(&[("n", &nanpts)], 20, 5).contains("no finite"));
        // Constant series doesn't divide by zero.
        let flat = [(0.0, 5.0), (1.0, 5.0)];
        let s = plot(&[("flat", &flat)], 20, 5);
        assert!(s.contains('*'));
    }
}
