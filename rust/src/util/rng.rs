//! Deterministic pseudo-random number generation.
//!
//! The offline build environment provides no `rand` crate, so this module
//! implements the small set of primitives the simulator needs:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., 2014).
//! * [`Xoshiro256pp`] — the main generator (Blackman & Vigna, 2019);
//!   fast, 256-bit state, passes BigCrush.
//! * Uniform floats, bounded integers (Lemire rejection), Gaussian samples
//!   (Box–Muller with caching), Fisher–Yates shuffling.
//!
//! All experiment code seeds its generators explicitly so every table and
//! figure in the reproduction is bit-reproducible.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Expander starting at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: the workhorse generator for all simulation randomness.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    gauss_cache: Option<f64>,
}

impl Xoshiro256pp {
    /// Create a generator from a seed, expanding it via SplitMix64 as the
    /// xoshiro authors recommend.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Derive an independent stream (device-local RNGs, shard RNGs, ...).
    ///
    /// Equivalent to seeding a fresh generator with `hash(seed, stream)`;
    /// streams with different ids are decorrelated.
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Next 64-bit output (xoshiro256++ scrambler).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (high half of [`Xoshiro256pp::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection; unbiased for all bounds.
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard Gaussian via Box–Muller (pair-cached).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        // Avoid log(0): u1 in (0, 1].
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gaussian with the given mean and standard deviation, as `f32`.
    #[inline]
    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        (mean as f64 + std as f64 * self.next_gaussian()) as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_bounded((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample `k` distinct indices from `[0, n)` in **O(k)** memory and
    /// expected O(k log k) time (Floyd's algorithm), returned sorted
    /// ascending.
    ///
    /// The million-device selection path uses this instead of
    /// [`Xoshiro256pp::sample_indices`], whose partial Fisher–Yates
    /// allocates the whole `(0..n)` index vector — O(population) per
    /// round. The two algorithms consume different draw sequences, so
    /// they are *not* interchangeable mid-run; a strategy picks one and
    /// keeps it at every population size.
    pub fn sample_floyd(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.next_bounded(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Full generator state `(xoshiro words, cached Box–Muller value)`
    /// for checkpointing; restore with [`Xoshiro256pp::from_snapshot`].
    pub fn snapshot(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_cache)
    }

    /// Rebuild a generator from [`Xoshiro256pp::snapshot`] output; the
    /// restored stream continues bit-exactly.
    pub fn from_snapshot(s: [u64; 4], gauss_cache: Option<f64>) -> Self {
        Self { s, gauss_cache }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (known-good reference sequence).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_distinct() {
        let mut a = Xoshiro256pp::stream(42, 0);
        let mut b = Xoshiro256pp::stream(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.next_bounded(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn snapshot_restores_stream_exactly() {
        let mut a = Xoshiro256pp::seed_from_u64(21);
        a.next_gaussian(); // populate the Box–Muller cache
        let (s, cache) = a.snapshot();
        let mut b = Xoshiro256pp::from_snapshot(s, cache);
        for _ in 0..16 {
            assert_eq!(a.next_gaussian().to_bits(), b.next_gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_floyd_distinct_sorted_deterministic() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let idx = r.sample_floyd(50, 20);
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
        assert!(idx.iter().all(|&i| i < 50));
        // Same seed, same cohort — the draw sequence is a pure function
        // of (state, n, k).
        let mut r2 = Xoshiro256pp::seed_from_u64(9);
        assert_eq!(r2.sample_floyd(50, 20), idx);
        // Edge cases: full range and empty sample.
        let mut r3 = Xoshiro256pp::seed_from_u64(1);
        assert_eq!(r3.sample_floyd(5, 5), vec![0, 1, 2, 3, 4]);
        assert!(r3.sample_floyd(5, 0).is_empty());
        // k = n at scale would overflow a Fisher–Yates clone; Floyd
        // touches only the chosen set.
        let mut r4 = Xoshiro256pp::seed_from_u64(2);
        let big = r4.sample_floyd(1_000_000, 100);
        assert_eq!(big.len(), 100);
        assert!(big.iter().all(|&i| i < 1_000_000));
    }

    #[test]
    fn sample_floyd_is_roughly_uniform() {
        // Each index of [0, n) should appear in ~k/n of the samples.
        let n = 40;
        let k = 10;
        let trials = 4_000;
        let mut counts = vec![0u32; n];
        let mut r = Xoshiro256pp::seed_from_u64(77);
        for _ in 0..trials {
            for i in r.sample_floyd(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64; // 1000
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.8 && (c as f64) < expect * 1.2,
                "index {i} hit {c} times, expected ≈{expect}"
            );
        }
    }
}
