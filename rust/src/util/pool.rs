//! Scoped parallel execution for per-device work.
//!
//! The simulator runs `M` devices per round; device gradient computation
//! dominates round wall-clock. With no tokio/rayon available offline, this
//! module provides a small work-stealing-free static partitioner over
//! `std::thread::scope`: deterministic (device i always produces result i,
//! independent of thread interleaving), panic-propagating, and with zero
//! per-round allocation beyond the output vector.

/// Number of worker threads to use: `AQUILA_THREADS` env var, else the
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("AQUILA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `0..n` in parallel on `threads` workers, preserving order.
///
/// Work is distributed in contiguous chunks. `f` must be `Sync` (it is
/// invoked concurrently from several threads); results are written into a
/// pre-sized vector so ordering is deterministic.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut results: Vec<Option<T>> = Vec::with_capacity(n);
    results.resize_with(n, || None);

    // Contiguous chunking: indices [t*chunk, min((t+1)*chunk, n)).
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slot_chunk) in results.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|o| o.expect("worker thread filled every slot"))
        .collect()
}

/// Parallel for-each over mutable slices: applies `f(index, &mut item)`
/// with work split in contiguous chunks.
pub fn parallel_for_each_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, part) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, item) in part.iter_mut().enumerate() {
                    f(base + i, item);
                }
            });
        }
    });
}

/// Split `out` into at most `threads` contiguous shards and run
/// `f(shard_start, shard)` on every shard in parallel — the
/// server-fold counterpart of [`parallel_for_each_mut`]. The shard
/// count is capped at `⌊n / min_shard⌋`, so shards average at least
/// `min_shard` elements (the final one may be slightly shorter) and
/// outputs under `2·min_shard` run serially — a thread spawn costs
/// more than that much scatter-add.
///
/// Each shard is an exclusive `&mut` sub-slice, so `f` can only write
/// its own output range; as long as `f`'s per-element work is
/// independent of the shard partition (true for the fused
/// dequantize–scatter fold, which accumulates uploads into each element
/// in upload order), results are bit-identical for every thread count.
pub fn parallel_for_shards<T, F>(out: &mut [T], threads: usize, min_shard: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    // Floor division keeps the *average* shard ≥ min_shard elements.
    let max_shards = (n / min_shard.max(1)).max(1);
    let shards = threads.clamp(1, max_shards);
    if shards <= 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(shards);
    std::thread::scope(|scope| {
        for (t, part) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(t * chunk, part));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_index_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn single_thread_fallback() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i), vec![0]);
    }

    #[test]
    fn more_threads_than_items() {
        assert_eq!(parallel_map(3, 64, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_each_mut_touches_all() {
        let mut xs = vec![0usize; 257];
        parallel_for_each_mut(&mut xs, 4, |i, x| *x = i + 1);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }

    #[test]
    fn shards_cover_output_exactly_once() {
        for threads in [1usize, 2, 3, 7, 16] {
            let mut out = vec![0usize; 1003];
            parallel_for_shards(&mut out, threads, 1, |base, shard| {
                for (i, x) in shard.iter_mut().enumerate() {
                    *x += base + i + 1;
                }
            });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn min_shard_limits_split() {
        // 200 elements at min_shard 64 ⇒ floor(200/64) = 3 shards even
        // with 8 threads, each at least 64 elements.
        let calls = AtomicUsize::new(0);
        let mut out = vec![0u8; 200];
        parallel_for_shards(&mut out, 8, 64, |_base, shard| {
            assert!(shard.len() >= 64, "undersized shard {}", shard.len());
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        // Output shorter than 2·min_shard ⇒ a single serial call.
        let calls1 = AtomicUsize::new(0);
        let mut small = vec![0u8; 100];
        parallel_for_shards(&mut small, 8, 64, |base, shard| {
            assert_eq!(base, 0);
            assert_eq!(shard.len(), 100);
            calls1.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls1.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn empty_output_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        parallel_for_shards(&mut out, 4, 16, |_, _| panic!("no shard expected"));
    }

    #[test]
    #[should_panic]
    fn panics_propagate() {
        parallel_map(8, 4, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }
}
