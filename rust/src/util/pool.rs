//! Scoped parallel execution for per-device work.
//!
//! The simulator runs `M` devices per round; device gradient computation
//! dominates round wall-clock. With no tokio/rayon available offline, this
//! module provides a small work-stealing-free static partitioner over
//! `std::thread::scope`: deterministic (device i always produces result i,
//! independent of thread interleaving), panic-propagating, and with zero
//! per-round allocation beyond the output vector.

/// Number of worker threads to use: `AQUILA_THREADS` env var, else the
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("AQUILA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `0..n` in parallel on `threads` workers, preserving order.
///
/// Work is distributed in contiguous chunks. `f` must be `Sync` (it is
/// invoked concurrently from several threads); results are written into a
/// pre-sized vector so ordering is deterministic.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut results: Vec<Option<T>> = Vec::with_capacity(n);
    results.resize_with(n, || None);

    // Contiguous chunking: indices [t*chunk, min((t+1)*chunk, n)).
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slot_chunk) in results.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|o| o.expect("worker thread filled every slot"))
        .collect()
}

/// Parallel for-each over mutable slices: applies `f(index, &mut item)`
/// with work split in contiguous chunks.
pub fn parallel_for_each_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, part) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (i, item) in part.iter_mut().enumerate() {
                    f(base + i, item);
                }
            });
        }
    });
}

/// Split `out` into at most `threads` contiguous shards and run
/// `f(shard_start, shard)` on every shard in parallel — the
/// server-fold counterpart of [`parallel_for_each_mut`]. The shard
/// count is capped at `⌊n / min_shard⌋`, so shards average at least
/// `min_shard` elements (the final one may be slightly shorter) and
/// outputs under `2·min_shard` run serially — a thread spawn costs
/// more than that much scatter-add.
///
/// Each shard is an exclusive `&mut` sub-slice, so `f` can only write
/// its own output range; as long as `f`'s per-element work is
/// independent of the shard partition (true for the fused
/// dequantize–scatter fold, which accumulates uploads into each element
/// in upload order), results are bit-identical for every thread count.
pub fn parallel_for_shards<T, F>(out: &mut [T], threads: usize, min_shard: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    // Floor division keeps the *average* shard ≥ min_shard elements.
    let max_shards = (n / min_shard.max(1)).max(1);
    let shards = threads.clamp(1, max_shards);
    if shards <= 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(shards);
    std::thread::scope(|scope| {
        for (t, part) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(t * chunk, part));
        }
    });
}

/// Run `f(worker_state, id, &mut items[id])` for every id in `cohort`
/// (a strictly-increasing index list into `items`), splitting the
/// cohort into at most `workers.len().min(threads)` contiguous chunks —
/// one worker state per chunk.
///
/// This is the device-phase counterpart of [`parallel_for_each_mut`]
/// for *sparse* selections: a round typically touches only the selected
/// cohort, so chunking the cohort (not the full item slice) keeps the
/// per-thread work balanced, and handing each chunk a dedicated
/// `&mut W` scratch lets callers keep O(threads·d) working memory
/// instead of O(M·d).
///
/// Determinism: each item is visited by exactly one worker, chunk
/// boundaries never change per-item inputs, and each worker owns an
/// exclusive sub-slice of `items` (progressive `split_at_mut` at the
/// chunk's id range). As long as `f`'s per-item work depends only on
/// `(id, item, state-after-reset)` — true for the device phase, which
/// fully overwrites its scratch buffers per device — results are
/// bit-identical for every thread count.
///
/// # Panics
///
/// Panics if `cohort` is not strictly increasing, an id is out of
/// bounds, or `workers` is empty while `cohort` is not.
pub fn parallel_for_cohort<T, W, F>(items: &mut [T], cohort: &[usize], workers: &mut [W], f: F)
where
    T: Send,
    W: Send,
    F: Fn(&mut W, usize, &mut T) + Sync,
{
    let k = cohort.len();
    if k == 0 {
        return;
    }
    assert!(
        cohort.windows(2).all(|w| w[0] < w[1]),
        "cohort ids must be strictly increasing"
    );
    assert!(
        *cohort.last().expect("non-empty cohort") < items.len(),
        "cohort id out of bounds"
    );
    assert!(!workers.is_empty(), "need at least one worker state");
    let threads = workers.len().min(k);
    if threads <= 1 {
        let w = &mut workers[0];
        for &id in cohort {
            f(w, id, &mut items[id]);
        }
        return;
    }
    let chunk = k.div_ceil(threads);
    std::thread::scope(|scope| {
        // Progressively split `items` so each chunk owns the exclusive
        // sub-slice covering its id range [ids[0], ids[last]].
        let mut rest = items;
        let mut base = 0usize; // global index where `rest` starts
        let mut free = &mut workers[..];
        for ids in cohort.chunks(chunk) {
            let lo = ids[0];
            let hi = ids[ids.len() - 1] + 1;
            let tail = std::mem::take(&mut rest);
            let (_, tail) = tail.split_at_mut(lo - base);
            let (mine, tail) = tail.split_at_mut(hi - lo);
            rest = tail;
            base = hi;
            let (w, wrest) = std::mem::take(&mut free).split_at_mut(1);
            free = wrest;
            let w = &mut w[0];
            let f = &f;
            scope.spawn(move || {
                for &id in ids {
                    f(w, id, &mut mine[id - lo]);
                }
            });
        }
    });
}

/// Run `f(worker_state, id, &mut item)` for every `(id, item)` pair,
/// splitting the pair slice into at most `workers.len()` contiguous
/// chunks — one worker state per chunk.
///
/// This is [`parallel_for_cohort`] for a cohort that has been
/// *materialized out* of its population: the lazy engine owns only the
/// selected `(device_id, DeviceSlot)` pairs, not a dense `items`
/// slice, so the chunking is over the pair vector itself. Ids must be
/// strictly increasing (the engine's sorted-cohort invariant), which
/// makes the chunk partition — and therefore the visit order within
/// each worker — a pure function of the cohort, not of thread timing.
///
/// Determinism: each pair is visited by exactly one worker and chunk
/// boundaries never change per-item inputs; as long as `f`'s per-item
/// work depends only on `(id, item, state-after-reset)` (true for the
/// device phase), results are bit-identical for every worker count.
///
/// # Panics
///
/// Panics if ids are not strictly increasing, or `workers` is empty
/// while `pairs` is not.
pub fn parallel_for_pairs<T, W, F>(pairs: &mut [(usize, T)], workers: &mut [W], f: F)
where
    T: Send,
    W: Send,
    F: Fn(&mut W, usize, &mut T) + Sync,
{
    let k = pairs.len();
    if k == 0 {
        return;
    }
    assert!(
        pairs.windows(2).all(|w| w[0].0 < w[1].0),
        "pair ids must be strictly increasing"
    );
    assert!(!workers.is_empty(), "need at least one worker state");
    let threads = workers.len().min(k);
    if threads <= 1 {
        let w = &mut workers[0];
        for (id, item) in pairs.iter_mut() {
            f(w, *id, item);
        }
        return;
    }
    let chunk = k.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut free = &mut workers[..];
        for part in pairs.chunks_mut(chunk) {
            let (w, wrest) = std::mem::take(&mut free).split_at_mut(1);
            free = wrest;
            let w = &mut w[0];
            let f = &f;
            scope.spawn(move || {
                for (id, item) in part.iter_mut() {
                    f(w, *id, item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_index_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn single_thread_fallback() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i), vec![0]);
    }

    #[test]
    fn more_threads_than_items() {
        assert_eq!(parallel_map(3, 64, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_each_mut_touches_all() {
        let mut xs = vec![0usize; 257];
        parallel_for_each_mut(&mut xs, 4, |i, x| *x = i + 1);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }

    #[test]
    fn shards_cover_output_exactly_once() {
        for threads in [1usize, 2, 3, 7, 16] {
            let mut out = vec![0usize; 1003];
            parallel_for_shards(&mut out, threads, 1, |base, shard| {
                for (i, x) in shard.iter_mut().enumerate() {
                    *x += base + i + 1;
                }
            });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn min_shard_limits_split() {
        // 200 elements at min_shard 64 ⇒ floor(200/64) = 3 shards even
        // with 8 threads, each at least 64 elements.
        let calls = AtomicUsize::new(0);
        let mut out = vec![0u8; 200];
        parallel_for_shards(&mut out, 8, 64, |_base, shard| {
            assert!(shard.len() >= 64, "undersized shard {}", shard.len());
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        // Output shorter than 2·min_shard ⇒ a single serial call.
        let calls1 = AtomicUsize::new(0);
        let mut small = vec![0u8; 100];
        parallel_for_shards(&mut small, 8, 64, |base, shard| {
            assert_eq!(base, 0);
            assert_eq!(shard.len(), 100);
            calls1.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls1.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn empty_output_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        parallel_for_shards(&mut out, 4, 16, |_, _| panic!("no shard expected"));
    }

    #[test]
    fn cohort_visits_each_selected_exactly_once() {
        for nworkers in [1usize, 2, 3, 7, 16] {
            let mut xs = vec![0usize; 100];
            let cohort: Vec<usize> = (0..100).filter(|i| i % 3 == 0).collect();
            let mut workers = vec![0usize; nworkers];
            parallel_for_cohort(&mut xs, &cohort, &mut workers, |w, id, x| {
                *w += 1;
                *x += id + 1;
            });
            for (i, x) in xs.iter().enumerate() {
                let want = if i % 3 == 0 { i + 1 } else { 0 };
                assert_eq!(*x, want, "workers={nworkers} i={i}");
            }
            let total: usize = workers.iter().sum();
            assert_eq!(total, cohort.len(), "workers={nworkers}");
        }
    }

    #[test]
    fn cohort_results_thread_invariant() {
        let cohort = vec![1usize, 4, 5, 9, 17, 30, 31];
        let run = |nworkers: usize| {
            let mut xs = vec![0u64; 32];
            let mut workers = vec![(); nworkers];
            parallel_for_cohort(&mut xs, &cohort, &mut workers, |_, id, x| {
                *x = (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            });
            xs
        };
        let serial = run(1);
        for n in [2usize, 3, 7] {
            assert_eq!(run(n), serial, "workers={n}");
        }
    }

    #[test]
    fn cohort_empty_and_edges() {
        let mut xs = vec![0u8; 4];
        let mut workers = vec![(); 2];
        parallel_for_cohort(&mut xs, &[], &mut workers, |_, _, _| {
            panic!("no work expected")
        });
        // First and last items selectable.
        parallel_for_cohort(&mut xs, &[0, 3], &mut workers, |_, _, x| *x = 1);
        assert_eq!(xs, vec![1, 0, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn cohort_rejects_unsorted() {
        let mut xs = vec![0u8; 4];
        let mut workers = vec![(); 2];
        parallel_for_cohort(&mut xs, &[2, 1], &mut workers, |_, _, _| {});
    }

    #[test]
    fn pairs_visit_each_exactly_once_and_thread_invariant() {
        let ids = [1usize, 4, 5, 9, 17, 30, 31];
        let run = |nworkers: usize| {
            let mut pairs: Vec<(usize, u64)> = ids.iter().map(|&i| (i, 0)).collect();
            let mut workers = vec![0usize; nworkers];
            parallel_for_pairs(&mut pairs, &mut workers, |w, id, x| {
                *w += 1;
                *x = (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            });
            let visits: usize = workers.iter().sum();
            assert_eq!(visits, ids.len(), "workers={nworkers}");
            pairs
        };
        let serial = run(1);
        for n in [2usize, 3, 7, 16] {
            assert_eq!(run(n), serial, "workers={n}");
        }
    }

    #[test]
    fn pairs_empty_is_noop() {
        let mut pairs: Vec<(usize, u8)> = Vec::new();
        let mut workers = vec![(); 2];
        parallel_for_pairs(&mut pairs, &mut workers, |_, _, _| {
            panic!("no work expected")
        });
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn pairs_reject_unsorted() {
        let mut pairs = vec![(2usize, 0u8), (1, 0)];
        let mut workers = vec![(); 2];
        parallel_for_pairs(&mut pairs, &mut workers, |_, _, _| {});
    }

    #[test]
    #[should_panic]
    fn panics_propagate() {
        parallel_map(8, 4, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }
}
