//! A fixed-capacity recency window over `f64` samples.
//!
//! The round engine broadcasts the most recent `history_depth` model
//! differences and train losses every round. Storing them
//! most-recent-first in a `Vec` made each push an O(depth)
//! `insert(0, …)`; [`RecentWindow`] keeps the same *view* (a contiguous
//! most-recent-first slice, which `RoundCtx` / `SelectionView` borrow
//! directly) with amortized O(1) pushes.
//!
//! Implementation: a `2·cap` buffer written right-to-left. The live
//! window is `buf[head..head + len]`; when `head` reaches 0 the window
//! is relocated to the buffer's midpoint (one O(cap) copy every `cap`
//! pushes).

/// See module docs.
#[derive(Clone, Debug)]
pub struct RecentWindow {
    buf: Vec<f64>,
    head: usize,
    len: usize,
    cap: usize,
}

impl RecentWindow {
    /// Window retaining the `cap` most recent samples (`cap = 0` is a
    /// valid always-empty window).
    pub fn new(cap: usize) -> Self {
        Self {
            buf: vec![0.0; 2 * cap],
            head: 2 * cap,
            len: 0,
            cap,
        }
    }

    /// Retention capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Record a sample, evicting the oldest once at capacity.
    pub fn push(&mut self, x: f64) {
        if self.cap == 0 {
            return;
        }
        if self.head == 0 {
            // Relocate the newest `cap − 1` survivors to the midpoint;
            // source and destination cannot overlap since keep < cap.
            let keep = self.len.min(self.cap - 1);
            self.buf.copy_within(0..keep, self.cap);
            self.head = self.cap;
            self.len = keep;
        }
        self.head -= 1;
        self.buf[self.head] = x;
        self.len = (self.len + 1).min(self.cap);
    }

    /// The retained samples, most recent first.
    pub fn as_slice(&self) -> &[f64] {
        &self.buf[self.head..self.head + self.len]
    }

    /// The most recent sample, if any.
    pub fn latest(&self) -> Option<f64> {
        self.as_slice().first().copied()
    }

    /// Owned most-recent-first copy (checkpoint serialization).
    pub fn to_vec(&self) -> Vec<f64> {
        self.as_slice().to_vec()
    }

    /// Replace the contents from a most-recent-first slice, keeping at
    /// most `capacity` newest samples (checkpoint restore).
    pub fn assign(&mut self, most_recent_first: &[f64]) {
        let keep = most_recent_first.len().min(self.cap);
        self.head = self.cap;
        self.len = keep;
        self.buf[self.cap..self.cap + keep].copy_from_slice(&most_recent_first[..keep]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_insert_front_truncate() {
        // The reference semantics this type replaces.
        for cap in [1usize, 2, 3, 10] {
            let mut ring = RecentWindow::new(cap);
            let mut reference: Vec<f64> = Vec::new();
            for i in 0..100 {
                let x = (i * i) as f64;
                ring.push(x);
                reference.insert(0, x);
                reference.truncate(cap);
                assert_eq!(ring.as_slice(), &reference[..], "cap={cap} i={i}");
                assert_eq!(ring.latest(), reference.first().copied());
            }
        }
    }

    #[test]
    fn zero_capacity_stays_empty() {
        let mut ring = RecentWindow::new(0);
        ring.push(1.0);
        ring.push(2.0);
        assert!(ring.is_empty());
        assert_eq!(ring.as_slice(), &[] as &[f64]);
        assert_eq!(ring.latest(), None);
    }

    #[test]
    fn partial_fill() {
        let mut ring = RecentWindow::new(5);
        ring.push(1.0);
        ring.push(2.0);
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.as_slice(), &[2.0, 1.0]);
    }

    #[test]
    fn assign_roundtrip() {
        let mut ring = RecentWindow::new(4);
        for i in 0..7 {
            ring.push(i as f64);
        }
        let saved = ring.to_vec();
        assert_eq!(saved, vec![6.0, 5.0, 4.0, 3.0]);
        let mut restored = RecentWindow::new(4);
        restored.assign(&saved);
        assert_eq!(restored.as_slice(), &saved[..]);
        // Pushing after restore keeps most-recent-first order.
        restored.assign(&saved);
        restored.push(9.0);
        assert_eq!(restored.as_slice(), &[9.0, 6.0, 5.0, 4.0]);
    }

    #[test]
    fn assign_truncates_to_capacity() {
        let mut ring = RecentWindow::new(2);
        ring.assign(&[9.0, 8.0, 7.0]);
        assert_eq!(ring.as_slice(), &[9.0, 8.0]);
    }

    #[test]
    fn assign_empty_clears() {
        let mut ring = RecentWindow::new(3);
        ring.push(1.0);
        ring.assign(&[]);
        assert!(ring.is_empty());
    }
}
