//! Minimal TOML-subset parser for experiment configuration files.
//!
//! Supports the subset the config system uses:
//!
//! * `[table]` and `[table.sub]` headers,
//! * `key = value` with values: string (`"..."`), integer, float, bool,
//!   and homogeneous arrays of those,
//! * `#` comments, blank lines.
//!
//! Not supported (rejected with an error rather than misparsed):
//! multi-line strings, dates, inline tables, array-of-tables.
//!
//! Parsed documents flatten to `dotted.key -> Value` which is what the
//! [`crate::config`] layer consumes.

use std::collections::BTreeMap;

/// A TOML scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// An inline array.
    Array(Vec<Value>),
}

impl Value {
    /// The string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer, if this is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric accessor accepting either int or float syntax.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Error with 1-based line number context.
#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    /// 1-based line number of the error.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

/// Parse a TOML-subset document into a flat `dotted.key -> Value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, TomlError> {
    let mut map = BTreeMap::new();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = lineno + 1;
        let stripped = strip_comment(raw).trim().to_string();
        if stripped.is_empty() {
            continue;
        }
        if let Some(rest) = stripped.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(TomlError {
                line,
                msg: "unterminated table header".into(),
            })?;
            if name.starts_with('[') {
                return Err(TomlError {
                    line,
                    msg: "array-of-tables is not supported".into(),
                });
            }
            let name = name.trim();
            if name.is_empty() {
                return Err(TomlError {
                    line,
                    msg: "empty table name".into(),
                });
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = stripped.find('=').ok_or(TomlError {
            line,
            msg: "expected `key = value`".into(),
        })?;
        let key = stripped[..eq].trim();
        if key.is_empty() {
            return Err(TomlError {
                line,
                msg: "empty key".into(),
            });
        }
        let val_text = stripped[eq + 1..].trim();
        let value = parse_value(val_text).map_err(|msg| TomlError { line, msg })?;
        map.insert(format!("{prefix}{key}"), value);
    }
    Ok(map)
}

/// Strip a `#` comment, respecting `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    let text = text.trim();
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Array(items));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {text}"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape: \\{other:?}")),
        }
    }
    Ok(out)
}

/// Split on commas not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let m = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["b"], Value::Float(2.5));
        assert_eq!(m["c"], Value::Str("hi".into()));
        assert_eq!(m["d"], Value::Bool(true));
    }

    #[test]
    fn parses_tables_and_dotted_keys() {
        let doc = "top = 1\n[server]\nalpha = 0.1\n[server.limits]\nmax = 10\n";
        let m = parse(doc).unwrap();
        assert_eq!(m["top"], Value::Int(1));
        assert_eq!(m["server.alpha"], Value::Float(0.1));
        assert_eq!(m["server.limits.max"], Value::Int(10));
    }

    #[test]
    fn parses_arrays() {
        let m = parse("xs = [1, 2, 3]\nys = [0.1, 0.25]\nss = [\"a\", \"b\"]\n").unwrap();
        assert_eq!(
            m["xs"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(m["ys"].as_array().unwrap()[1].as_f64(), Some(0.25));
        assert_eq!(m["ss"].as_array().unwrap()[0].as_str(), Some("a"));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = "# heading\na = 1 # trailing\n\nb = \"has # inside\" # real comment\n";
        let m = parse(doc).unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["b"], Value::Str("has # inside".into()));
    }

    #[test]
    fn underscores_in_numbers() {
        let m = parse("n = 1_000_000\n").unwrap();
        assert_eq!(m["n"], Value::Int(1_000_000));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("x = \n").is_err());
        assert!(parse("x = \"unterminated\n").is_err());
        assert!(parse("[[aot]]\n").is_err());
    }

    #[test]
    fn int_vs_float_distinction() {
        let m = parse("i = 3\nf = 3.0\ne = 1e2\n").unwrap();
        assert_eq!(m["i"], Value::Int(3));
        assert_eq!(m["f"], Value::Float(3.0));
        assert_eq!(m["e"], Value::Float(100.0));
        assert_eq!(m["i"].as_f64(), Some(3.0));
    }
}
