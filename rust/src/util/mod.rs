//! Self-contained substrates: RNG, JSON, TOML-subset, thread pool,
//! dense vector kernels (BLAS-1 in `vecmath`, blocked SGEMM in
//! `gemm`), and the recency ring buffer backing the engine's history
//! views.
//!
//! The offline build environment ships only the `xla` crate's transitive
//! dependencies, so everything a typical project would pull from
//! `rand`/`serde_json`/`toml`/`rayon` is implemented here (see
//! DESIGN.md §4, S18).

pub mod ascii_plot;
pub mod gemm;
pub mod json;
pub mod pool;
pub mod ring;
pub mod rng;
pub mod toml;
pub mod vecmath;
