//! Self-contained substrates: RNG, JSON, TOML-subset, thread pool, and
//! dense vector kernels.
//!
//! The offline build environment ships only the `xla` crate's transitive
//! dependencies, so everything a typical project would pull from
//! `rand`/`serde_json`/`toml`/`rayon` is implemented here (see
//! DESIGN.md §4, S18).

pub mod ascii_plot;
pub mod json;
pub mod pool;
pub mod rng;
pub mod toml;
pub mod vecmath;
