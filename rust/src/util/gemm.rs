//! Blocked f32 SGEMM micro-kernels for the batched device-compute
//! layer (`crate::problems`).
//!
//! Three row-major accumulate variants cover every product the problems
//! need — `C += A·Bᵀ` for forward passes over a shard (`X·Wᵀ`),
//! `C += Aᵀ·B` for weight gradients (`δᵀ·X`), and `C += A·B` for
//! backpropagated deltas (`δ·W`) — plus the column-sum reduction for
//! bias gradients.
//!
//! **Determinism contract.** Every kernel accumulates each output
//! element in one fixed, data-independent order: [`gemm_nt`] walks the
//! depth dimension in `KC`-sized blocks whose dot products fold
//! [`LANES`] strided partial sums through a fixed reduction tree, while
//! [`gemm_nn`] / [`gemm_tn`] / [`col_sum_add`] accumulate in plain
//! index/row order (no depth blocking — adding it would *change* their
//! accumulation order and the results the property tests pin). The
//! kernels themselves are single-threaded (callers parallelize across
//! *devices*, never inside one gradient), so `local_grad` is
//! bit-reproducible run-to-run at any engine thread count. See
//! DESIGN.md §Compute.
//!
//! The lane-strided partial sums exist so the reductions vectorize:
//! a single-accumulator f32 dot cannot be auto-vectorized (strict FP
//! semantics forbid reassociation), whereas independent lanes map
//! directly onto SIMD adds.

/// Depth (k) block size: `2·KC·4` bytes of operand rows stay L1-hot
/// while a block of dot products runs.
const KC: usize = 256;

/// Partial-sum lanes in the dot-product kernel (one SIMD-width's worth
/// of independent f32 accumulators).
const LANES: usize = 8;

/// Dot product of equal-length slices with `LANES` strided partial
/// sums and a fixed reduction tree. Deterministic for a given input
/// length; `debug_assert`s equal lengths.
#[inline]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    let split = chunks * LANES;
    for (a8, b8) in a[..split]
        .chunks_exact(LANES)
        .zip(b[..split].chunks_exact(LANES))
    {
        for ((s, &x), &y) in acc.iter_mut().zip(a8).zip(b8) {
            *s += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        tail += x * y;
    }
    // Fixed pairwise tree over the lanes.
    let q0 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let q1 = (acc[4] + acc[5]) + (acc[6] + acc[7]);
    (q0 + q1) + tail
}

/// `C[m×n] += A[m×k] · B[n×k]ᵀ` (all row-major).
///
/// The transposed-B form makes both operand rows contiguous, so each
/// `C[i,j]` is one [`dot_lanes`] call per depth block. This is the
/// forward-pass kernel: `logits[n×K] += X[n×D] · W[K×D]ᵀ`.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), n * k, "B must be n×k");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for (i, c_row) in c.chunks_exact_mut(n).enumerate() {
            let a_blk = &a[i * k + k0..i * k + k0 + kb];
            for (j, cij) in c_row.iter_mut().enumerate() {
                let b_blk = &b[j * k + k0..j * k + k0 + kb];
                *cij += dot_lanes(a_blk, b_blk);
            }
        }
        k0 += kb;
    }
}

/// `C[m×n] += A[m×k] · B[k×n]` (all row-major).
///
/// Axpy-style kernel: each `A[i,l]` scales row `l` of `B` into row `i`
/// of `C`, so the inner loop vectorizes over `n` and each `C` element
/// accumulates its `k` terms in index order. This is the
/// delta-backprop kernel: `δ_hidden[n×H] += δ_out[n×K] · W2[K×H]`.
pub fn gemm_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    for (a_row, c_row) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        for (&ail, b_row) in a_row.iter().zip(b.chunks_exact(n)) {
            for (cij, &blj) in c_row.iter_mut().zip(b_row) {
                *cij += ail * blj;
            }
        }
    }
}

/// `C[m×n] += A[p×m]ᵀ · B[p×n]` (all row-major).
///
/// Rank-1-update kernel: each of the `p` rows contributes the outer
/// product `A[r,·]ᵀ · B[r,·]`, streamed once, with `C` (the small
/// weight-gradient matrix) staying cache-hot. Each `C` element
/// accumulates its `p` terms in row order — fixed and data-independent.
/// This is the weight-gradient kernel: `∂W[K×D] += δ[n×K]ᵀ · X[n×D]`.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, p: usize) {
    assert_eq!(a.len(), p * m, "A must be p×m");
    assert_eq!(b.len(), p * n, "B must be p×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    for (a_row, b_row) in a.chunks_exact(m).zip(b.chunks_exact(n)) {
        for (&ari, c_row) in a_row.iter().zip(c.chunks_exact_mut(n)) {
            for (cij, &brj) in c_row.iter_mut().zip(b_row) {
                *cij += ari * brj;
            }
        }
    }
}

/// `out[j] += Σ_rows A[·×n][row, j]` — column sums of a row-major
/// matrix, accumulated in row order (the bias-gradient reduction).
pub fn col_sum_add(a: &[f32], out: &mut [f32], n: usize) {
    assert_eq!(out.len(), n, "out must have one slot per column");
    if n == 0 {
        return;
    }
    assert_eq!(a.len() % n, 0, "A must be rows×n");
    for row in a.chunks_exact(n) {
        for (o, &x) in out.iter_mut().zip(row) {
            *o += x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn randv(rng: &mut Xoshiro256pp, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian_f32(0.0, 1.0)).collect()
    }

    /// f64 reference: C += op(A)·op(B) with naive triple loops.
    fn refr_nt(a: &[f32], b: &[f32], c: &mut [f64], m: usize, n: usize, k: usize) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..k {
                    acc += a[i * k + l] as f64 * b[j * k + l] as f64;
                }
                c[i * n + j] += acc;
            }
        }
    }

    fn assert_close(got: &[f32], want: &[f64], tol: f64) {
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            let denom = w.abs().max(1.0);
            assert!(
                ((g as f64 - w) / denom).abs() < tol,
                "elem {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn nt_matches_f64_reference() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (17, 9, 300), (4, 32, 1000)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, n * k);
            let mut c = vec![0.0f32; m * n];
            let mut want = vec![0.0f64; m * n];
            gemm_nt(&a, &b, &mut c, m, n, k);
            refr_nt(&a, &b, &mut want, m, n, k);
            assert_close(&c, &want, 1e-5);
        }
    }

    #[test]
    fn nn_matches_nt_on_transposed_b() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let (m, n, k) = (6, 11, 23);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n); // k×n
        let mut bt = vec![0.0f32; n * k]; // n×k
        for r in 0..k {
            for j in 0..n {
                bt[j * k + r] = b[r * n + j];
            }
        }
        let mut c_nn = vec![0.0f32; m * n];
        let mut c_nt = vec![0.0f32; m * n];
        gemm_nn(&a, &b, &mut c_nn, m, n, k);
        gemm_nt(&a, &bt, &mut c_nt, m, n, k);
        for (x, y) in c_nn.iter().zip(&c_nt) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn tn_matches_f64_reference() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let (m, n, p) = (9, 14, 200);
        let a = randv(&mut rng, p * m);
        let b = randv(&mut rng, p * n);
        let mut c = vec![0.0f32; m * n];
        gemm_tn(&a, &b, &mut c, m, n, p);
        let mut want = vec![0.0f64; m * n];
        for r in 0..p {
            for i in 0..m {
                for j in 0..n {
                    want[i * n + j] += a[r * m + i] as f64 * b[r * n + j] as f64;
                }
            }
        }
        assert_close(&c, &want, 1e-5);
    }

    #[test]
    fn kernels_accumulate_into_c() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let mut c = [10.0f32];
        gemm_nt(&a, &b, &mut c, 1, 1, 2);
        assert_eq!(c[0], 10.0 + 11.0);
    }

    #[test]
    fn deterministic_across_repeated_calls() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let (m, n, k) = (13, 21, 777);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_nt(&a, &b, &mut c1, m, n, k);
        gemm_nt(&a, &b, &mut c2, m, n, k);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&c1), bits(&c2));
    }

    #[test]
    fn empty_dims_are_noops() {
        let mut c = [1.0f32, 2.0];
        gemm_nt(&[], &[], &mut c, 2, 1, 0);
        assert_eq!(c, [1.0, 2.0]);
        gemm_nn(&[], &[], &mut [], 0, 0, 5);
        gemm_tn(&[], &[], &mut [], 0, 3, 0);
        col_sum_add(&[], &mut [], 0);
    }

    #[test]
    fn col_sums() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let mut out = [0.5f32, 0.0, 0.0];
        col_sum_add(&a, &mut out, 3);
        assert_eq!(out, [5.5, 7.0, 9.0]);
    }
}
