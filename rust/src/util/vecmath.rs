//! Dense `f32` vector kernels used on the coordinator hot path.
//!
//! These are the L3 equivalents of BLAS-1 routines. They are written to
//! auto-vectorize (simple indexed loops over slices of equal, asserted
//! length, accumulation in f64 where numerical robustness matters for
//! norms of million-element gradients).

/// `||v||₂²` with f64 accumulation.
#[inline]
pub fn norm2_sq(v: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        acc += (x as f64) * (x as f64);
    }
    acc
}

/// `||v||₂`.
#[inline]
pub fn norm2(v: &[f32]) -> f64 {
    norm2_sq(v).sqrt()
}

/// `||v||_∞`.
#[inline]
pub fn norm_inf(v: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &x in v {
        let a = x.abs();
        if a > m {
            m = a;
        }
    }
    m
}

/// `||a − b||₂²` without materializing the difference.
#[inline]
pub fn diff_norm2_sq(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y = x`.
#[inline]
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// `v *= s`.
#[inline]
pub fn scale(v: &mut [f32], s: f32) {
    for x in v {
        *x *= s;
    }
}

/// `out = a − b`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += (a[i] as f64) * (b[i] as f64);
    }
    acc
}

/// Fused pass computing `(||v||₂², ||v||_∞)` in a single traversal —
/// the reduction stage of the AQUILA device step (mirrors the L1 Pallas
/// kernel's pass 1).
#[inline]
pub fn l2sq_and_linf(v: &[f32]) -> (f64, f32) {
    let mut l2 = 0.0f64;
    let mut li = 0.0f32;
    for &x in v {
        l2 += (x as f64) * (x as f64);
        let a = x.abs();
        if a > li {
            li = a;
        }
    }
    (l2, li)
}

/// Fused pass over the *implicit* innovation `g − q` computing
/// `(||g−q||₂², ||g−q||_∞)` without materializing it.
#[inline]
pub fn innovation_norms(g: &[f32], q: &[f32]) -> (f64, f32) {
    assert_eq!(g.len(), q.len());
    let mut l2 = 0.0f64;
    let mut li = 0.0f32;
    for i in 0..g.len() {
        let d = g[i] - q[i];
        l2 += (d as f64) * (d as f64);
        let a = d.abs();
        if a > li {
            li = a;
        }
    }
    (l2, li)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        let v = [3.0f32, -4.0];
        assert_eq!(norm2_sq(&v), 25.0);
        assert_eq!(norm2(&v), 5.0);
        assert_eq!(norm_inf(&v), 4.0);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(norm2_sq(&[]), 0.0);
        assert_eq!(norm_inf(&[]), 0.0);
        let (l2, li) = l2sq_and_linf(&[]);
        assert_eq!((l2, li), (0.0, 0.0));
    }

    #[test]
    fn axpy_and_sub() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
        let mut out = [0.0f32; 3];
        sub(&y, &x, &mut out);
        assert_eq!(out, [11.0, 22.0, 33.0]);
    }

    #[test]
    fn fused_matches_separate() {
        let v: Vec<f32> = (0..1000).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        let (l2, li) = l2sq_and_linf(&v);
        assert!((l2 - norm2_sq(&v)).abs() < 1e-6);
        assert_eq!(li, norm_inf(&v));
    }

    #[test]
    fn innovation_matches_materialized() {
        let g: Vec<f32> = (0..512).map(|i| (i as f32).sin()).collect();
        let q: Vec<f32> = (0..512).map(|i| (i as f32).cos()).collect();
        let mut d = vec![0.0f32; 512];
        sub(&g, &q, &mut d);
        let (l2, li) = innovation_norms(&g, &q);
        assert!((l2 - norm2_sq(&d)).abs() < 1e-6);
        assert_eq!(li, norm_inf(&d));
    }

    #[test]
    fn diff_norm_matches() {
        let a = [1.0f32, 5.0, -2.0];
        let b = [0.0f32, 3.0, -4.0];
        assert_eq!(diff_norm2_sq(&a, &b), 1.0 + 4.0 + 4.0);
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
