//! Streaming metric sinks: a [`RoundObserver`] receives every
//! [`RoundRecord`] as the round loop produces it, so traces no longer
//! have to be accumulated monolithically inside the coordinator.
//!
//! Shipped sinks: [`TraceCollector`] (in-memory [`RunTrace`]),
//! [`CsvStream`] (streaming CSV file), [`JsonLines`] (one JSON object
//! per round). Attach with `SessionBuilder::observer`; a session may
//! carry any number of sinks.

use super::{RoundRecord, RunTrace};
use crate::util::json::{obj, Json};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Run-identifying metadata delivered once at `on_run_start`.
#[derive(Clone, Debug)]
pub struct RunMeta {
    /// Algorithm name.
    pub algorithm: String,
    /// Dataset label.
    pub dataset: String,
    /// Split label.
    pub split: String,
    /// Configured horizon `K`.
    pub rounds: usize,
}

/// A per-round metrics sink. All methods are called from the round
/// loop thread, in round order.
pub trait RoundObserver: Send {
    /// Called once before round 0 when driven via `Session::run`
    /// (manual `run_round` stepping skips it).
    fn on_run_start(&mut self, _meta: &RunMeta) {}

    /// Called after every completed round.
    fn on_round(&mut self, record: &RoundRecord);

    /// Called once after the final round; flush buffers here.
    fn on_run_end(&mut self) {}
}

/// In-memory sink accumulating a [`RunTrace`]. Wrap in
/// `Arc<Mutex<...>>` (which also implements [`RoundObserver`]) to keep
/// a handle to the trace while the session owns the observer.
#[derive(Debug, Default)]
pub struct TraceCollector {
    trace: RunTrace,
}

impl TraceCollector {
    /// Empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// A handle usable both as an observer (clone one `Arc` into the
    /// builder) and as the post-run accessor.
    pub fn shared() -> Arc<Mutex<TraceCollector>> {
        Arc::new(Mutex::new(Self::new()))
    }

    /// The trace collected so far.
    pub fn trace(&self) -> &RunTrace {
        &self.trace
    }

    /// Consume the collector, yielding the trace.
    pub fn into_trace(self) -> RunTrace {
        self.trace
    }
}

impl RoundObserver for TraceCollector {
    fn on_run_start(&mut self, meta: &RunMeta) {
        self.trace.algorithm = meta.algorithm.clone();
        self.trace.dataset = meta.dataset.clone();
        self.trace.split = meta.split.clone();
        self.trace.rounds.reserve(meta.rounds);
    }

    fn on_round(&mut self, record: &RoundRecord) {
        self.trace.rounds.push(record.clone());
    }
}

impl RoundObserver for Arc<Mutex<TraceCollector>> {
    fn on_run_start(&mut self, meta: &RunMeta) {
        self.lock().unwrap().on_run_start(meta);
    }

    fn on_round(&mut self, record: &RoundRecord) {
        self.lock().unwrap().on_round(record);
    }
}

/// Streaming CSV sink: header on creation, one row per round, flushed
/// at run end (and on drop via `BufWriter`).
pub struct CsvStream {
    w: BufWriter<std::fs::File>,
}

impl CsvStream {
    /// Create/truncate `path` (parent directories are created) and
    /// write the header line.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        writeln!(w, "{}", RoundRecord::CSV_HEADER)?;
        Ok(Self { w })
    }
}

impl RoundObserver for CsvStream {
    fn on_round(&mut self, record: &RoundRecord) {
        // Fail loudly: a silently truncated trace is worse than an
        // aborted run (the pre-observer `--out` path panicked too).
        writeln!(self.w, "{}", record.csv_row()).expect("writing CSV trace row");
    }

    fn on_run_end(&mut self) {
        self.w.flush().expect("flushing CSV trace");
    }
}

/// JSON-lines sink: one `{"meta": ...}` object at run start, then one
/// record object per round.
pub struct JsonLines {
    w: BufWriter<std::fs::File>,
}

impl JsonLines {
    /// Create/truncate `path` (parent directories are created).
    pub fn create(path: &Path) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self {
            w: BufWriter::new(std::fs::File::create(path)?),
        })
    }
}

impl RoundObserver for JsonLines {
    fn on_run_start(&mut self, meta: &RunMeta) {
        let j = obj(vec![(
            "meta",
            obj(vec![
                ("algorithm", Json::Str(meta.algorithm.clone())),
                ("dataset", Json::Str(meta.dataset.clone())),
                ("split", Json::Str(meta.split.clone())),
                ("rounds", Json::Num(meta.rounds as f64)),
            ]),
        )]);
        writeln!(self.w, "{j}").expect("writing json-lines meta");
    }

    fn on_round(&mut self, record: &RoundRecord) {
        writeln!(self.w, "{}", record.to_json()).expect("writing json-lines record");
    }

    fn on_run_end(&mut self) {
        self.w.flush().expect("flushing json-lines trace");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            bits_up: 10,
            cum_bits: 10 * (round as u64 + 1),
            uploads: 2,
            skips: 1,
            mean_level: 3.0,
            train_loss: 1.0 / (round as f64 + 1.0),
            eval_loss: None,
            accuracy: Some(0.5),
            perplexity: None,
            ..RoundRecord::default()
        }
    }

    fn meta() -> RunMeta {
        RunMeta {
            algorithm: "AQUILA".into(),
            dataset: "quad".into(),
            split: "iid".into(),
            rounds: 3,
        }
    }

    #[test]
    fn trace_collector_accumulates() {
        let mut c = TraceCollector::new();
        c.on_run_start(&meta());
        for k in 0..3 {
            c.on_round(&rec(k));
        }
        c.on_run_end();
        let t = c.into_trace();
        assert_eq!(t.algorithm, "AQUILA");
        assert_eq!(t.rounds.len(), 3);
        assert_eq!(t.total_bits(), 30);
    }

    #[test]
    fn shared_collector_readable_after_run() {
        let shared = TraceCollector::shared();
        {
            let mut obs: Box<dyn RoundObserver> = Box::new(shared.clone());
            obs.on_run_start(&meta());
            obs.on_round(&rec(0));
            obs.on_run_end();
        }
        assert_eq!(shared.lock().unwrap().trace().rounds.len(), 1);
    }

    #[test]
    fn csv_stream_writes_rows() {
        let dir = std::env::temp_dir().join("aquila_obs_csv");
        let path = dir.join("t.csv");
        {
            let mut s = CsvStream::create(&path).unwrap();
            s.on_run_start(&meta());
            for k in 0..2 {
                s.on_round(&rec(k));
            }
            s.on_run_end();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], RoundRecord::CSV_HEADER);
        assert!(lines[1].starts_with("0,10,10,2,1,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_lines_parse_back() {
        let dir = std::env::temp_dir().join("aquila_obs_jsonl");
        let path = dir.join("t.jsonl");
        {
            let mut s = JsonLines::create(&path).unwrap();
            s.on_run_start(&meta());
            for k in 0..2 {
                s.on_round(&rec(k));
            }
            s.on_run_end();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let m = Json::parse(lines[0]).unwrap();
        assert_eq!(m.get("meta").get("algorithm").as_str(), Some("AQUILA"));
        let r1 = Json::parse(lines[2]).unwrap();
        assert_eq!(r1.get("round").as_usize(), Some(1));
        assert_eq!(r1.get("eval_loss"), &Json::Null);
        std::fs::remove_dir_all(&dir).ok();
    }
}
