//! Per-round metrics, run summaries, and CSV/JSON emission — the data
//! behind every table row and figure series. Streaming sinks live in
//! [`observer`]: attach a [`observer::RoundObserver`] to a
//! `crate::coordinator::Session` to emit records as the run progresses
//! instead of accumulating them monolithically.

pub mod observer;

use crate::util::json::{obj, Json};
use std::io::Write;
use std::path::Path;

/// One communication round's record (one point of the Figure 2/3
/// series).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    /// Communication round index (0-based).
    pub round: usize,
    /// Uplink bits this round (actual serialized bytes × 8).
    pub bits_up: u64,
    /// Cumulative uplink bits.
    pub cum_bits: u64,
    /// Devices that uploaded.
    pub uploads: usize,
    /// Devices that skipped.
    pub skips: usize,
    /// Mean quantization level among devices that computed one.
    pub mean_level: f64,
    /// Global training loss `f(θᵏ)` (average of local losses).
    pub train_loss: f64,
    /// Held-out loss (sampled every `eval_every` rounds; `None`
    /// between evaluations).
    pub eval_loss: Option<f64>,
    /// Held-out accuracy (classification problems; same cadence).
    pub accuracy: Option<f64>,
    /// Held-out perplexity (LM problems; same cadence).
    pub perplexity: Option<f64>,
    /// Uploads that missed the round deadline this round (simulated
    /// network scenarios; 0 over the ideal network).
    pub stragglers: usize,
    /// Downlink broadcast bits this round (model bits × participants).
    pub bits_down: u64,
    /// Simulated duration of this round in seconds.
    pub round_time: f64,
    /// Cumulative simulated wall-clock at the end of this round —
    /// the x-axis of time-to-accuracy curves
    /// ([`RunTrace::time_to_loss`]).
    pub sim_time: f64,
    /// Mean staleness (commits elapsed since dispatch) of the uploads
    /// folded this round. Always 0 on the synchronous path.
    pub mean_staleness: f64,
    /// Maximum staleness among the uploads folded this round.
    pub max_staleness: usize,
    /// Uploads still in flight when this round's model committed
    /// (buffered-async overlap; 0 on the synchronous path).
    pub inflight: usize,
}

impl RoundRecord {
    /// Column header matching [`RoundRecord::csv_row`].
    pub const CSV_HEADER: &'static str = "round,bits_up,cum_bits,uploads,skips,mean_level,\
         train_loss,eval_loss,accuracy,perplexity,stragglers,bits_down,round_time,sim_time,\
         mean_staleness,max_staleness,inflight";

    /// One CSV line (no trailing newline).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{:.4},{:.6},{},{},{},{},{},{:.6},{:.6},{:.4},{},{}",
            self.round,
            self.bits_up,
            self.cum_bits,
            self.uploads,
            self.skips,
            self.mean_level,
            self.train_loss,
            self.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            self.accuracy.map(|v| format!("{v:.6}")).unwrap_or_default(),
            self.perplexity.map(|v| format!("{v:.4}")).unwrap_or_default(),
            self.stragglers,
            self.bits_down,
            self.round_time,
            self.sim_time,
            self.mean_staleness,
            self.max_staleness,
            self.inflight,
        )
    }

    /// The record as a JSON object (JSON-lines streaming sink).
    /// Non-finite values (a NaN train loss on a round with no
    /// participants) serialize as `null` — bare `NaN` is not JSON.
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("bits_up", Json::Num(self.bits_up as f64)),
            ("cum_bits", Json::Num(self.cum_bits as f64)),
            ("uploads", Json::Num(self.uploads as f64)),
            ("skips", Json::Num(self.skips as f64)),
            ("mean_level", num(self.mean_level)),
            ("train_loss", num(self.train_loss)),
            ("eval_loss", opt(self.eval_loss)),
            ("accuracy", opt(self.accuracy)),
            ("perplexity", opt(self.perplexity)),
            ("stragglers", Json::Num(self.stragglers as f64)),
            ("bits_down", Json::Num(self.bits_down as f64)),
            ("round_time", num(self.round_time)),
            ("sim_time", num(self.sim_time)),
            ("mean_staleness", num(self.mean_staleness)),
            ("max_staleness", Json::Num(self.max_staleness as f64)),
            ("inflight", Json::Num(self.inflight as f64)),
        ])
    }
}

/// Full trace of a run plus identifying metadata.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    /// Algorithm name (as printed in the tables).
    pub algorithm: String,
    /// Dataset label.
    pub dataset: String,
    /// Split label.
    pub split: String,
    /// Per-round records, in round order.
    pub rounds: Vec<RoundRecord>,
}

impl RunTrace {
    /// Total uplink bits across the run.
    pub fn total_bits(&self) -> u64 {
        self.rounds.last().map(|r| r.cum_bits).unwrap_or(0)
    }

    /// Total downlink (broadcast) bits across the run.
    pub fn total_bits_down(&self) -> u64 {
        self.rounds.iter().map(|r| r.bits_down).sum()
    }

    /// Total simulated wall-clock of the run in seconds (0 over the
    /// ideal network).
    pub fn total_sim_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    /// Total deadline-missing uploads across the run.
    pub fn total_stragglers(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers).sum()
    }

    /// Final training loss `f(θᴷ)`.
    pub fn final_train_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    /// Last observed held-out accuracy.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.accuracy)
    }

    /// Last observed held-out perplexity.
    pub fn final_perplexity(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.perplexity)
    }

    /// Total uploads across all rounds/devices.
    pub fn total_uploads(&self) -> usize {
        self.rounds.iter().map(|r| r.uploads).sum()
    }

    /// Total skip decisions across all rounds/devices.
    pub fn total_skips(&self) -> usize {
        self.rounds.iter().map(|r| r.skips).sum()
    }

    /// Bits needed to first reach `loss` (communication-to-target
    /// metric; `None` if never reached).
    pub fn bits_to_loss(&self, loss: f64) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.train_loss <= loss)
            .map(|r| r.cum_bits)
    }

    /// Simulated seconds needed to first reach `loss` — the
    /// time-to-accuracy companion of [`RunTrace::bits_to_loss`]
    /// (`None` if never reached; 0 over the ideal network).
    pub fn time_to_loss(&self, loss: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.train_loss <= loss)
            .map(|r| r.sim_time)
    }

    /// Write the trace as CSV (one row per round).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", RoundRecord::CSV_HEADER)?;
        for r in &self.rounds {
            writeln!(f, "{}", r.csv_row())?;
        }
        Ok(())
    }

    /// Summary as a JSON object (machine-readable experiment record).
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("algorithm", Json::Str(self.algorithm.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("split", Json::Str(self.split.clone())),
            ("rounds", Json::Num(self.rounds.len() as f64)),
            ("total_bits", Json::Num(self.total_bits() as f64)),
            ("total_bits_down", Json::Num(self.total_bits_down() as f64)),
            ("total_uploads", Json::Num(self.total_uploads() as f64)),
            ("total_skips", Json::Num(self.total_skips() as f64)),
            ("total_stragglers", Json::Num(self.total_stragglers() as f64)),
            ("sim_time", Json::Num(self.total_sim_time())),
            ("final_train_loss", Json::Num(self.final_train_loss())),
            (
                "final_accuracy",
                self.final_accuracy().map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "final_perplexity",
                self.final_perplexity().map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Pretty-print bits as the paper's tables do (GB = 10⁹ bits here;
/// the paper labels columns "GB" while reporting total communication
/// bits — we mirror the convention and note it in EXPERIMENTS.md).
pub fn bits_display(bits: u64) -> String {
    let gb = bits as f64 / 1e9;
    if gb >= 0.01 {
        format!("{gb:.2}")
    } else {
        format!("{gb:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> RunTrace {
        RunTrace {
            algorithm: "AQUILA".into(),
            dataset: "cf10".into(),
            split: "iid".into(),
            rounds: vec![
                RoundRecord {
                    round: 0,
                    bits_up: 100,
                    cum_bits: 100,
                    uploads: 4,
                    skips: 0,
                    mean_level: 3.0,
                    train_loss: 2.0,
                    eval_loss: Some(2.1),
                    accuracy: Some(0.1),
                    perplexity: None,
                    stragglers: 1,
                    bits_down: 400,
                    round_time: 0.5,
                    sim_time: 0.5,
                    mean_staleness: 0.0,
                    max_staleness: 0,
                    inflight: 0,
                },
                RoundRecord {
                    round: 1,
                    bits_up: 50,
                    cum_bits: 150,
                    uploads: 2,
                    skips: 2,
                    mean_level: 2.5,
                    train_loss: 1.0,
                    eval_loss: None,
                    accuracy: None,
                    perplexity: None,
                    stragglers: 0,
                    bits_down: 200,
                    round_time: 0.25,
                    sim_time: 0.75,
                    mean_staleness: 0.5,
                    max_staleness: 1,
                    inflight: 3,
                },
            ],
        }
    }

    #[test]
    fn aggregates() {
        let t = trace();
        assert_eq!(t.total_bits(), 150);
        assert_eq!(t.total_uploads(), 6);
        assert_eq!(t.total_skips(), 2);
        assert_eq!(t.final_train_loss(), 1.0);
        assert_eq!(t.final_accuracy(), Some(0.1)); // last observed
        assert_eq!(t.bits_to_loss(1.5), Some(150));
        assert_eq!(t.bits_to_loss(0.1), None);
        assert_eq!(t.total_bits_down(), 600);
        assert_eq!(t.total_stragglers(), 1);
        assert_eq!(t.total_sim_time(), 0.75);
        assert_eq!(t.time_to_loss(1.5), Some(0.75));
        assert_eq!(t.time_to_loss(0.1), None);
    }

    #[test]
    fn csv_writes_and_parses_back() {
        let t = trace();
        let dir = std::env::temp_dir().join("aquila_metrics_test");
        let path = dir.join("trace.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[0].ends_with("mean_staleness,max_staleness,inflight"));
        assert!(lines[1].contains("2.000000"));
        assert!(lines[2].ends_with(",0.5000,1,3"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_json_shape() {
        let j = trace().summary_json();
        assert_eq!(j.get("algorithm").as_str(), Some("AQUILA"));
        assert_eq!(j.get("total_bits").as_usize(), Some(150));
        assert_eq!(j.get("final_perplexity"), &Json::Null);
    }

    #[test]
    fn bits_display_formats() {
        assert_eq!(bits_display(15_610_000_000), "15.61");
        assert_eq!(bits_display(4_590_000_000), "4.59");
    }
}
