//! Theory calculators for Section IV: convergence-round predictions,
//! hyperparameter feasibility, the Lemma-1 deviation bound, and the
//! Assumption-3 `γ` estimator. The integration tests in
//! `rust/tests/theory_validation.rs` check these against measured runs
//! on the quadratic problem (where `L`, `μ`, `f*` are exact).

use crate::quant::midtread::QuantizedVec;

/// The hyperparameter condition of Corollary 1 / Theorem 3:
/// `L/2 − 1/(2α) + βγ/α ≤ 0`.
pub fn corollary1_condition(l: f64, alpha: f64, beta: f64, gamma: f64) -> bool {
    l / 2.0 - 1.0 / (2.0 * alpha) + beta * gamma / alpha <= 0.0
}

/// Largest `β` satisfying the Corollary-1 condition for given `L`, `α`,
/// `γ` (useful when choosing experiment presets).
pub fn max_feasible_beta(l: f64, alpha: f64, gamma: f64) -> f64 {
    // β ≤ (1/(2α) − L/2)·α/γ = (1 − αL)/(2γ)
    ((1.0 - alpha * l) / (2.0 * gamma)).max(0.0)
}

/// Corollary 1: rounds to reach `min_k ‖∇f(θᵏ)‖² ≤ ε²` in the general
/// non-convex case,
/// `K = 2ω₁/(α ε²)` with `ω₁ = f(θ¹) − f* + (βγ/α)‖θ¹ − θ⁰‖²`.
pub fn corollary1_rounds(
    f_theta1: f64,
    f_star: f64,
    theta_diff01_sq: f64,
    alpha: f64,
    beta: f64,
    gamma: f64,
    epsilon_sq: f64,
) -> f64 {
    let omega1 = f_theta1 - f_star + beta * gamma / alpha * theta_diff01_sq;
    2.0 * omega1 / (alpha * epsilon_sq)
}

/// Theorem 3 (PL case): rounds for
/// `f(θ^{K+1}) − f* + (1/(2α) − L/2)‖θ^{K+1} − θ^K‖² ≤ ε`:
/// `K = log(ω₁/ε) / (−log(1 − αμ))`.
pub fn theorem3_rounds(
    f_theta1: f64,
    f_star: f64,
    theta_diff01_sq: f64,
    alpha: f64,
    l: f64,
    mu: f64,
    epsilon: f64,
) -> f64 {
    let omega1 = f_theta1 - f_star + (1.0 / (2.0 * alpha) - l / 2.0) * theta_diff01_sq;
    if omega1 <= epsilon {
        return 0.0;
    }
    let rate = 1.0 - alpha * mu;
    assert!(rate > 0.0 && rate < 1.0, "need 0 < αμ < 1");
    (omega1 / epsilon).ln() / (-rate.ln())
}

/// LAG's PL-case round count for the same target (eq. 47–48 of the
/// paper's remark): contraction `1 − αμ + αμ√(Dξ)` — strictly worse
/// than Theorem 3's `1 − αμ` for any `ξ > 0`.
pub fn lag_rounds(
    omega1: f64,
    alpha: f64,
    mu: f64,
    d_depth: f64,
    xi: f64,
    epsilon: f64,
) -> f64 {
    let rate = 1.0 - alpha * mu + alpha * mu * (d_depth * xi).sqrt();
    assert!(rate > 0.0 && rate < 1.0);
    (omega1 / epsilon).ln() / (-rate.ln())
}

/// The Lemma-1 upper bound on the model deviation caused by skipping:
///
/// ```text
/// ‖θ̃ᵏ − θᵏ‖² ≤ (4α²|M_c|/M²) Σ_{m∈M_c} [ (‖v_m‖₂ − ‖τ_m R_m 1‖₂)² + 6 R_m² d ]
/// ```
///
/// (final line of the Lemma-1 chain). `skipped` carries, per skipped
/// device, `(innov_l2 = ‖∇f_m − q_m^{k−1}‖₂, quantized)`.
pub fn lemma1_bound(alpha: f64, m_total: usize, skipped: &[(f64, &QuantizedVec)]) -> f64 {
    let mc = skipped.len() as f64;
    let mut sum = 0.0;
    for (innov_l2, q) in skipped {
        let d = q.dim() as f64;
        let tau_r = q.tau() * q.range as f64;
        let tau_r_vec_norm = tau_r * d.sqrt(); // ‖τR·1‖₂ = τR√d
        let a = innov_l2 - tau_r_vec_norm;
        sum += a * a + 6.0 * (q.range as f64) * (q.range as f64) * d;
    }
    4.0 * alpha * alpha * mc / ((m_total * m_total) as f64) * sum
}

/// The per-device Lemma-1 objective `(‖v‖₂ − τR√d)²` that Theorem 1
/// minimizes over `τ = 1/(2^b − 1)` — used by tests to verify eq. 19 is
/// the integer minimizer.
pub fn deviation_objective(innov_l2: f64, range: f64, d: usize, bits: u8) -> f64 {
    let tau = 1.0 / (((1u64 << bits) - 1) as f64);
    let a = innov_l2 - tau * range * (d as f64).sqrt();
    a * a
}

/// Estimate Assumption 3's `γ`: the smallest `γ ≥ 1` with
/// `‖ε‖² ≤ (γ/M²)‖Σ_{m∈M_c} ε_m‖²`, given the global error norm and the
/// skipped-device error-sum norm. Returns `None` when `M_c` is empty or
/// the RHS vanishes while the LHS does not (the degenerate case the
/// paper's Assumption-3 discussion covers).
pub fn estimate_gamma(global_err_sq: f64, skipped_err_sum_sq: f64, m_total: usize) -> Option<f64> {
    if skipped_err_sum_sq <= 0.0 {
        return if global_err_sq <= 0.0 { Some(1.0) } else { None };
    }
    let g = global_err_sq * (m_total * m_total) as f64 / skipped_err_sum_sq;
    Some(g.max(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::midtread::quantize;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::vecmath::norm2;

    #[test]
    fn condition_and_max_beta_agree() {
        let (l, alpha, gamma) = (2.5, 0.1, 2.0);
        let bmax = max_feasible_beta(l, alpha, gamma);
        assert!(corollary1_condition(l, alpha, bmax, gamma));
        assert!(!corollary1_condition(l, alpha, bmax + 1e-6, gamma));
        // NOTE (reproduction finding, recorded in EXPERIMENTS.md): the
        // paper's worked example after Corollary 2 — α=0.1, β=0.25,
        // γ=2, L=2.5 — does NOT satisfy its own condition:
        // L/2 − 1/(2α) + βγ/α = 1.25 − 5 + 5 = 1.25 > 0.
        assert!(!corollary1_condition(2.5, 0.1, 0.25, 2.0));
        // A corrected instance: β = 0.15 gives 1.25 − 5 + 3 ≤ 0.
        assert!(corollary1_condition(2.5, 0.1, 0.15, 2.0));
    }

    #[test]
    fn aquila_beats_lag_rate() {
        // Theorem-3 remark: AQUILA's contraction 1−αμ beats LAG's
        // 1−αμ+αμ√(Dξ) — so K_AQUILA < K_LAG for the same ω₁, ε.
        let (alpha, mu, omega1, eps) = (0.1, 0.5, 10.0, 1e-3);
        let k_aquila = theorem3_rounds(omega1 + 0.0, 0.0, 0.0, alpha, 1.0, mu, eps);
        let k_lag = lag_rounds(omega1, alpha, mu, 10.0, 0.05, eps);
        assert!(k_aquila < k_lag, "{k_aquila} vs {k_lag}");
    }

    #[test]
    fn theorem3_rounds_monotone_in_epsilon() {
        let k1 = theorem3_rounds(2.0, 0.0, 0.1, 0.1, 1.0, 0.5, 1e-2);
        let k2 = theorem3_rounds(2.0, 0.0, 0.1, 0.1, 1.0, 0.5, 1e-4);
        assert!(k2 > k1);
        assert_eq!(theorem3_rounds(0.5, 0.0, 0.0, 0.1, 1.0, 0.5, 1.0), 0.0);
    }

    #[test]
    fn corollary1_rounds_scale() {
        let k = corollary1_rounds(1.0, 0.0, 0.0, 0.1, 0.25, 2.0, 1e-2);
        assert!((k - 2.0 * 1.0 / (0.1 * 1e-2)).abs() < 1e-9);
        // Adding the θ-difference term increases ω₁.
        let k2 = corollary1_rounds(1.0, 0.0, 1.0, 0.1, 0.25, 2.0, 1e-2);
        assert!(k2 > k);
    }

    #[test]
    fn lemma1_bound_holds_empirically() {
        // Model deviation from skipping = (α/M)‖Σ_{m∈M_c} Δq_m − v_m ... ‖;
        // here we verify the bound dominates the actual deviation
        // ‖(α/M) Σ_{skip} (q_m^{k-1} + Δq_m − q_m^{k-1})‖... Direct
        // construction: deviation = (α/M)·‖Σ Δq_m‖ where the paper's θ̃−θ
        // = (α/M) Σ_{m∈M_c} Δq_m (difference between aggregating Δq and
        // reusing old q).
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let (alpha, m_total, d) = (0.1f64, 10usize, 256usize);
        for bits in [1u8, 2, 4, 8] {
            let mut skipped_q = Vec::new();
            let mut innovs = Vec::new();
            let mut dq_sum = vec![0.0f32; d];
            for _ in 0..3 {
                let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
                let q = quantize(&v, bits);
                let dq = crate::quant::midtread::dequantize(&q);
                for (s, x) in dq_sum.iter_mut().zip(&dq) {
                    *s += x;
                }
                innovs.push(norm2(&v));
                skipped_q.push(q);
            }
            let deviation_sq = {
                let n = norm2(&dq_sum);
                (alpha / m_total as f64) * (alpha / m_total as f64) * n * n
            };
            let pairs: Vec<(f64, &QuantizedVec)> = innovs
                .iter()
                .copied()
                .zip(skipped_q.iter())
                .collect();
            let bound = lemma1_bound(alpha, m_total, &pairs);
            assert!(
                deviation_sq <= bound,
                "bits={bits}: deviation {deviation_sq} > bound {bound}"
            );
        }
    }

    #[test]
    fn eq19_minimizes_deviation_objective_over_integers() {
        use crate::quant::levels::aquila_level;
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..50 {
            let d = 16 + rng.next_bounded(2048) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32(0.0, 2.0)).collect();
            let (l2sq, linf) = crate::util::vecmath::l2sq_and_linf(&v);
            let l2 = l2sq.sqrt();
            let b_star = aquila_level(l2, linf, d);
            // Brute-force the true integer minimizer of the Lemma-1
            // objective; eq. 19 (ceil of the continuous optimum) must be
            // within one level of it — the integer-rounding slack of
            // Theorem 1.
            let b_best = (1u8..=32)
                .min_by(|&a, &b| {
                    deviation_objective(l2, linf as f64, d, a)
                        .partial_cmp(&deviation_objective(l2, linf as f64, d, b))
                        .unwrap()
                })
                .unwrap();
            assert!(
                (b_star as i32 - b_best as i32).abs() <= 1,
                "d={d}: eq19 gives b*={b_star}, brute-force best b={b_best}"
            );
        }
    }

    #[test]
    fn gamma_estimator() {
        assert_eq!(estimate_gamma(0.0, 0.0, 10), Some(1.0));
        assert_eq!(estimate_gamma(1.0, 0.0, 10), None);
        // ‖ε‖² = 4, ‖Σ_skip ε‖² = 100, M = 10: γ = 4·100/100 = 4.
        assert_eq!(estimate_gamma(4.0, 100.0, 10), Some(4.0));
        // Clamped to ≥ 1.
        assert_eq!(estimate_gamma(1e-9, 100.0, 10), Some(1.0));
    }
}
