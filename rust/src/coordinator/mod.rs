//! The federated coordinator: Algorithm 1's outer loop.
//!
//! Owns the global model `θ`, the per-device states, the simulated
//! uplink channel, and the round protocol:
//!
//! 1. a [`crate::selection::SelectionStrategy`] picks the round's
//!    participant set (full, random-K, round-robin, loss-weighted,
//!    availability-aware, or user-defined);
//! 2. broadcast `θᵏ` (plus `‖θᵏ − θ^{k−1}‖²` and the loss estimates the
//!    baselines' rules need);
//! 3. every *selected* device computes its full-batch local gradient
//!    `∇f_m(θᵏ)` (in parallel across a thread pool), gathers it through
//!    its HeteroFL capacity mask, and runs the algorithm's client step;
//! 4. uploads cross the byte-counting channel — which also simulates
//!    the configured network scenario: per-device link transfer times,
//!    the round deadline's straggler window, availability traces, and
//!    optional fault injection (`crate::transport::scenario`) — and the
//!    algorithm's server fold produces the step direction; the server
//!    updates `θ^{k+1} = θᵏ − α·direction` (eq. 5 / Algorithm 1
//!    line 14);
//! 5. metrics are recorded and streamed to every attached
//!    [`crate::metrics::observer::RoundObserver`].
//!
//! The round protocol is implemented once by [`engine::RoundEngine`];
//! the owned, builder-constructed [`Session`] is its front-end. (The
//! deprecated lifetime-bound `Coordinator<'_>` shim that also wrapped
//! the engine was removed after its one-release grace period — migrate
//! to `Session::builder(...)`.) See DESIGN.md §2 for the architecture.

pub mod aggregation;
pub mod checkpoint;
pub mod engine;
pub mod population;
mod session;

pub use aggregation::{AggregationMode, StalenessPolicy};
pub use population::PopulationSpec;
pub use session::{Session, SessionBuilder};

pub(crate) use session::SessionParts;

use crate::quant::SectionSpec;
use crate::selection::{FullParticipation, RandomK, SelectionStrategy};
use crate::transport::scenario::NetworkSpec;
use crate::transport::FaultSpec;

/// How the engine stores per-device slot state (DESIGN.md §Population).
///
/// Both policies produce byte-identical traces (pinned by
/// `tests/prop_population.rs`); the policy only trades memory for slot
/// rebuild work on re-selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Materialize every device's slot at construction — the
    /// pre-virtualization behavior; O(population) memory.
    Eager,
    /// Materialize slots lazily for selected cohorts only, keeping at
    /// most `cache` materialized slots between rounds (least recently
    /// selected devices are parked to compact state; `cache = 0` means
    /// unbounded). Memory is O(cache + cohort + d).
    Lazy {
        /// Live-slot cache capacity (0 = unbounded).
        cache: usize,
    },
}

/// Runtime configuration of one FL run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Server learning rate `α`.
    pub alpha: f32,
    /// AQUILA tuning factor `β` (eq. 8).
    pub beta: f32,
    /// Number of communication rounds `K`.
    pub rounds: usize,
    /// Evaluate held-out metrics every this many rounds (0 = only at
    /// the end).
    pub eval_every: usize,
    /// Base seed (device RNG streams, θ⁰, MARINA coin, selection).
    pub seed: u64,
    /// Worker threads for device gradient computation (0 = auto).
    pub threads: usize,
    /// MARINA synchronization probability.
    pub marina_p_sync: f64,
    /// DAdaQuant time-adaptive schedule: initial quantization level
    /// `b₀` (doubles on training-loss stagnation).
    pub dadaquant_b0: u8,
    /// DAdaQuant schedule: stagnant observations tolerated before the
    /// level doubles.
    pub dadaquant_patience: u32,
    /// DAdaQuant schedule: hard cap on the doubled level.
    pub dadaquant_cap: u8,
    /// Deprecated spelling of [`crate::selection::SelectionSpec::RandomK`]:
    /// honored by [`SessionBuilder`] when no explicit strategy/spec is
    /// given. Prefer `SessionBuilder::selection_spec`.
    pub sample_k: Option<usize>,
    /// Depth of the model-difference history broadcast (LAQ/LENA `D`).
    pub history_depth: usize,
    /// Uplink fault injection.
    pub faults: FaultSpec,
    /// Simulated network scenario (per-device links, round deadline,
    /// availability trace). Default: the ideal zero-cost network —
    /// `sim_time` stays 0 and no upload ever straggles.
    pub network: NetworkSpec,
    /// Quantization sectioning (`crate::quant::sections`): how each
    /// device partitions its upload into per-scale sections. The
    /// default `global` reproduces the single-scale wire format
    /// byte-for-byte; `tensor` gives one scale per `ParamLayout`
    /// tensor; `fixed:N` gives `N`-element blocks.
    pub quant_sections: SectionSpec,
    /// Device-slot storage policy. The default [`SlotPolicy::Eager`]
    /// keeps every device materialized (fine up to ~10⁵ devices);
    /// million-device populations should run [`SlotPolicy::Lazy`] with
    /// a cache a few times the cohort size.
    pub slots: SlotPolicy,
    /// Aggregation mode: the default synchronous barrier or the
    /// buffered-async event engine (DESIGN.md §Async).
    pub aggregation: AggregationMode,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            alpha: 0.1,
            beta: 0.25,
            rounds: 100,
            eval_every: 10,
            seed: 17,
            threads: 0,
            marina_p_sync: 0.1,
            dadaquant_b0: 2,
            dadaquant_patience: 3,
            dadaquant_cap: 16,
            sample_k: None,
            history_depth: 10,
            faults: FaultSpec::none(),
            network: NetworkSpec::default(),
            quant_sections: SectionSpec::Global,
            slots: SlotPolicy::Eager,
            aggregation: AggregationMode::Sync,
        }
    }
}

/// The deprecated `sample_k` fallback [`SessionBuilder`] applies when
/// no explicit strategy/spec is given (kept so old configs keep
/// working).
pub(crate) fn strategy_from_cfg(cfg: &RunConfig) -> Box<dyn SelectionStrategy> {
    match cfg.sample_k {
        Some(k) => Box::new(RandomK::new(k.max(1), cfg.seed)),
        None => Box::new(FullParticipation),
    }
}

#[cfg(test)]
mod tests {
    use super::checkpoint::{self, Checkpoint};
    use super::*;
    use crate::algorithms::{aquila::Aquila, fedavg::FedAvg, qsgd::QsgdAlgo, Algorithm};
    use crate::problems::quadratic::QuadraticProblem;
    use crate::problems::GradientSource;
    use crate::selection::SelectionSpec;
    use std::sync::Arc;

    fn quick_cfg(rounds: usize) -> RunConfig {
        RunConfig {
            alpha: 0.2,
            beta: 0.1,
            rounds,
            eval_every: 0,
            seed: 3,
            threads: 2,
            ..RunConfig::default()
        }
    }

    fn session(
        p: &Arc<QuadraticProblem>,
        algo: Arc<dyn Algorithm>,
        cfg: RunConfig,
    ) -> Session {
        Session::builder(p.clone(), algo)
            .config(cfg)
            .dataset("quad")
            .split("iid")
            .build()
    }

    #[test]
    fn fedavg_converges_on_quadratic() {
        let p = Arc::new(QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 1));
        let trace = session(&p, Arc::new(FedAvg), quick_cfg(60)).run();
        let gap0 = trace.rounds[0].train_loss - p.optimum_value();
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < gap0 * 1e-3, "no convergence: {gap0} -> {gap}");
    }

    #[test]
    fn aquila_converges_and_skips() {
        let p = Arc::new(QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 2));
        let trace = session(&p, Arc::new(Aquila::new(0.25)), quick_cfg(80)).run();
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < 1e-2, "gap {gap}");
        assert!(trace.total_skips() > 0, "β=0.25 should skip sometimes");
    }

    #[test]
    fn aquila_beats_fedavg_bits_on_quadratic() {
        let p = Arc::new(QuadraticProblem::new(64, 10, 0.5, 2.0, 0.5, 3));
        let t_fed = session(&p, Arc::new(FedAvg), quick_cfg(60)).run();
        let t_aq = session(&p, Arc::new(Aquila::new(0.25)), quick_cfg(60)).run();
        // Both converge...
        assert!(t_fed.final_train_loss() - p.optimum_value() < 1e-2);
        assert!(t_aq.final_train_loss() - p.optimum_value() < 1e-2);
        // ...but AQUILA spends far fewer bits.
        assert!(
            (t_aq.total_bits() as f64) < 0.5 * t_fed.total_bits() as f64,
            "{} vs {}",
            t_aq.total_bits(),
            t_fed.total_bits()
        );
    }

    #[test]
    fn bits_accounting_is_consistent() {
        let p = Arc::new(QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 4));
        let mut s = session(&p, Arc::new(QsgdAlgo::new(8)), quick_cfg(10));
        let trace = s.run();
        let sum: u64 = trace.rounds.iter().map(|r| r.bits_up).sum();
        assert_eq!(sum, trace.total_bits());
        assert_eq!(sum, s.total_bits());
        // QSGD transmits every device every round.
        assert!(trace.rounds.iter().all(|r| r.uploads == 4 && r.skips == 0));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = Arc::new(QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 5));
        let t1 = session(&p, Arc::new(Aquila::new(0.25)), quick_cfg(20)).run();
        let t2 = session(&p, Arc::new(Aquila::new(0.25)), quick_cfg(20)).run();
        assert_eq!(t1.total_bits(), t2.total_bits());
        assert_eq!(t1.final_train_loss(), t2.final_train_loss());
        // Thread count must not affect results.
        let mut cfg1 = quick_cfg(20);
        cfg1.threads = 1;
        let t3 = session(&p, Arc::new(Aquila::new(0.25)), cfg1).run();
        assert_eq!(t1.final_train_loss(), t3.final_train_loss());
        assert_eq!(t1.total_bits(), t3.total_bits());
    }

    #[test]
    fn eval_cadence() {
        let p = Arc::new(QuadraticProblem::new(8, 3, 0.5, 2.0, 0.5, 6));
        let mut cfg = quick_cfg(10);
        cfg.eval_every = 3;
        let trace = session(&p, Arc::new(FedAvg), cfg).run();
        for r in &trace.rounds {
            let expect = r.round % 3 == 0 || r.round == 9;
            assert_eq!(r.eval_loss.is_some(), expect, "round {}", r.round);
        }
    }

    #[test]
    fn fault_injection_still_converges() {
        let p = Arc::new(QuadraticProblem::new(16, 8, 0.5, 2.0, 0.5, 7));
        let mut cfg = quick_cfg(120);
        cfg.faults = FaultSpec {
            drop_prob: 0.2,
            seed: 9,
        };
        cfg.alpha = 0.1;
        let trace = session(&p, Arc::new(FedAvg), cfg).run();
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < 0.05, "gap {gap} under 20% drop rate");
    }

    #[test]
    fn sampled_cohort_limits_uploads() {
        use crate::algorithms::dadaquant::DAdaQuant;
        let p = Arc::new(QuadraticProblem::new(16, 10, 0.5, 2.0, 0.5, 8));
        let trace = Session::builder(p.clone(), Arc::new(DAdaQuant::uniform(16)))
            .config(quick_cfg(10))
            .selection_spec(SelectionSpec::RandomK(3))
            .build()
            .run();
        assert!(trace.rounds.iter().all(|r| r.uploads <= 3));
        assert!(trace.rounds.iter().all(|r| r.uploads >= 1));
    }

    #[test]
    fn checkpoint_resume_is_exact() {
        // Run 20 rounds straight vs 10 + snapshot/restore + 10: the
        // deterministic parts of the trace must match exactly.
        let p = Arc::new(QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 77));
        let algo: Arc<dyn Algorithm> = Arc::new(Aquila::new(0.25));
        let mut full = session(&p, algo.clone(), quick_cfg(20));
        let mut full_trace = Vec::new();
        for k in 0..20 {
            full_trace.push(full.run_round(k));
        }

        let mut first = session(&p, algo.clone(), quick_cfg(20));
        for k in 0..10 {
            first.run_round(k);
        }
        let ckpt = first.snapshot(10);
        // Round-trip through disk too.
        let dir = std::env::temp_dir().join("aquila_coord_ckpt");
        let path = dir.join("t.ckpt");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        let mut second = session(&p, algo, quick_cfg(20));
        let next = second.restore(&loaded).unwrap();
        assert_eq!(next, 10);
        for k in next..20 {
            let rec = second.run_round(k);
            assert_eq!(rec.train_loss, full_trace[k].train_loss, "round {k}");
            assert_eq!(rec.bits_up, full_trace[k].bits_up, "round {k}");
            assert_eq!(rec.cum_bits, full_trace[k].cum_bits, "round {k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_resume_qsgd_is_exact() {
        // QSGD's client is a stochastic quantizer: exact resume needs
        // the device RNG streams the v2 checkpoint format persists —
        // the gap the v1 format left open.
        let p = Arc::new(QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 79));
        let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(6));
        let mut full = session(&p, algo.clone(), quick_cfg(16));
        let mut full_trace = Vec::new();
        for k in 0..16 {
            full_trace.push(full.run_round(k));
        }

        let mut first = session(&p, algo.clone(), quick_cfg(16));
        for k in 0..8 {
            first.run_round(k);
        }
        let ckpt = first.snapshot(8);
        let dir = std::env::temp_dir().join("aquila_coord_ckpt_qsgd");
        let path = dir.join("t.ckpt");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.version, checkpoint::VERSION);
        assert_eq!(loaded.device_rng.len(), 5);
        let mut second = session(&p, algo, quick_cfg(16));
        let next = second.restore(&loaded).unwrap();
        for k in next..16 {
            let rec = second.run_round(k);
            assert_eq!(rec.train_loss, full_trace[k].train_loss, "round {k}");
            assert_eq!(rec.bits_up, full_trace[k].bits_up, "round {k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let p = Arc::new(QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 78));
        let p2 = Arc::new(QuadraticProblem::new(16, 5, 0.5, 2.0, 0.5, 78));
        let algo: Arc<dyn Algorithm> = Arc::new(Aquila::new(0.25));
        let s1 = session(&p, algo.clone(), quick_cfg(5));
        let ckpt = s1.snapshot(0);
        let mut s2 = session(&p2, algo, quick_cfg(5));
        assert!(s2.restore(&ckpt).is_err());
    }

    #[test]
    fn hetero_masks_reduce_bits() {
        use crate::hetero::half_half_masks;
        let p = Arc::new(QuadraticProblem::new(64, 8, 0.5, 2.0, 0.5, 9));
        let algo: Arc<dyn Algorithm> = Arc::new(QsgdAlgo::new(8));
        let full_trace = session(&p, algo.clone(), quick_cfg(5)).run();
        let masks = half_half_masks(&p.layout(), 8, 0.5);
        let hetero_trace = Session::builder(p.clone(), algo)
            .config(quick_cfg(5))
            .masks(masks)
            .build()
            .run();
        assert!(
            hetero_trace.total_bits() < full_trace.total_bits(),
            "{} vs {}",
            hetero_trace.total_bits(),
            full_trace.total_bits()
        );
    }

    #[test]
    fn session_honors_deprecated_sample_k() {
        // `RunConfig::sample_k` (the pre-Session spelling of random-K
        // selection) must keep working through the builder fallback now
        // that the borrowed `Coordinator<'_>` shim is gone.
        use crate::algorithms::dadaquant::DAdaQuant;
        let p = Arc::new(QuadraticProblem::new(16, 10, 0.5, 2.0, 0.5, 8));
        let mut cfg = quick_cfg(10);
        cfg.sample_k = Some(3);
        let trace = session(&p, Arc::new(DAdaQuant::uniform(16)), cfg).run();
        assert!(trace.rounds.iter().all(|r| r.uploads <= 3));
        assert!(trace.rounds.iter().all(|r| r.uploads >= 1));
    }

    #[test]
    fn sectioned_run_converges_and_shrinks_nothing_it_shouldnt() {
        // `quant_sections = tensor` over a single-tensor problem
        // resolves to one section, so the whole run must be
        // bit-identical to the default global configuration.
        let p = Arc::new(QuadraticProblem::new(32, 6, 0.5, 2.0, 0.5, 11));
        let mut cfg = quick_cfg(25);
        cfg.quant_sections = SectionSpec::Tensor;
        let t_tensor = session(&p, Arc::new(Aquila::new(0.25)), cfg).run();
        let t_global = session(&p, Arc::new(Aquila::new(0.25)), quick_cfg(25)).run();
        assert_eq!(t_tensor.total_bits(), t_global.total_bits());
        assert_eq!(
            t_tensor.final_train_loss().to_bits(),
            t_global.final_train_loss().to_bits()
        );
        // Fixed 8-element blocks: payloads grow by the section table
        // but the run still converges.
        let mut cfg = quick_cfg(60);
        cfg.quant_sections = SectionSpec::Fixed(8);
        let t_fixed = session(&p, Arc::new(Aquila::new(0.25)), cfg).run();
        let gap = t_fixed.final_train_loss() - p.optimum_value();
        assert!(gap < 1e-2, "sectioned run failed to converge: {gap}");
    }
}
