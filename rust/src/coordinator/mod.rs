//! The federated coordinator: Algorithm 1's outer loop.
//!
//! Owns the global model `θ`, the per-device states, the simulated
//! uplink channel, and the round protocol:
//!
//! 1. broadcast `θᵏ` (plus `‖θᵏ − θ^{k−1}‖²` and the loss estimates the
//!    baselines' rules need);
//! 2. every device computes its full-batch local gradient
//!    `∇f_m(θᵏ)` (in parallel across a thread pool), gathers it through
//!    its HeteroFL capacity mask, and runs the algorithm's client step;
//! 3. uploads cross the byte-counting channel (with optional fault
//!    injection) and are decoded server-side;
//! 4. the algorithm's server fold produces the step direction and the
//!    server updates `θ^{k+1} = θᵏ − α·direction` (eq. 5 / Algorithm 1
//!    line 14);
//! 5. metrics are recorded (bits, uploads, levels, losses, periodic
//!    held-out evaluation).

pub mod checkpoint;

use crate::algorithms::{Algorithm, ClientUpload, DeviceState, RoundCtx, ServerAgg};
use checkpoint::Checkpoint;
use crate::hetero::CapacityMask;
use crate::metrics::{RoundRecord, RunTrace};
use crate::problems::GradientSource;
use crate::quant::levels::DadaquantSchedule;
use crate::transport::wire::Payload;
use crate::transport::{Channel, FaultSpec};
use crate::util::pool::parallel_for_each_mut;
use crate::util::rng::Xoshiro256pp;
use crate::util::vecmath::{axpy, diff_norm2_sq};
use std::collections::VecDeque;
use std::sync::Arc;

/// Runtime configuration of one FL run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Server learning rate `α`.
    pub alpha: f32,
    /// AQUILA tuning factor `β` (eq. 8).
    pub beta: f32,
    /// Number of communication rounds `K`.
    pub rounds: usize,
    /// Evaluate held-out metrics every this many rounds (0 = only at
    /// the end).
    pub eval_every: usize,
    /// Base seed (device RNG streams, θ⁰, MARINA coin, sampling).
    pub seed: u64,
    /// Worker threads for device gradient computation (0 = auto).
    pub threads: usize,
    /// MARINA synchronization probability.
    pub marina_p_sync: f64,
    /// DAdaQuant cohort size (None = all devices participate — the
    /// setting of every non-DAdaQuant algorithm).
    pub sample_k: Option<usize>,
    /// Depth of the model-difference history broadcast (LAQ/LENA `D`).
    pub history_depth: usize,
    /// Uplink fault injection.
    pub faults: FaultSpec,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            alpha: 0.1,
            beta: 0.25,
            rounds: 100,
            eval_every: 10,
            seed: 17,
            threads: 0,
            marina_p_sync: 0.1,
            sample_k: None,
            history_depth: 10,
            faults: FaultSpec::none(),
        }
    }
}

/// Per-device slot: algorithm state + reusable buffers + per-round
/// staging, kept together so one thread owns the whole cache line set.
struct DeviceSlot {
    state: DeviceState,
    grad_full: Vec<f32>,
    grad_gathered: Vec<f32>,
    staged: Option<Payload>,
    staged_level: Option<u8>,
    loss: f64,
    participated: bool,
}

/// The coordinator. See module docs.
pub struct Coordinator<'a> {
    problem: &'a dyn GradientSource,
    algo: &'a dyn Algorithm,
    cfg: RunConfig,
    slots: Vec<DeviceSlot>,
    server: ServerAgg,
    theta: Vec<f32>,
    prev_theta: Vec<f32>,
    channel: Channel,
    diff_history: VecDeque<f64>,
    init_loss: f64,
    prev_loss: f64,
    coin_rng: Xoshiro256pp,
    dadaquant: DadaquantSchedule,
    threads: usize,
    cum_bits: u64,
}

impl<'a> Coordinator<'a> {
    /// Homogeneous setup: every device holds the full model.
    pub fn new(problem: &'a dyn GradientSource, algo: &'a dyn Algorithm, cfg: RunConfig) -> Self {
        let d = problem.dim();
        let m = problem.num_devices();
        let full = Arc::new(CapacityMask::full(d));
        let masks = vec![full; m];
        Self::with_masks(problem, algo, masks, cfg)
    }

    /// Heterogeneous setup with explicit per-device capacity masks
    /// (Table III / Figure 3; see `crate::hetero::half_half_masks`).
    pub fn with_masks(
        problem: &'a dyn GradientSource,
        algo: &'a dyn Algorithm,
        masks: Vec<Arc<CapacityMask>>,
        cfg: RunConfig,
    ) -> Self {
        let d = problem.dim();
        let m = problem.num_devices();
        assert_eq!(masks.len(), m, "need one mask per device");
        for mask in &masks {
            assert_eq!(mask.full_dim, d);
        }
        let theta = problem.init_theta(cfg.seed);
        let slots = masks
            .iter()
            .enumerate()
            .map(|(i, mask)| DeviceSlot {
                state: DeviceState::new(i, mask.clone(), cfg.seed),
                grad_full: vec![0.0; d],
                grad_gathered: Vec::with_capacity(mask.support()),
                staged: None,
                staged_level: None,
                loss: 0.0,
                participated: false,
            })
            .collect();
        let threads = if cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            cfg.threads
        };
        Self {
            problem,
            algo,
            server: ServerAgg::new(d, masks),
            slots,
            prev_theta: theta.clone(),
            theta,
            channel: Channel::new(cfg.faults.clone()),
            diff_history: VecDeque::with_capacity(cfg.history_depth + 1),
            init_loss: f64::NAN,
            prev_loss: f64::NAN,
            coin_rng: Xoshiro256pp::stream(cfg.seed, 0xC011),
            dadaquant: DadaquantSchedule::new(2, 3, 16),
            threads,
            cfg,
            cum_bits: 0,
        }
    }

    /// Current global model.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Cumulative uplink bits so far.
    pub fn total_bits(&self) -> u64 {
        self.channel.total_bits
    }

    /// Per-device upload/skip counters.
    pub fn device_stats(&self) -> Vec<(u64, u64)> {
        self.slots
            .iter()
            .map(|s| (s.state.uploads, s.state.skips))
            .collect()
    }

    /// Snapshot the run state (resume with [`Coordinator::restore`]).
    /// `next_round` is the index of the first round not yet executed.
    pub fn snapshot(&self, next_round: usize) -> Checkpoint {
        Checkpoint {
            version: 1,
            round: next_round,
            theta: self.theta.clone(),
            prev_theta: self.prev_theta.clone(),
            direction: self.server.direction.clone(),
            device_q: self.slots.iter().map(|s| s.state.q_prev.clone()).collect(),
            device_stats: self
                .slots
                .iter()
                .map(|s| (s.state.uploads, s.state.skips, s.state.prev_err_sq))
                .collect(),
            diff_history: self.diff_history.iter().copied().collect(),
            cum_bits: self.cum_bits,
            init_loss: self.init_loss,
            prev_loss: self.prev_loss,
        }
    }

    /// Restore a snapshot produced by [`Coordinator::snapshot`] on a
    /// coordinator built with the same problem/masks/config. Returns the
    /// next round index to execute.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<usize> {
        anyhow::ensure!(
            ckpt.theta.len() == self.theta.len(),
            "checkpoint dim {} != model dim {}",
            ckpt.theta.len(),
            self.theta.len()
        );
        anyhow::ensure!(
            ckpt.device_q.len() == self.slots.len(),
            "checkpoint device count mismatch"
        );
        for (slot, q) in self.slots.iter().zip(&ckpt.device_q) {
            anyhow::ensure!(
                slot.state.q_prev.len() == q.len(),
                "device {} support mismatch",
                slot.state.id
            );
        }
        self.theta.copy_from_slice(&ckpt.theta);
        self.prev_theta.copy_from_slice(&ckpt.prev_theta);
        self.server.direction.copy_from_slice(&ckpt.direction);
        for (slot, (q, &(u, s, e))) in self
            .slots
            .iter_mut()
            .zip(ckpt.device_q.iter().zip(&ckpt.device_stats))
        {
            slot.state.q_prev.copy_from_slice(q);
            slot.state.uploads = u;
            slot.state.skips = s;
            slot.state.prev_err_sq = e;
        }
        self.diff_history = ckpt.diff_history.iter().copied().collect();
        self.cum_bits = ckpt.cum_bits;
        self.init_loss = ckpt.init_loss;
        self.prev_loss = ckpt.prev_loss;
        Ok(ckpt.round)
    }

    fn build_ctx(&mut self, round: usize) -> RoundCtx {
        let m = self.slots.len();
        let model_diff_sq = self.diff_history.front().copied().unwrap_or(0.0);
        let selected = self.cfg.sample_k.map(|k| {
            let k = k.min(m);
            self.coin_rng.sample_indices(m, k)
        });
        let dadaquant_level = if round == 0 || self.prev_loss.is_nan() {
            self.dadaquant.level()
        } else {
            self.dadaquant.observe(self.prev_loss)
        };
        RoundCtx {
            round,
            num_devices: m,
            alpha: self.cfg.alpha,
            beta: self.cfg.beta,
            model_diff_sq,
            model_diff_history: self.diff_history.iter().copied().collect(),
            init_loss: if self.init_loss.is_nan() { 1.0 } else { self.init_loss },
            prev_loss: if self.prev_loss.is_nan() { 1.0 } else { self.prev_loss },
            marina_sync: round == 0 || self.coin_rng.bernoulli(self.cfg.marina_p_sync),
            selected,
            dadaquant_level,
        }
    }

    /// Execute one communication round; returns its record.
    pub fn run_round(&mut self, round: usize) -> RoundRecord {
        let ctx = self.build_ctx(round);
        let theta = &self.theta;
        let problem = self.problem;
        let algo = self.algo;

        // ---- device phase (parallel) ---------------------------------
        parallel_for_each_mut(&mut self.slots, self.threads, |i, slot| {
            slot.staged = None;
            slot.staged_level = None;
            slot.participated = ctx.is_selected(i);
            if !slot.participated {
                // Unselected devices (DAdaQuant sampling) do not even
                // compute this round.
                let up = algo.client_step(&mut slot.state, &[], &ctx);
                debug_assert!(up.payload.is_none());
                return;
            }
            slot.loss = problem.local_grad(i, theta, &mut slot.grad_full);
            slot.state.mask.gather(&slot.grad_full, &mut slot.grad_gathered);
            let ClientUpload { payload, level } =
                algo.client_step(&mut slot.state, &slot.grad_gathered, &ctx);
            slot.staged = payload;
            slot.staged_level = level;
        });

        // ---- transport phase ------------------------------------------
        let uploads: Vec<(usize, Payload)> = self
            .slots
            .iter_mut()
            .filter_map(|s| s.staged.take().map(|p| (s.state.id, p)))
            .collect();
        let upload_count = uploads.len();
        let (delivered, stats) = self.channel.transmit(uploads);

        // ---- server phase ---------------------------------------------
        self.algo.server_fold(&mut self.server, &delivered, &ctx);
        self.prev_theta.copy_from_slice(&self.theta);
        axpy(-self.cfg.alpha, &self.server.direction, &mut self.theta);
        let diff = diff_norm2_sq(&self.theta, &self.prev_theta);
        self.diff_history.push_front(diff);
        while self.diff_history.len() > self.cfg.history_depth {
            self.diff_history.pop_back();
        }

        // ---- metrics ----------------------------------------------------
        let participants: Vec<&DeviceSlot> =
            self.slots.iter().filter(|s| s.participated).collect();
        let train_loss = if participants.is_empty() {
            self.prev_loss
        } else {
            participants.iter().map(|s| s.loss).sum::<f64>() / participants.len() as f64
        };
        if round == 0 {
            self.init_loss = train_loss;
        }
        self.prev_loss = train_loss;
        let levels: Vec<u8> = self
            .slots
            .iter()
            .filter_map(|s| s.staged_level)
            .collect();
        let mean_level = if levels.is_empty() {
            0.0
        } else {
            levels.iter().map(|&b| b as f64).sum::<f64>() / levels.len() as f64
        };
        self.cum_bits += stats.uplink_bits;
        let do_eval = (self.cfg.eval_every > 0 && round.is_multiple_of(self.cfg.eval_every))
            || round + 1 == self.cfg.rounds;
        let (eval_loss, accuracy, perplexity) = if do_eval {
            let ev = self.problem.eval(&self.theta);
            (Some(ev.loss), ev.accuracy, ev.perplexity)
        } else {
            (None, None, None)
        };
        RoundRecord {
            round,
            bits_up: stats.uplink_bits,
            cum_bits: self.cum_bits,
            uploads: upload_count,
            skips: participants.len().saturating_sub(upload_count),
            mean_level,
            train_loss,
            eval_loss,
            accuracy,
            perplexity,
        }
    }

    /// Run the full configured horizon, producing a trace.
    pub fn run(&mut self, dataset: &str, split: &str) -> RunTrace {
        let mut trace = RunTrace {
            algorithm: self.algo.name().to_string(),
            dataset: dataset.to_string(),
            split: split.to_string(),
            rounds: Vec::with_capacity(self.cfg.rounds),
        };
        for k in 0..self.cfg.rounds {
            trace.rounds.push(self.run_round(k));
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{aquila::Aquila, fedavg::FedAvg, qsgd::QsgdAlgo};
    use crate::problems::quadratic::QuadraticProblem;
    use crate::problems::GradientSource;

    fn quick_cfg(rounds: usize) -> RunConfig {
        RunConfig {
            alpha: 0.2,
            beta: 0.1,
            rounds,
            eval_every: 0,
            seed: 3,
            threads: 2,
            ..RunConfig::default()
        }
    }

    #[test]
    fn fedavg_converges_on_quadratic() {
        let p = QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 1);
        let algo = FedAvg;
        let mut c = Coordinator::new(&p, &algo, quick_cfg(60));
        let trace = c.run("quad", "iid");
        let gap0 = trace.rounds[0].train_loss - p.optimum_value();
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < gap0 * 1e-3, "no convergence: {gap0} -> {gap}");
    }

    #[test]
    fn aquila_converges_and_skips() {
        let p = QuadraticProblem::new(32, 8, 0.5, 2.0, 0.5, 2);
        let algo = Aquila::new(0.25);
        let mut c = Coordinator::new(&p, &algo, quick_cfg(80));
        let trace = c.run("quad", "iid");
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < 1e-2, "gap {gap}");
        assert!(trace.total_skips() > 0, "β=0.25 should skip sometimes");
    }

    #[test]
    fn aquila_beats_fedavg_bits_on_quadratic() {
        let p = QuadraticProblem::new(64, 10, 0.5, 2.0, 0.5, 3);
        let fed = FedAvg;
        let aq = Aquila::new(0.25);
        let t_fed = Coordinator::new(&p, &fed, quick_cfg(60)).run("q", "iid");
        let t_aq = Coordinator::new(&p, &aq, quick_cfg(60)).run("q", "iid");
        // Both converge...
        assert!(t_fed.final_train_loss() - p.optimum_value() < 1e-2);
        assert!(t_aq.final_train_loss() - p.optimum_value() < 1e-2);
        // ...but AQUILA spends far fewer bits.
        assert!(
            (t_aq.total_bits() as f64) < 0.5 * t_fed.total_bits() as f64,
            "{} vs {}",
            t_aq.total_bits(),
            t_fed.total_bits()
        );
    }

    #[test]
    fn bits_accounting_is_consistent() {
        let p = QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 4);
        let algo = QsgdAlgo::new(8);
        let mut c = Coordinator::new(&p, &algo, quick_cfg(10));
        let trace = c.run("q", "iid");
        let sum: u64 = trace.rounds.iter().map(|r| r.bits_up).sum();
        assert_eq!(sum, trace.total_bits());
        assert_eq!(sum, c.total_bits());
        // QSGD transmits every device every round.
        assert!(trace.rounds.iter().all(|r| r.uploads == 4 && r.skips == 0));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = QuadraticProblem::new(16, 4, 0.5, 2.0, 0.5, 5);
        let algo = Aquila::new(0.25);
        let t1 = Coordinator::new(&p, &algo, quick_cfg(20)).run("q", "iid");
        let t2 = Coordinator::new(&p, &algo, quick_cfg(20)).run("q", "iid");
        assert_eq!(t1.total_bits(), t2.total_bits());
        assert_eq!(t1.final_train_loss(), t2.final_train_loss());
        // Thread count must not affect results.
        let mut cfg1 = quick_cfg(20);
        cfg1.threads = 1;
        let t3 = Coordinator::new(&p, &algo, cfg1).run("q", "iid");
        assert_eq!(t1.final_train_loss(), t3.final_train_loss());
        assert_eq!(t1.total_bits(), t3.total_bits());
    }

    #[test]
    fn eval_cadence() {
        let p = QuadraticProblem::new(8, 3, 0.5, 2.0, 0.5, 6);
        let algo = FedAvg;
        let mut cfg = quick_cfg(10);
        cfg.eval_every = 3;
        let trace = Coordinator::new(&p, &algo, cfg).run("q", "iid");
        for r in &trace.rounds {
            let expect = r.round % 3 == 0 || r.round == 9;
            assert_eq!(r.eval_loss.is_some(), expect, "round {}", r.round);
        }
    }

    #[test]
    fn fault_injection_still_converges() {
        let p = QuadraticProblem::new(16, 8, 0.5, 2.0, 0.5, 7);
        let algo = FedAvg;
        let mut cfg = quick_cfg(120);
        cfg.faults = FaultSpec {
            drop_prob: 0.2,
            seed: 9,
        };
        cfg.alpha = 0.1;
        let trace = Coordinator::new(&p, &algo, cfg).run("q", "iid");
        let gap = trace.final_train_loss() - p.optimum_value();
        assert!(gap < 0.05, "gap {gap} under 20% drop rate");
    }

    #[test]
    fn sampled_cohort_limits_uploads() {
        use crate::algorithms::dadaquant::DAdaQuant;
        let p = QuadraticProblem::new(16, 10, 0.5, 2.0, 0.5, 8);
        let algo = DAdaQuant::uniform(16);
        let mut cfg = quick_cfg(10);
        cfg.sample_k = Some(3);
        let trace = Coordinator::new(&p, &algo, cfg).run("q", "iid");
        assert!(trace.rounds.iter().all(|r| r.uploads <= 3));
        assert!(trace.rounds.iter().all(|r| r.uploads >= 1));
    }

    #[test]
    fn checkpoint_resume_is_exact() {
        // Run 20 rounds straight vs 10 + snapshot/restore + 10: the
        // deterministic parts of the trace must match exactly.
        // (Algorithms with client RNG — QSGD — would also need the RNG
        // stream persisted; AQUILA's client is deterministic.)
        let p = QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 77);
        let algo = Aquila::new(0.25);
        let mut full = Coordinator::new(&p, &algo, quick_cfg(20));
        let mut full_trace = Vec::new();
        for k in 0..20 {
            full_trace.push(full.run_round(k));
        }

        let mut first = Coordinator::new(&p, &algo, quick_cfg(20));
        for k in 0..10 {
            first.run_round(k);
        }
        let ckpt = first.snapshot(10);
        // Round-trip through disk too.
        let dir = std::env::temp_dir().join("aquila_coord_ckpt");
        let path = dir.join("t.ckpt");
        ckpt.save(&path).unwrap();
        let loaded = crate::coordinator::checkpoint::Checkpoint::load(&path).unwrap();
        let mut second = Coordinator::new(&p, &algo, quick_cfg(20));
        let next = second.restore(&loaded).unwrap();
        assert_eq!(next, 10);
        for k in next..20 {
            let rec = second.run_round(k);
            assert_eq!(rec.train_loss, full_trace[k].train_loss, "round {k}");
            assert_eq!(rec.bits_up, full_trace[k].bits_up, "round {k}");
            assert_eq!(rec.cum_bits, full_trace[k].cum_bits, "round {k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let p = QuadraticProblem::new(24, 5, 0.5, 2.0, 0.5, 78);
        let p2 = QuadraticProblem::new(16, 5, 0.5, 2.0, 0.5, 78);
        let algo = Aquila::new(0.25);
        let c1 = Coordinator::new(&p, &algo, quick_cfg(5));
        let ckpt = c1.snapshot(0);
        let mut c2 = Coordinator::new(&p2, &algo, quick_cfg(5));
        assert!(c2.restore(&ckpt).is_err());
    }

    #[test]
    fn hetero_masks_reduce_bits() {
        use crate::hetero::half_half_masks;
        let p = QuadraticProblem::new(64, 8, 0.5, 2.0, 0.5, 9);
        let algo = QsgdAlgo::new(8);
        let full_trace = Coordinator::new(&p, &algo, quick_cfg(5)).run("q", "iid");
        let masks = half_half_masks(&p.layout(), 8, 0.5);
        let hetero_trace = Coordinator::with_masks(&p, &algo, masks, quick_cfg(5)).run("q", "het");
        assert!(
            hetero_trace.total_bits() < full_trace.total_bits(),
            "{} vs {}",
            hetero_trace.total_bits(),
            full_trace.total_bits()
        );
    }
}
