//! Aggregation-mode spec: the synchronous round barrier vs the
//! buffered-async (FedBuff-style) event engine.
//!
//! The sync engine closes a round only when every surviving upload of
//! the cohort has arrived; the buffered engine folds each upload the
//! moment it lands on the simulated clock, commits a model version
//! after every `m` arrivals, scales stale contributions down by a
//! [`StalenessPolicy`], and keeps up to `max_inflight` uploads in
//! flight across overlapping cohorts. DESIGN.md §Async carries the
//! determinism argument; `tests/prop_async.rs` pins the degenerate
//! equivalence (`m = K`, `constant:1`, `inflight ≥ K` ⇒ bit-identical
//! to sync).

use std::fmt;

/// Staleness weighting applied to a buffered fold: the upload's
/// contribution is scaled by `weight(version_now − version_sent)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StalenessPolicy {
    /// Constant weight `c` regardless of staleness (FedBuff's
    /// unweighted buffer at `c = 1`).
    Constant(f32),
    /// Polynomial decay `(1 + s)^(−a)` on staleness `s` — fresh
    /// uploads (`s = 0`) keep weight 1, stale ones decay smoothly.
    Poly(f32),
}

impl StalenessPolicy {
    /// Spec grammar accepted by [`StalenessPolicy::parse`].
    pub const SYNTAX: &'static str = "constant[:C] | poly:A";

    /// Parse a staleness spec: `constant` (weight 1), `constant:C`,
    /// or `poly:A`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        match s.split_once(':') {
            None if s.eq_ignore_ascii_case("constant") => Some(Self::Constant(1.0)),
            Some((kind, arg)) => {
                let v: f32 = arg.trim().parse().ok()?;
                if !v.is_finite() || v < 0.0 {
                    return None;
                }
                match kind.trim().to_ascii_lowercase().as_str() {
                    "constant" => Some(Self::Constant(v)),
                    "poly" => Some(Self::Poly(v)),
                    _ => None,
                }
            }
            None => None,
        }
    }

    /// Fold weight for an upload that is `staleness` commits old.
    pub fn weight(&self, staleness: usize) -> f32 {
        match *self {
            Self::Constant(c) => c,
            Self::Poly(a) => (1.0 + staleness as f32).powf(-a),
        }
    }
}

impl fmt::Display for StalenessPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Constant(c) => write!(f, "constant:{c}"),
            Self::Poly(a) => write!(f, "poly:{a}"),
        }
    }
}

/// How the engine folds a cohort's uploads into a model step.
#[derive(Clone, Debug, PartialEq)]
pub enum AggregationMode {
    /// The classic barrier: wait for the whole cohort, fold once.
    Sync,
    /// Buffered-async: fold uploads as they arrive on the simulated
    /// clock, commit a version every `m` arrivals, dispatch the next
    /// cohort while stale uploads are still in flight.
    Buffered {
        /// Buffer size: arrivals per committed model version.
        m: usize,
        /// Staleness weighting applied to each buffered fold.
        staleness: StalenessPolicy,
        /// Upper bound on uploads concurrently in flight; dispatching
        /// pauses at the bound and resumes as arrivals drain it.
        max_inflight: usize,
    },
}

impl AggregationMode {
    /// Spec grammar accepted by [`AggregationMode::parse`] (the CLI
    /// `--aggregation` flag and the TOML `aggregation` key).
    pub const SYNTAX: &'static str =
        "sync | buffered:m=M[,staleness=constant:C|poly:A][,inflight=N]";

    /// Parse an aggregation spec. `staleness` defaults to
    /// `constant:1`, `inflight` to `2·m`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("sync") {
            return Some(Self::Sync);
        }
        let rest = s.strip_prefix("buffered")?;
        let rest = if rest.is_empty() { "" } else { rest.strip_prefix(':')? };
        let mut m = None;
        let mut staleness = StalenessPolicy::Constant(1.0);
        let mut inflight = None;
        for part in rest.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part.split_once('=')?;
            match k.trim().to_ascii_lowercase().as_str() {
                "m" => m = Some(v.trim().parse::<usize>().ok().filter(|&m| m >= 1)?),
                "staleness" => staleness = StalenessPolicy::parse(v)?,
                "inflight" => {
                    inflight = Some(v.trim().parse::<usize>().ok().filter(|&n| n >= 1)?)
                }
                _ => return None,
            }
        }
        let m = m?;
        Some(Self::Buffered {
            m,
            staleness,
            max_inflight: inflight.unwrap_or(2 * m),
        })
    }

    /// Whether this is the synchronous barrier mode.
    pub fn is_sync(&self) -> bool {
        matches!(self, Self::Sync)
    }
}

impl Default for AggregationMode {
    fn default() -> Self {
        Self::Sync
    }
}

impl fmt::Display for AggregationMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Sync => write!(f, "sync"),
            Self::Buffered { m, staleness, max_inflight } => {
                write!(f, "buffered:m={m},staleness={staleness},inflight={max_inflight}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sync() {
        assert_eq!(AggregationMode::parse("sync"), Some(AggregationMode::Sync));
        assert_eq!(AggregationMode::parse(" SYNC "), Some(AggregationMode::Sync));
    }

    #[test]
    fn parse_buffered_full() {
        assert_eq!(
            AggregationMode::parse("buffered:m=32,staleness=poly:0.5,inflight=200"),
            Some(AggregationMode::Buffered {
                m: 32,
                staleness: StalenessPolicy::Poly(0.5),
                max_inflight: 200,
            })
        );
    }

    #[test]
    fn parse_buffered_defaults() {
        // staleness defaults to constant:1, inflight to 2·m.
        assert_eq!(
            AggregationMode::parse("buffered:m=8"),
            Some(AggregationMode::Buffered {
                m: 8,
                staleness: StalenessPolicy::Constant(1.0),
                max_inflight: 16,
            })
        );
        assert_eq!(
            AggregationMode::parse("buffered:m=4,staleness=constant:0.5"),
            Some(AggregationMode::Buffered {
                m: 4,
                staleness: StalenessPolicy::Constant(0.5),
                max_inflight: 8,
            })
        );
    }

    #[test]
    fn parse_rejects_bad_specs() {
        // m is required; unknown keys, kinds, and ranges are errors.
        assert_eq!(AggregationMode::parse("buffered"), None);
        assert_eq!(AggregationMode::parse("buffered:inflight=4"), None);
        assert_eq!(AggregationMode::parse("buffered:m=0"), None);
        assert_eq!(AggregationMode::parse("buffered:m=4,inflight=0"), None);
        assert_eq!(AggregationMode::parse("buffered:m=4,stale=poly:1"), None);
        assert_eq!(AggregationMode::parse("buffered:m=4,staleness=exp:1"), None);
        assert_eq!(AggregationMode::parse("buffered:m=4,staleness=poly:-1"), None);
        assert_eq!(AggregationMode::parse("banana"), None);
    }

    #[test]
    fn display_roundtrips() {
        for spec in [
            "sync",
            "buffered:m=32,staleness=poly:0.5,inflight=200",
            "buffered:m=8,staleness=constant:1,inflight=16",
        ] {
            let mode = AggregationMode::parse(spec).unwrap();
            assert_eq!(AggregationMode::parse(&mode.to_string()), Some(mode));
        }
    }

    #[test]
    fn staleness_weights() {
        // Fresh uploads keep weight 1 under both policies.
        assert_eq!(StalenessPolicy::Constant(1.0).weight(0), 1.0);
        assert_eq!(StalenessPolicy::Poly(0.5).weight(0), 1.0);
        // Constant ignores staleness; poly decays monotonically.
        assert_eq!(StalenessPolicy::Constant(0.25).weight(7), 0.25);
        let p = StalenessPolicy::Poly(0.5);
        assert!(p.weight(1) < p.weight(0));
        assert!(p.weight(10) < p.weight(1));
        assert!((p.weight(3) - 0.5).abs() < 1e-6); // (1+3)^(−1/2) = 1/2
    }
}
